"""Fault-tolerance walkthrough: node failure -> replica failover ->
rebalance -> elastic batch rescale -> checkpoint resume.

  PYTHONPATH=src python examples/elastic_recovery.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data.synthetic import small_file_dataset
from repro.fanstore import FanStoreCluster, prepare_dataset
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager, restore_checkpoint
from repro.train.elastic import apply_rebalance, plan_rebalance, rescale_batch
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_state, make_train_step

# a store with replication 2 across 6 nodes ------------------------------------
files = small_file_dataset(200, (200, 2000), seed=0)
blobs, _ = prepare_dataset(files, 12, compress=False)
cluster = FanStoreCluster(6)
cluster.load_partitions(blobs, replication=2)
print(f"store: {len(files)} files, 12 partitions x2 replicas on 6 nodes")

# kill a node mid-"training" ---------------------------------------------------
cluster.fail_node(2)
print("node 2 FAILED")
assert cluster.unreachable_paths() == []      # replicas cover everything
probe = sorted(files)[7]
assert cluster.read(0, probe) == files[probe]
print("reads fail over to surviving replicas: OK")

# plan + execute repair back to R=2 --------------------------------------------
plan = plan_rebalance(cluster, target_replication=2)
made = apply_rebalance(cluster, plan)
print(f"rebalance: re-replicated {made} partitions "
      f"(lost={len(plan.lost_partitions)})")
cluster.fail_node(4)                          # a second failure is survivable
assert cluster.unreachable_paths() == []
print("second failure survivable after repair: OK")

# keep the global batch constant on the smaller world ---------------------------
bp = rescale_batch(global_batch=48, old_workers=6, new_workers=4,
                   old_microbatches=1)
print(f"batch plan after shrink: {bp.num_workers} workers x "
      f"{bp.per_worker} samples x {bp.microbatches} microbatches "
      f"= {bp.effective_batch} (unchanged)")

# checkpoint-based resume (the paper's §5.6 recovery story) ---------------------
cfg = get_smoke("qwen2-72b")
model = build_model(cfg)
ocfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=20)
state = init_state(model, jax.random.key(0), ocfg)
step = jax.jit(make_train_step(model, ocfg, microbatches=bp.microbatches))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(
    rng.integers(0, cfg.vocab_size, (48, 32)).astype(np.int32))}
mgr = CheckpointManager("/tmp/elastic_ckpt", keep=2)
for i in range(4):
    state, m = step(state, batch)
mgr.save(4, state, blocking=True)
state2, manifest = restore_checkpoint("/tmp/elastic_ckpt", state)
state2, m2 = step(state2, batch)
state, m1 = step(state, batch)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-6
print(f"checkpoint resume bit-exact at step {manifest['step']} "
      f"(loss {float(m1['loss']):.4f}): OK")
