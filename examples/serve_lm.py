"""Batched serving example: prefill a batch of prompts, stream decode.

Exercises every cache family by default (full KV, sliding-window + SSM via
hymba, MLA latent via deepseek smoke config):

  PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.models import build_model
from repro.serve.serve_step import generate, make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch).scaled(remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    shape = (args.batch, args.prompt_len)
    if cfg.family == "audio":
        shape += (cfg.num_codebooks,)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, shape).astype(np.int32))}
    if cfg.family == "vlm":
        prompt["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_patches, cfg.d_model)),
            jnp.bfloat16)

    t0 = time.perf_counter()
    out = generate(model, params, prompt, steps=args.steps,
                   sample="greedy" if args.temperature == 0 else "temp",
                   key=jax.random.key(1))
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"{args.arch} [{cfg.family}] cache segments: "
          f"{[(s.kind, s.n_layers, s.window) for s in model.segments]}")
    print(f"generated {tuple(out.shape)} tokens in {dt:.2f}s "
          f"({args.batch*args.steps/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out)[0].reshape(args.steps, -1)[:, 0].tolist())


if __name__ == "__main__":
    main()
