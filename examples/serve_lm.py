"""Batched serving example: prefill a batch of prompts, stream decode.

Exercises every cache family by default (full KV, sliding-window + SSM via
hymba, MLA latent via deepseek smoke config):

  PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b

``--fanstore`` runs the serving-plane flow instead: a publisher streams
the params AND a shared prompt-prefix KV cache into the FanStore output
tier, then N inference tenants restore both through admission-gated
:class:`~repro.fanstore.serving.TenantSession` reads on the concurrent
serve-app lane (per-tenant attributed, hot shards auto-promoted to
replicated placement) and decode from the restored state:

  PYTHONPATH=src python examples/serve_lm.py --fanstore --tenants 8
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.models import build_model
from repro.serve.serve_step import generate, make_decode_step, make_prefill_step


def run_fanstore(args) -> None:
    """Publish params + a shared KV prefix once; serve them to N tenants
    through the admission-gated serving plane. With ``--metrics-jsonl``
    the per-tenant restore latencies (p50/p99 via the bounded sketch) and
    the full ledger bridge — tenant attribution included — stream through
    the cluster's MetricsCollector to the JSONL sink."""
    from repro.fanstore.cluster import FanStoreCluster
    from repro.fanstore.metrics import JsonlSink, Reduce
    from repro.fanstore.serving import ServeGroup
    from repro.fanstore.spec import ClusterSpec
    from repro.train.checkpoint import restore_from_session, save_to_session

    cfg = get_smoke(args.arch).scaled(remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompt = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32))}
    max_len = args.prompt_len + args.steps
    prefill = jax.jit(make_prefill_step(model, max_len))
    logits, caches = prefill(params, prompt)
    # transport as float32 (npy shards); restored leaves cast back below
    caches_f32 = jax.tree_util.tree_map(
        lambda a: jnp.asarray(a, jnp.float32), caches)

    # every tenant restores every shard, so a shard goes hot exactly when
    # the last tenant reads it — the demo promotes on that final pass
    spec = ClusterSpec(num_nodes=4, selector="power-of-two",
                       max_inflight_bytes=16 << 20,
                       hot_shard_threshold=args.tenants,
                       hot_shard_replication=2)
    with FanStoreCluster.from_spec(spec) as cluster:
        publisher = cluster.connect(0, 0)
        save_to_session(publisher, 0, params, prefix="params")
        save_to_session(publisher, 0, caches_f32, prefix="kvprefix")
        group = ServeGroup(cluster, args.tenants)
        sink = (JsonlSink(args.metrics_jsonl, every_s=1.0)
                if args.metrics_jsonl else None)
        t0 = time.perf_counter()
        t_params = t_caches = None
        for tenant in group.tenants:
            ts = group.session(tenant)    # gated, serve_app-lane session
            t_tenant = time.perf_counter()
            t_params, _ = restore_from_session(ts, params, prefix="params")
            t_caches, _ = restore_from_session(ts, caches_f32,
                                               prefix="kvprefix")
            if sink is not None:
                cluster.metrics.record_metric(
                    "serve.tenant_restore_s",
                    time.perf_counter() - t_tenant, reduce=Reduce.P99)
                cluster.metrics.record_metric("serve.tenants_restored", 1,
                                              reduce=Reduce.COUNT)
                sink.tick(cluster.metrics)
        dt = time.perf_counter() - t0
        t_caches = jax.tree_util.tree_map(
            lambda a, orig: jnp.asarray(a, orig.dtype), t_caches, caches)
        # the last tenant decodes one step from the RESTORED state
        decode = jax.jit(make_decode_step(model))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        tok, _, _ = decode(t_params, nxt, t_caches,
                           jnp.int32(args.prompt_len))
        stats = group.stats()
        per_tenant = stats["tenant_bytes"]
        print(f"{args.arch}: published params + KV prefix, restored by "
              f"{args.tenants} tenants in {dt:.2f}s")
        print(f"serve_app bytes={stats['serve_app_bytes']} "
              f"requests={stats['serve_app_requests']} "
              f"peak_inflight={stats['peak_inflight_bytes']} "
              f"waits={stats['waits']} shed={stats['shed']}")
        print(f"promoted hot outputs: "
              f"{len(stats['promoted_outputs'])} of "
              f"{len(cluster.output_ns.paths())} shards; "
              f"attribution ties out: {group.attribution_ok()}")
        worst = max(per_tenant, key=per_tenant.get)
        print(f"per-tenant bytes: min={min(per_tenant.values())} "
              f"max={per_tenant[worst]} ({worst})")
        if sink is not None:
            snap = sink.flush(cluster.metrics)   # final explicit flush
            sink.close()
            rs = snap["metrics"]["serve.tenant_restore_s"]
            assert snap["cluster"]["tenant_bytes"] == per_tenant, (
                "snapshot tenant ledger diverged from ServeGroup stats")
            print(f"metrics: jsonl={args.metrics_jsonl} "
                  f"records={sink.records_written} "
                  f"version={snap['version']} "
                  f"restore_p50={rs['p50']:.4f}s "
                  f"restore_p99={rs['p99']:.4f}s "
                  f"tenants_restored="
                  f"{snap['metrics']['serve.tenants_restored']['value']:.0f}")
        print("decoded token sample from restored state:",
              np.asarray(tok)[:4].tolist())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--fanstore", action="store_true",
                    help="serve params + KV prefix to N tenants through "
                         "the FanStore serving plane")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="with --fanstore: stream per-tenant restore "
                         "metrics + the ledger bridge (tenant attribution "
                         "included) to this JSONL sink")
    args = ap.parse_args()
    if args.fanstore:
        run_fanstore(args)
        return

    cfg = get_smoke(args.arch).scaled(remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    shape = (args.batch, args.prompt_len)
    if cfg.family == "audio":
        shape += (cfg.num_codebooks,)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, shape).astype(np.int32))}
    if cfg.family == "vlm":
        prompt["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_patches, cfg.d_model)),
            jnp.bfloat16)

    t0 = time.perf_counter()
    out = generate(model, params, prompt, steps=args.steps,
                   sample="greedy" if args.temperature == 0 else "temp",
                   key=jax.random.key(1))
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"{args.arch} [{cfg.family}] cache segments: "
          f"{[(s.kind, s.n_layers, s.window) for s in model.segments]}")
    print(f"generated {tuple(out.shape)} tokens in {dt:.2f}s "
          f"({args.batch*args.steps/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out)[0].reshape(args.steps, -1)[:, 0].tolist())


if __name__ == "__main__":
    main()
