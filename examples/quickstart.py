"""Quickstart: the whole FanStore data plane in ~60 lines.

  1. make a many-small-files dataset,
  2. pack it into partitions (the paper's preparation step),
  3. declare the topology as a ClusterSpec (4 nodes x 2 workers,
     replication 2) and stand the transient store up from it,
  4. connect() a descriptor-based FanStoreSession — reads, writes, and
     directory listings all through one surface, including unmodified
     user code via interception; co-located workers share their node's
     cache tier,
  5. write outputs back through the batched write path (payloads land on
     their placement owners, visible cluster-wide on close),
  6. train a tiny LM from it for a handful of steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data.pipeline import PrefetchLoader
from repro.data.sampler import GlobalUniformSampler
from repro.data.synthetic import files_to_tokens, token_dataset, tokens_to_files
from repro.fanstore import ClusterSpec, FanStoreCluster, prepare_dataset
from repro.fanstore.intercept import intercept
from repro.models import build_model
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_state, make_train_step

# 1-2. dataset -> partitions ---------------------------------------------------
tokens = token_dataset(num_samples=256, seq_len=32, vocab=128, seed=0)
files = tokens_to_files(tokens)
blobs, report = prepare_dataset(files, num_partitions=8, compress=True)
print(f"packed {report.num_files} files -> {report.num_partitions} partitions "
      f"(ratio {report.compression_ratio:.2f}x, {report.seconds:.2f}s)")

# 3. the topology as a value: 4 "nodes" x 2 co-located workers, each
#    partition on 2 nodes. The spec is frozen, validated (typos raise with
#    suggestions), and JSON round-trips for spawned worker processes.
spec = ClusterSpec(num_nodes=4, workers_per_node=2, codec="lzss",
                   replication=2)
cluster = FanStoreCluster.from_spec(spec)
cluster.load_partitions(blobs)

# 4. one session per worker: fds, batched verbs, interception -----------------
session = cluster.connect(node_id=0, worker_id=0)
print("files visible:", session.walk_count())
first = sorted(files)[0]
fd = session.open(f"/fanstore/{first}")            # descriptor-based read
assert session.pread(fd, 16, 0) == files[first][:16]
session.close(fd)
with intercept(session):
    data = open(f"/fanstore/{first}", "rb").read()     # unmodified user code
    assert data == files[first]
    print(f"read {first} through intercepted builtins.open: {len(data)} bytes")
    fd = os.open("/fanstore/out/pred_000.bin", os.O_WRONLY | os.O_CREAT)
    os.write(fd, b"\x07" * 64)                     # fd-level detour, too
    os.close(fd)                                   # visible-on-close

# 5. batched write path: one round trip per (writer, owner) pair --------------
peer = cluster.connect(node_id=2, worker_id=1)
peer.write_many([(f"out/pred_{i:03d}.bin", bytes([i]) * 64)
                 for i in range(1, 9)])
assert session.listdir("/fanstore/out")            # outputs list everywhere
assert session.read_many(["out/pred_004.bin"])[0] == bytes([4]) * 64
print(f"wrote {len(session.listdir('/fanstore/out'))} outputs; "
      f"write lane busy {cluster.clocks[2].write_s*1e6:.1f}us on node 2")

# 6. train a tiny LM straight off the store -----------------------------------
cfg = get_smoke("chatglm3-6b")
model = build_model(cfg)
ocfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=20)
state = init_state(model, jax.random.key(0), ocfg)
step = jax.jit(make_train_step(model, ocfg))

paths = sorted(files)
sampler = GlobalUniformSampler(len(paths), 16, seed=0)
loader = PrefetchLoader(
    sampler,
    fetch_many=lambda idxs: session.read_many([paths[i] for i in idxs]),
    decode=lambda blobs: {"tokens": jnp.asarray(files_to_tokens(blobs, 32))},
    num_threads=4)

for i, batch in enumerate(loader.batches(20)):
    state, metrics = step(state, batch)
    if (i + 1) % 5 == 0:
        print(f"step {i+1:3d}  loss {float(metrics['loss']):.4f}")
print(f"local hit rate {cluster.local_hit_rate():.2f} "
      f"(node 0's session, replication=2 on 4 nodes + uniform sampling)")
