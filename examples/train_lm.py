"""End-to-end driver: train a ~100M-parameter LM through the FanStore plane.

This is the (b)-deliverable end-to-end example: a real model size (~100M),
a few hundred steps, checkpoint/resume, and the full data path
(partitions -> simulated multi-node store -> prefetch loader). On the CPU
container a full run takes tens of minutes; pass --steps 30 for a quick
pass. Resume works: re-run with --resume after interrupting.

  PYTHONPATH=src python examples/train_lm.py --steps 300 \
      --ckpt-dir /tmp/lm_ckpt
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import PrefetchLoader
from repro.data.sampler import GlobalUniformSampler
from repro.data.synthetic import files_to_tokens, token_dataset, tokens_to_files
from repro.fanstore import FanStoreCluster, FanStoreSession, prepare_dataset
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager, restore_checkpoint
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_state, make_train_step

# ~100M params: 12L x 768d x 12H, 32k vocab (GPT-2-small-like, llama-style)
LM100M = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=768,
    vocab_size=32_000, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, rope="full", remat=False, loss_chunk=4096)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--num-samples", type=int, default=2048)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    model = build_model(LM100M)
    n_params = model.param_count(jax.eval_shape(model.init, jax.random.key(0)))
    print(f"model: {n_params/1e6:.1f}M params")

    tokens = token_dataset(args.num_samples, args.seq_len, LM100M.vocab_size)
    files = tokens_to_files(tokens)
    blobs, rep = prepare_dataset(files, args.nodes * 2, compress=False)
    cluster = FanStoreCluster(args.nodes)
    cluster.load_partitions(blobs, replication=1)
    paths = sorted(files)
    print(f"fanstore: {rep.num_files} files / {rep.num_partitions} partitions "
          f"on {args.nodes} nodes")

    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=20,
                           total_steps=args.steps)
    state = init_state(model, jax.random.key(0), ocfg)
    sampler = GlobalUniformSampler(args.num_samples, args.global_batch)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if args.resume and mgr and mgr.latest_step() is not None:
        state, manifest = restore_checkpoint(args.ckpt_dir, state)
        start = manifest["step"]
        sampler.state.step = manifest["extra"]["sampler_step"]
        sampler.state.epoch = manifest["extra"]["sampler_epoch"]
        print(f"resumed at step {start}")

    # the unified client surface: each step's batch is one coalesced
    # read_many through the session of the node whose turn it is
    sessions = [FanStoreSession(cluster, nid) for nid in range(args.nodes)]
    turn = {"n": 0}

    def fetch_many(idxs):
        s = sessions[turn["n"] % args.nodes]
        turn["n"] += 1
        return s.read_many([paths[i] for i in idxs])

    loader = PrefetchLoader(
        sampler,
        fetch_many=fetch_many,
        decode=lambda bl: {"tokens": jnp.asarray(
            files_to_tokens(bl, args.seq_len))},
        num_threads=4)
    step = jax.jit(make_train_step(model, ocfg))

    t0 = time.perf_counter()
    n = start
    for batch in loader.batches(args.steps - start):
        state, metrics = step(state, batch)
        n += 1
        if n % 10 == 0 or n == args.steps:
            dt = time.perf_counter() - t0
            tps = (n - start) * args.global_batch * args.seq_len / dt
            print(f"step {n:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {tps:,.0f} tok/s", flush=True)
        if mgr and n % args.ckpt_every == 0:
            mgr.save(n, state, extra={"sampler_step": sampler.state.step,
                                      "sampler_epoch": sampler.state.epoch})
    if mgr:
        mgr.save(n, state, blocking=True,
                 extra={"sampler_step": sampler.state.step,
                        "sampler_epoch": sampler.state.epoch})
    print("done")


if __name__ == "__main__":
    main()
