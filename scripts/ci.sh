#!/usr/bin/env bash
# CI entry point. Two lanes:
#   scripts/ci.sh fast   -> tier-1 command minus tests marked slow
#   scripts/ci.sh full   -> the tier-1 command (ROADMAP.md)
# pytest.ini provides pythonpath=src, so no PYTHONPATH dance is needed;
# it is still exported for subprocess-spawning tests.
set -euo pipefail
cd "$(dirname "$0")/.."

lane="${1:-full}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "$lane" in
  fast)
    # backend-parity first: identical payloads/visibility/modeled clocks on
    # modeled vs socket vs shm wires (rdma rides the same parity matrix
    # with its one-sided no-serve contract pinned exactly), racing-writer
    # commit atomicity, and deterministic serving-loop teardown — striped
    # connections included (the conftest leak fixture fails any test that
    # strands a fanstore-* thread, so this lane cannot hang). The wire
    # suite drives the framing/codec layer directly: torn reads,
    # oversized-frame rejection, codec-flag round trips, out-of-order
    # stripe reassembly. Then the multi-worker topology parity suite:
    # ClusterSpec validation + round trip, co-located sessions sharing one
    # node cache tier (shared beats private at equal total bytes,
    # attribution sums == tier totals), per-(node, worker) schedules, and
    # the cross-process ShmArena spawn-attach round trip.
    # ... plus the fault-tolerance suite: deterministic fault injection,
    # replica failover (zero client-visible errors at R=2, retry ledger
    # == injected faults), R=1 classified NodeLostError, membership churn
    # (mark_failed/mark_joined/heal), and socket dial-retry/teardown.
    # ... plus the serving-plane suite: admission gate caps inflight
    # bytes under a 16-thread storm, DRR keeps a backlogged zipf-head
    # tenant from starving the tail, per-tenant attribution sums equal
    # the serve-app lane totals exactly, and hot shards (partitions AND
    # committed outputs) promote to replicated placement.
    # ... plus the online cache-intelligence suite: LFU/ARC/GDSF/
    # Predictive policy behavior, invalidate/clear forgetting ghost +
    # predictor state per policy, cross-epoch prefetch stitching (the
    # boundary window covers the next epoch's step 0, clean retry
    # ledger), and per-job attribution tie-out under a 2-job storm.
    # ... plus the observability-plane suite: reduce truth, the bounded
    # quantile sketch (memory O(capacity) at 100k samples), a 16-rank
    # collector storm tied out EXACTLY against the ledger bridge,
    # PER_RANK vs GLOBAL_REDUCE equivalence, JSONL rotation/reload/
    # torn-tail semantics, declarative SLO guards, and the reset-vs-
    # accrual race regression on the shared clock lock.
    python -m pytest -x -q tests/test_wire.py tests/test_backends.py \
        tests/test_topology.py tests/test_faults.py tests/test_serving.py \
        tests/test_cache_online.py tests/test_metrics.py
    python -m pytest -x -q -m "not slow" --ignore=tests/test_wire.py \
        --ignore=tests/test_backends.py \
        --ignore=tests/test_topology.py \
        --ignore=tests/test_faults.py \
        --ignore=tests/test_serving.py \
        --ignore=tests/test_cache_online.py \
        --ignore=tests/test_metrics.py
    # perf trajectory smoke: seed/batched/prefetched arms + cache policies
    # + the multi-tenant `workers` block (shared node tier strictly beats
    # private per-worker caches; attribution ledgers tie out) + the
    # MEASURED blocks (read+write, scheduled-prefetch, and checkpoint-
    # overlap traces over real socket + shm wires; guards assert nonzero
    # lane time, ledger==trace/staged bytes, shm beats socket, and clean
    # serving-loop teardown) + the `measured.wire` block (single-conn vs
    # striped/pipelined socket vs one-sided rdma on a pure-remote trace:
    # pinned throughput floor, stripe attribution, cost-model-gated codec
    # engagement, zero rdma serve time) + the guarded `prefetch_depth`
    # ratio on the slow latency-bound fabric + the guarded `failover`
    # block (mid-epoch node kill at R=2: zero failed reads, retry ledger
    # == injected faults, bounded degraded makespan; R=1 control loses
    # partitions with a classified error) + the guarded `serving` block
    # (64 tenants on 8 nodes over a zipfian trace: hot-shard replication
    # strictly beats single-owner makespan, attribution ties out, peak
    # inflight <= max_inflight_bytes, within-node fairness <= 2x).
    # ... and the guarded `cache_policy_sweep` (all seven policies x
    # three byte budgets x permutation/zipf/scan traces: ARC/Predictive
    # >= LRU everywhere, Predictive closes >= 40% of the LRU->Belady
    # zipf gap, Belady stays the upper bound, 2Q >= LRU on the scan
    # arm) + the guarded `cross_epoch` block (stitched multi-epoch
    # prefetch schedule strictly beats drain-and-refill makespan).
    # Writes BENCH_io.json (uploaded as the bench-io artifact, `workers`,
    # `measured.wire`, `prefetch_depth`, `failover`, and `serving`
    # blocks included). The run itself routes every block through the
    # observability pipeline (snapshot -> JSONL sink -> reload ->
    # byte-compatible BENCH_io.json) and evaluates the declarative
    # SloGuard table, so a pass here certifies the streamed telemetry
    # matches the emitted artifact exactly.
    python benchmarks/run.py --only io-json --io-json BENCH_io.json --smoke
    # the streaming sink must actually have streamed: a nonempty JSONL
    # twin rides next to the artifact (write_io_json reloads it and
    # asserts record == artifact before emitting either)
    test -s BENCH_io.jsonl
    ;;
  full)
    python -m pytest -x -q
    ;;
  *)
    echo "usage: $0 [fast|full]" >&2
    exit 2
    ;;
esac
