#!/usr/bin/env bash
# CI entry point. Two lanes:
#   scripts/ci.sh fast   -> tier-1 command minus tests marked slow
#   scripts/ci.sh full   -> the tier-1 command (ROADMAP.md)
# pytest.ini provides pythonpath=src, so no PYTHONPATH dance is needed;
# it is still exported for subprocess-spawning tests.
set -euo pipefail
cd "$(dirname "$0")/.."

lane="${1:-full}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

case "$lane" in
  fast)
    python -m pytest -x -q -m "not slow"
    # perf trajectory smoke: seed/batched/prefetched arms + cache policies
    # (writes BENCH_io.json; asserts prefetch beats batched, Belady beats LRU)
    python benchmarks/run.py --only io-json --io-json BENCH_io.json --smoke
    ;;
  full)
    python -m pytest -x -q
    ;;
  *)
    echo "usage: $0 [fast|full]" >&2
    exit 2
    ;;
esac
