"""Device-tier FanStore: fetch-step collective cost + dequant throughput.

Two measurements:
  1. fetch_step lowered on the production mesh (8 fake devices here, 256 in
     dryrun) -> collective bytes per step for uniform (capacity 2.0) vs
     stratified (capacity 1.0) sampling: the stratified sampler halves the
     all_to_all payload, the beyond-paper win quantified in §Perf.
  2. dequant kernel (interpret) vs ref on a batch of fetched records —
     wall time here is interpreter overhead; the roofline number that
     matters is bytes in/out (fixed 2x ratio).

Runs in a subprocess with 8 fake devices so the parent keeps 1 device.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from typing import List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import numpy as np, jax, jax.numpy as jnp, time
from repro.core import DeviceStore, DeviceStoreConfig
from repro.data.sampler import StratifiedSampler
from repro.utils.roofline import parse_collectives

mesh = jax.make_mesh((4, 2), ("data", "model"))
S, B = 4096, 4096             # samples x bytes
G = 256
rng = np.random.default_rng(0)
records = rng.integers(0, 255, (S, B), dtype=np.uint8)

for name, cf in (("uniform", 2.0), ("stratified", 1.0)):
    st = DeviceStore(mesh, DeviceStoreConfig(num_samples=S, sample_bytes=B,
                                             capacity_factor=cf))
    with mesh:
        arr = st.place(records)
        if name == "uniform":
            idx = rng.permutation(S)[:G].astype(np.int32)
        else:
            idx = StratifiedSampler(S, G, num_shards=4).next_batch()
        idxd = jax.device_put(idx, st.idx_sharding)
        fetched = jax.jit(st.fetch)
        lowered = fetched.lower(arr, idxd)
        compiled = lowered.compile()
        stats = parse_collectives(compiled.as_text())
        t0 = time.perf_counter()
        for _ in range(5):
            out, ovf = fetched(arr, idxd)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 5
        print(f"fetch,{name},cf={cf},wire_bytes={int(stats.wire_bytes)},"
              f"coll_ops={stats.count},wall_us={dt*1e6:.0f},"
              f"payload_bytes={G*B}")
"""


def main() -> List[str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_CODE)],
                         capture_output=True, text=True, env=env, timeout=480)
    if out.returncode != 0:
        return [f"fetch,ERROR,{out.stderr.strip()[-200:]}"]
    return [l for l in out.stdout.splitlines() if l.startswith("fetch,")]


if __name__ == "__main__":
    for line in main():
        print(line)
