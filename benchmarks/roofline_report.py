"""Format experiments/dryrun/*.json into the EXPERIMENTS.md roofline table.

  PYTHONPATH=src python -m benchmarks.roofline_report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.configs import ARCH_IDS, SHAPES


def load(dir_: str) -> List[Dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.1f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(rows: List[Dict], mesh: str = "16x16") -> List[str]:
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "model GF | useful | MFU-bound | peak GB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = next((r for r in rows if r.get("arch") == arch
                      and r.get("shape") == shape
                      and r.get("mesh") == mesh
                      and not r.get("skipped")), None)
            s = next((r for r in rows if r.get("arch") == arch
                      and r.get("shape") == shape and r.get("skipped")), None)
            if r is None:
                if s is not None:
                    out.append(f"| {arch} | {shape} | — | — | — | SKIP "
                               f"(sub-quadratic only) | | | | |")
                continue
            peak = r.get("peak_memory_bytes") or 0
            out.append(
                f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                f"**{r['dominant']}** | {r['model_flops_global']/1e9:.0f} | "
                f"{r['useful_flops_ratio']:.2f} | {r['mfu_bound']:.3f} | "
                f"{peak/1e9:.1f} |")
    return out


def multipod_table(rows: List[Dict]) -> List[str]:
    out = ["| arch | shape | compiled | compile_s | peak GB/dev | "
           "collectives seen |",
           "|---|---|---|---|---|---|"]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = next((r for r in rows if r.get("arch") == arch
                      and r.get("shape") == shape
                      and r.get("mesh") == "2x16x16"
                      and not r.get("skipped")), None)
            if r is None:
                continue
            peak = r.get("peak_memory_bytes") or 0
            kinds = ",".join(sorted((r.get("collectives") or {}).keys()))
            out.append(f"| {arch} | {shape} | yes | {r['compile_s']:.0f} | "
                       f"{peak/1e9:.1f} | {kinds} |")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    lines = multipod_table(rows) if args.multipod else table(rows, args.mesh)
    for l in lines:
        print(l)


if __name__ == "__main__":
    main()
