"""§Perf hillclimb cell 3: the FanStore fetch step itself, on the
production 16x16 mesh (256 chips) — the cell most representative of the
paper's technique.

Workload: train_4k's data need — G=256 samples/step of 16 KiB records
(4k tokens x int32) from a 2 TiB-class store (samples scaled so the HBM
slice stays in placeholder range; wire bytes scale exactly with G x bytes).

Arms (hypothesis -> expected collective-term delta):
  A. uniform cf=2.0 (paper-faithful: random access + capacity headroom)
  B. stratified cf=1.0 (beyond-paper: balanced sampler -> zero padding,
     expected ~2x wire reduction vs A)
  C. stratified + int8 block-quantized payload + scales (wire ~/2 again;
     dequant runs at HBM bw on device — the paper's Fig-10 trade on ICI)

Runs under a subprocess with 512 fake devices; parses the compiled HLO's
collective payloads (same methodology as the dry-run roofline).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from typing import List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import DeviceStore, DeviceStoreConfig
from repro.launch.mesh import make_production_mesh
from repro.utils.roofline import parse_collectives, LINK_BW

mesh = make_production_mesh(multi_pod=False)
G = 256
SEQ = 4096
S = 256 * 64                       # samples (64 per data shard)

def lower_arm(name, sample_bytes, cf):
    cfgs = DeviceStoreConfig(num_samples=S, sample_bytes=sample_bytes,
                             capacity_factor=cf)
    st = DeviceStore(mesh, cfgs)
    store_sds = jax.ShapeDtypeStruct((S, sample_bytes), jnp.uint8,
                                     sharding=st.store_sharding)
    idx_sds = st.idx_spec(G)
    with mesh:
        lowered = jax.jit(st.fetch).lower(store_sds, idx_sds)
        compiled = lowered.compile()
    stats = parse_collectives(compiled.as_text())
    term_us = stats.wire_bytes / LINK_BW * 1e6
    print(f"fetch_arm,{name},cf={cf},sample_bytes={sample_bytes},"
          f"wire_bytes={int(stats.wire_bytes)},coll_term_us={term_us:.1f},"
          f"by_kind={stats.bytes_by_kind}")
    return stats.wire_bytes

raw = SEQ * 4                       # int32 tokens
quant = SEQ + SEQ // 256 * 2        # int8 payload + f16 scales (4x smaller)
quant = -(-quant // 64) * 64        # pad to the byte-sharding granule
a = lower_arm("A_uniform_bf16", raw, 2.0)
b = lower_arm("B_stratified", raw, 1.0)
c = lower_arm("C_strat_int8", quant, 1.0)
print(f"fetch_arm,summary,B_vs_A={a/b:.2f}x,C_vs_A={a/c:.2f}x")
"""


def main() -> List[str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(_CODE)],
                         capture_output=True, text=True, env=env,
                         timeout=580)
    if out.returncode != 0:
        return [f"fetch_arm,ERROR,{out.stderr.strip()[-300:]}"]
    return [l for l in out.stdout.splitlines() if l.startswith("fetch_arm,")]


if __name__ == "__main__":
    for line in main():
        print(line)
