"""Figs 10-11 + §6.3: compression ratio, prep cost, and relative throughput.

Fig 10: SRGAN-like dataset packed with/without LZSS -> training throughput
delta (time saved reading smaller wire payloads vs decompress CPU cost).
Fig 11: relative bandwidth/throughput of compressed vs uncompressed reads
across node counts (small files CPU-bound -> compression hurts on 1 node;
network-bound at scale -> compression wins), using the interconnect model
with the measured LZSS decode rate.
§6.3: data-preparation wall time with and without compression.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.data.synthetic import fixed_size_files
from repro.fanstore import lzss
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.prepare import prepare_dataset


def measure_codec(sample_bytes: int = 262_144, entropy_bits: float = 3.0
                  ) -> Dict:
    """LZSS ratio + encode/decode rates on SRGAN-like (low-entropy) data."""
    rng = np.random.default_rng(0)
    data = bytes(rng.integers(0, int(2 ** entropy_bits), sample_bytes,
                              dtype=np.uint8))
    t0 = time.perf_counter()
    comp = lzss.compress(data)
    enc_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = lzss.decompress(comp)
    dec_s = time.perf_counter() - t0
    assert out == data
    return {"ratio": len(data) / len(comp),
            "encode_Bps": len(data) / enc_s,
            "decode_Bps": len(data) / dec_s}


def encode_speedup(sample_bytes: int = 262_144, entropy_bits: float = 3.0,
                   reps: int = 3) -> Dict:
    """Tuned vs reference LZSS encoder on the synthetic corpus.

    Asserts the two streams are byte-identical and that the tuned hot loop
    is >= 2x the reference throughput (interleaved best-of-``reps`` CPU
    time, so machine noise hits both encoders equally).
    """
    rng = np.random.default_rng(7)
    data = bytes(rng.integers(0, int(2 ** entropy_bits), sample_bytes,
                              dtype=np.uint8))
    fast_out = lzss.compress(data)
    ref_out = lzss.compress_reference(data)
    assert fast_out == ref_out, "tuned encoder is not byte-identical"
    assert lzss.decompress(fast_out) == data
    t_fast = []
    t_ref = []
    for _ in range(reps):
        t0 = time.process_time()
        lzss.compress(data)
        t_fast.append(time.process_time() - t0)
        t0 = time.process_time()
        lzss.compress_reference(data)
        t_ref.append(time.process_time() - t0)
    speedup = min(t_ref) / min(t_fast)
    assert speedup >= 2.0, f"encode speedup {speedup:.2f}x < 2x"
    return {"speedup": speedup,
            "fast_Bps": len(data) / min(t_fast),
            "ref_Bps": len(data) / min(t_ref)}


def prep_cost(num_files: int = 128, file_size: int = 65_536) -> List[Dict]:
    rows = []
    files = fixed_size_files(file_size, num_files, entropy_bits=3)
    for compress in (False, True):
        _, rep = prepare_dataset(files, 8, compress=compress)
        rows.append({"compress": compress, "seconds": rep.seconds,
                     "ratio": rep.compression_ratio})
    return rows


def relative_scaling(codec_stats: Dict, *, ratio: float = 2.8,
                     dec_core_Bps: float = 4.0e9, threads: int = 4,
                     inline_dec_Bps: float = 1.0e9) -> List[Dict]:
    """Fig 11: compressed/uncompressed aggregate bandwidth across scales.

    Two regimes, matching the paper's explanation (§6.6):
      * LOCAL reads (hit rate 1/N): decode shares the reading core — serial
        single-core cost added; this is why 1-node small-file compression
        *loses* in Fig 11.
      * REMOTE reads: the prefetch threads (§3.4) pipeline decode behind
        the wire, so the rate is max(wire_of_smaller_payload, dec/threads);
        with LZSSE8-class decode (>= wire rate) compression *wins* at scale.
    ``dec_core_Bps`` is native LZSSE8 (4 GB/s); ``inline_dec_Bps`` the
    effective rate when decode runs inline on the reading core with per-op
    overheads (the paper: "the bound factor is the CPU clock rate"). The
    measured pure-Python rate is reported separately by measure_codec.
    """
    rows = []
    dec_pipe = dec_core_Bps * threads
    for nodes in (1, 16, 64, 256):
        for size in (128 * 1024, 512 * 1024, 2 * 1024 * 1024, 8 * 1024 * 1024):
            net = InterconnectModel(latency_s=1.5e-6, bandwidth_Bps=100e9 / 8)
            local = 1.0 / nodes            # hit rate with R=1
            remote = 1.0 - local
            t_un = net.latency_s + size * (
                local / net.disk_bw_Bps + remote / net.bandwidth_Bps)
            t_loc = size / (net.disk_bw_Bps * ratio) + size / inline_dec_Bps
            t_rem = max(size / (net.bandwidth_Bps * ratio), size / dec_pipe)
            t_c = net.latency_s + local * t_loc + remote * t_rem
            rows.append({"nodes": nodes, "file_size": size,
                         "relative_bw": t_un / t_c})
    return rows


def main() -> List[str]:
    out = []
    stats = measure_codec()
    out.append(f"fig10,lzss_ratio={stats['ratio']:.2f},"
               f"encode={stats['encode_Bps']/1e6:.1f}MB/s,"
               f"decode={stats['decode_Bps']/1e6:.1f}MB/s")
    sp = encode_speedup()
    out.append(f"lzss_hotloop,speedup={sp['speedup']:.2f}x,"
               f"fast={sp['fast_Bps']/1e6:.2f}MB/s,"
               f"ref={sp['ref_Bps']/1e6:.2f}MB/s")
    for r in prep_cost():
        out.append(f"sec6.3,prep_compress={r['compress']},"
                   f"seconds={r['seconds']:.2f},ratio={r['ratio']:.2f}")
    for r in relative_scaling(stats):
        out.append(f"fig11,nodes={r['nodes']},"
                   f"size={r['file_size']//1024}KB,"
                   f"relative_bw={r['relative_bw']:.2f}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
