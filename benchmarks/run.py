"""Benchmark aggregator — one section per paper table/figure.

  fig1    global vs partitioned dataset view (accuracy/loss gap)
  fig3    single-node bw/throughput: FanStore vs SSD vs FUSE vs SFS
  fig5/6  multi-node scaling (GPU-cluster and CPU-cluster arms)
  fig7-9  application throughput + weak scaling (ResNet/SRGAN/FRNN minis)
  fig10/11 + sec6.3  compression ratio / prep cost / relative throughput
  fetch   device-tier fetch collective bytes (uniform vs stratified)

Prints ``name,metric=value,...`` CSV-ish lines.

``--io-json PATH`` additionally (or, with ``--only io-json``, exclusively)
writes the machine-readable BENCH_io.json perf snapshot: epoch makespan,
hit rates, and bytes moved for the seed / batched / prefetched arms at 8
and 64 nodes, the write half (write_many vs per-file loop, checkpoint
flush makespan with/without prefetch-lane overlap), the
LRU-vs-Belady-vs-2Q cache comparison, the guarded ``cache_policy_sweep``
(all seven eviction policies x three byte budgets x permutation / zipf /
scan traces) and ``cross_epoch`` block (stitched multi-epoch prefetch
schedule vs drain-and-refill), the multi-tenant ``workers`` block
(shared node cache tier vs private per-worker caches at the same total
bytes), the ``measured`` block (read+write, scheduled-prefetch, and
checkpoint-overlap traces over the real socket/shm wires), the
``measured.wire`` block (single-connection vs striped/pipelined socket vs
the one-sided rdma backend on a pure-remote trace, with a pinned
throughput floor and wire-codec engagement truth), the
``prefetch_depth`` block (the slow latency-bound fabric where the
scheduled-prefetch ratio is guarded), and the ``failover`` block (kill a
node mid-epoch at R=2: zero failed reads via replica failover, retry
ledger == injected faults, bounded degraded makespan, plus the R=1
classified-NodeLostError control), and the ``serving`` block (64
read-mostly tenants on 8 nodes replaying a zipfian shard trace through
the admission-gated serving plane: hot-shard replication strictly beats
single-owner makespan, per-tenant attribution ties out exactly, peak
inflight respects ``max_inflight_bytes``, and the within-node fairness
ratio stays under 2x). ``--smoke`` shrinks it to the fast-lane CI
variant (scripts/ci.sh fast).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback

_ROOT = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:         # `python benchmarks/run.py` from anywhere,
        sys.path.insert(0, _p)     # with or without PYTHONPATH=src


def write_io_json(path: str, *, smoke: bool = False) -> None:
    from benchmarks.io_scaling import bench_json
    result = bench_json(smoke=smoke)
    with open(path, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    # perf-trajectory guards (deterministic modeled quantities, not timing)
    for entry in result["arms"]:
        # direction-only on the fast-fabric arms: their ~1-2% prefetch
        # edge is real but thin; the GUARDED prefetch ratio lives in the
        # prefetch_depth block below, where the win is structural
        assert entry["prefetch_speedup_vs_batched"] >= 1.0, (
            f"prefetch arm went backwards at {entry['nodes']} nodes")
        w = entry["write"]
        assert w["write_speedup"] > 1.0, (
            f"write_many no longer beats the per-file write loop at "
            f"{entry['nodes']} nodes")
        assert w["overlapped_makespan_s"] < w["serialized_makespan_s"], (
            f"checkpoint/prefetch overlap regressed at "
            f"{entry['nodes']} nodes")
    cp = result["cache_policies"]
    assert cp["belady_hit_rate"] > cp["lru_hit_rate"], (
        "Belady no longer beats LRU at equal byte budget")
    # online-intelligence guards: on EVERY (budget, trace) arm of the
    # policy sweep the adaptive policies must not lose to plain LRU, the
    # reuse-distance predictor must close >= 40% of the LRU->Belady gap
    # on the zipf trace, and the oracle must stay the upper bound
    cs = result["cache_policy_sweep"]
    for kind in ("uniform", "zipf"):
        for bf, arm in cs[kind]["arms"].items():
            top = max(arm.values())
            assert arm["arc"] >= arm["lru"], (
                f"ARC lost to LRU on the {kind} trace at {bf} files "
                f"({arm['arc']:.3f} < {arm['lru']:.3f})")
            assert arm["predictive"] >= arm["lru"], (
                f"Predictive lost to LRU on the {kind} trace at {bf} "
                f"files ({arm['predictive']:.3f} < {arm['lru']:.3f})")
            assert arm["belady"] >= top, (
                f"Belady is no longer the upper bound on the {kind} "
                f"trace at {bf} files ({arm['belady']:.3f} < {top:.3f})")
    for bf, closure in cs["zipf_gap_closure"].items():
        assert closure >= 0.40, (
            f"Predictive closes only {closure:.0%} of the LRU->Belady "
            f"gap on the zipf trace at {bf} files (need >= 40%)")
    assert cs["scan"]["2q"] >= cs["scan"]["lru"], (
        f"2Q lost to LRU on the scan trace "
        f"({cs['scan']['2q']:.3f} < {cs['scan']['lru']:.3f})")
    # cross-epoch stitching guards: the stitched multi-epoch schedule
    # must make strictly fewer boundary round trips than drain-and-refill
    # and therefore finish strictly earlier, with a clean retry ledger
    ce = result["cross_epoch"]
    assert ce["stitched"]["makespan_s"] < ce["drain_refill"]["makespan_s"], (
        f"cross-epoch stitching no longer beats drain-and-refill "
        f"({ce['stitched']['makespan_s']} vs "
        f"{ce['drain_refill']['makespan_s']})")
    assert (ce["stitched"]["prefetch_windows"]
            < ce["drain_refill"]["prefetch_windows"]), (
        "stitched arm no longer saves the boundary window round trip")
    assert ce["stitched"]["retries"] == 0 == ce["drain_refill"]["retries"], (
        "cross-epoch arms recorded retries with fault injection off")
    # multi-tenant guards: the shared node cache tier must strictly beat
    # private per-worker caches of the same total bytes, and the
    # per-worker attribution ledgers must tie out against the tier totals
    wb = result["workers"]
    assert wb["shared"]["makespan_s"] < wb["private"]["makespan_s"], (
        f"shared cache tier no longer beats private per-worker caches at "
        f"{wb['nodes']}x{wb['workers']} "
        f"({wb['shared']['makespan_s']} vs {wb['private']['makespan_s']})")
    assert wb["shared"]["cache_hit_rate"] > wb["private"]["cache_hit_rate"], (
        "shared-tier hit rate regressed below the private baseline")
    assert wb["shared"]["attribution_ok"] and wb["private"]["attribution_ok"], (
        "per-worker cache attribution no longer sums to the tier totals")
    # hardware-truth guards: real bytes moved over real wires, serving
    # loops torn down, and the co-located shm path beat the socket path
    m = result["measured"]
    assert m["teardown_clean"], "serving-loop teardown leaked threads"
    for wire_arm in ("socket", "shm"):
        w = m[wire_arm]
        assert w["elapsed_s"] > 0 and w["measured_makespan_s"] > 0, (
            f"{wire_arm} backend recorded no measured time — the wire "
            f"path did not actually run")
        assert w["measured_bytes"] == w["read_bytes"] > 0, (
            f"{wire_arm} backend measured-byte ledger disagrees with the "
            f"trace ({w['measured_bytes']} != {w['read_bytes']})")
    assert m["shm_speedup_vs_socket"] > 1.0, (
        "co-located shared-memory path no longer beats the socket path")
    # measured-arm guards for the prefetch benchmark, mirroring the
    # read+write trace's: nonzero time on the PREFETCH lane specifically,
    # ledger == staged bytes, clean teardown, shm beats socket
    mp = m["prefetch"]
    assert mp["teardown_clean"], "prefetch measured arm leaked threads"
    for wire_arm in ("socket", "shm"):
        w = mp[wire_arm]
        assert w["measured_prefetch_s"] > 0, (
            f"{wire_arm} prefetch arm recorded no measured prefetch-lane "
            f"time — the scheduled windows did not cross the wire")
        assert w["measured_bytes"] == w["staged_bytes"] > 0, (
            f"{wire_arm} prefetch byte ledger disagrees with the staged "
            f"schedule ({w['measured_bytes']} != {w['staged_bytes']})")
        assert w["cache_hits"] > 0, (
            f"{wire_arm} prefetch arm demand reads never hit the cache")
    assert mp["shm_speedup_vs_socket"] > 1.0, (
        "shm no longer beats socket on the scheduled-prefetch wire leg")
    # ... and for the checkpoint-overlap benchmark: BOTH concurrent lanes
    # (prefetch + write) must show measured time in the same wall window
    mc = m["checkpoint"]
    assert mc["teardown_clean"], "checkpoint measured arm leaked threads"
    for wire_arm in ("socket", "shm"):
        w = mc[wire_arm]
        assert w["measured_write_s"] > 0 and w["measured_prefetch_s"] > 0, (
            f"{wire_arm} checkpoint-overlap arm did not exercise both "
            f"concurrent lanes (write={w['measured_write_s']}, "
            f"prefetch={w['measured_prefetch_s']})")
        assert w["elapsed_s"] > 0 and w["measured_makespan_s"] > 0, (
            f"{wire_arm} checkpoint arm recorded no measured time")
    assert mc["shm_speedup_vs_socket"] > 1.0, (
        "shm no longer beats socket on the checkpoint-overlap trace")
    # wire-gap guards: the rebuilt socket data plane must hold its floor.
    # 300 MB/s is deliberately conservative (>= 4x the 68 MB/s the PR-4
    # wire measured on this trace shape, ~3x under what the striped wire
    # actually does here) so CI noise can't flake it while a protocol
    # regression can't hide under it.
    mw = m["wire"]
    assert mw["teardown_clean"], "wire arms leaked stripe threads"
    assert mw["striped"]["throughput_MBps"] >= 300.0, (
        f"striped socket wire fell below the 300 MB/s floor "
        f"({mw['striped']['throughput_MBps']:.0f} MB/s)")
    if mw["cpu_count"] > 1:
        assert mw["stripe_speedup"] > 1.0, (
            f"striped wire no longer beats its single-connection self "
            f"(speedup {mw['stripe_speedup']:.3f})")
    else:
        # one core: stripe threads serialize, so wall-clock parallelism
        # cannot express — demand bounded overhead instead (the striping
        # machinery must not cost more than it could ever win back) and
        # leave the >1.0 claim to multi-core hosts
        assert mw["stripe_speedup"] > 0.4, (
            f"striping overhead exploded on a single-core host "
            f"(speedup {mw['stripe_speedup']:.3f})")
    assert len(mw["striped"]["stripes_used"]) > 1, (
        "striped arm moved all bytes on one stripe — striping is off")
    assert set(mw["single"]["stripes_used"]) <= {0}, (
        "single-connection arm booked bytes on extra stripes")
    # codec truth: LZSS engages exactly when the cost model predicts a
    # win — forced-slow modeled wire saves bytes, honest loopback never
    # compresses
    assert mw["codec"]["engages_when_predicted"], (
        "wire codec saved no bytes under a cost model that demands it")
    assert mw["codec"]["raw_when_not_predicted"], (
        "wire codec engaged on loopback where the cost model says raw")
    # one-sided contract: rdma moves the same bytes with ZERO owner
    # serve-lane time
    assert mw["rdma"]["serve_ns"] == 0, (
        f"rdma arm accrued owner serve time ({mw['rdma']['serve_ns']} ns) "
        f"— the one-sided contract is broken")
    assert mw["rdma"]["throughput_MBps"] > 0, "rdma arm moved no bytes"
    # the guarded prefetch ratio: on the slow latency-bound fabric with a
    # deep window the scheduler's win is structural (~1.2x), not the thin
    # smoke-arm ~1-2%
    pd = result["prefetch_depth"]
    assert pd["prefetch_speedup"] > 1.15, (
        f"deep-window prefetch win collapsed on the slow fabric "
        f"(speedup {pd['prefetch_speedup']:.3f})")
    assert pd["prefetch_windows"] > 0, (
        "prefetch_depth arm scheduled no windows")
    # failover guards: killing a node mid-epoch at R=2 must be invisible
    # to readers (zero failed reads), fully accounted (retry ledger ==
    # injected-fault count, exactly), and cheap (bounded makespan
    # inflation over the healthy run); the R=1 control must fail FAST and
    # CLASSIFIED — a NodeLostError naming the lost partitions, not a hang
    fo = result["failover"]
    fd = fo["degraded"]
    assert fd["reads_failed"] == 0, (
        f"R=2 degraded run lost {fd['reads_failed']} reads — replica "
        f"failover did not cover the killed node")
    assert fd["injected"] > 0, (
        "failover arm injected no faults — the kill never fired")
    assert fd["retries"] == fd["injected"], (
        f"retry ledger ({fd['retries']}) != injected faults "
        f"({fd['injected']}) — failover accounting is off")
    assert fo["kill_node"] in fd["failed_nodes"], (
        "killed node was never detected as failed")
    assert fd["healed_copies"] > 0, (
        "heal() restored no replicas after the kill")
    assert fo["degraded_ratio"] <= 1.6, (
        f"degraded makespan blew past the 1.6x bound "
        f"({fo['degraded_ratio']:.2f}x of healthy)")
    r1 = fo["r1"]
    assert r1["error"] == "NodeLostError" and r1["lost_partitions"], (
        f"R=1 control did not surface a classified loss "
        f"(error={r1['error']}, lost={r1['lost_partitions']})")
    # serving-plane guards: the multi-tenant zipfian trace must stay
    # multi-tenant (>= 64 tenants, 8 nodes, smoke included), hot-shard
    # replication must strictly beat single-owner makespan, per-tenant
    # attribution must tie out exactly on both arms, the measured peak
    # inflight must respect the admission cap, promotion must have
    # actually fired, and the slowest co-located tenant stays within the
    # 2x fairness bound of its node's mean
    sv = result["serving"]
    assert sv["tenants"] >= 64 and sv["nodes"] == 8, (
        f"serving arm shrank below the multi-tenant claim "
        f"({sv['tenants']} tenants, {sv['nodes']} nodes)")
    ssv, rsv = sv["single"], sv["replicated"]
    assert rsv["makespan_s"] < ssv["makespan_s"], (
        f"hot-shard replication no longer beats single-owner serving "
        f"({rsv['makespan_s']} vs {ssv['makespan_s']})")
    assert ssv["attribution_ok"] and rsv["attribution_ok"], (
        "per-tenant serving attribution no longer sums to the "
        "serve-app lane totals")
    assert rsv["promoted_partitions"], (
        "serving arm promoted no hot shards — the popularity "
        "threshold never tripped")
    for arm_name, arm in (("single", ssv), ("replicated", rsv)):
        assert 0 < arm["peak_inflight_bytes"] <= sv["max_inflight_bytes"], (
            f"{arm_name} serving arm peak inflight "
            f"({arm['peak_inflight_bytes']}) outside "
            f"(0, {sv['max_inflight_bytes']}] — the admission gate is off")
        assert arm["admission_shed"] == 0, (
            f"{arm_name} serving arm shed requests under a queue that "
            f"should absorb this trace")
        assert arm["fairness_ratio"] <= 2.0, (
            f"{arm_name} serving arm fairness ratio "
            f"{arm['fairness_ratio']:.3f} exceeds the 2x bound — a "
            f"zipf-head tenant is starving its node's tail")
    for entry in result["arms"]:
        w = entry["write"]
        print(f"io_json,nodes={entry['nodes']},"
              f"batched_speedup={entry['batched_speedup']:.3f},"
              f"prefetch_speedup={entry['prefetch_speedup_vs_batched']:.3f},"
              f"write_speedup={w['write_speedup']:.3f},"
              f"ckpt_overlap_speedup={w['overlap_speedup']:.3f}",
              flush=True)
    print(f"io_json,lru_hit={cp['lru_hit_rate']:.3f},"
          f"belady_hit={cp['belady_hit_rate']:.3f},"
          f"twoq_hit={cp['2q_hit_rate']:.3f}", flush=True)
    for kind in ("uniform", "zipf"):
        for bf, arm in sorted(cs[kind]["arms"].items(),
                              key=lambda kv: int(kv[0])):
            print(f"io_json,sweep={kind},budget_files={bf},"
                  + ",".join(f"{p}_hit={arm[p]:.3f}"
                             for p in cs["policies"]), flush=True)
    print("io_json,"
          + ",".join(f"zipf_gap_closure_{bf}={c:.2f}"
                     for bf, c in sorted(cs["zipf_gap_closure"].items(),
                                         key=lambda kv: int(kv[0])))
          + f",scan_lru_hit={cs['scan']['lru']:.3f}"
          f",scan_twoq_hit={cs['scan']['2q']:.3f}", flush=True)
    print(f"io_json,cross_epoch_stitched="
          f"{ce['stitched']['makespan_s']:.4f}s,"
          f"drain_refill={ce['drain_refill']['makespan_s']:.4f}s,"
          f"stall_speedup={ce['stall_speedup']:.3f},"
          f"windows={ce['stitched']['prefetch_windows']}v"
          f"{ce['drain_refill']['prefetch_windows']}", flush=True)
    print(f"io_json,workers={wb['workers']},nodes={wb['nodes']},"
          f"shared_hit={wb['shared']['cache_hit_rate']:.3f},"
          f"private_hit={wb['private']['cache_hit_rate']:.3f},"
          f"shared_tier_speedup={wb['shared_speedup']:.3f}", flush=True)
    print(f"io_json,measured_socket={m['socket']['elapsed_s']:.4f}s,"
          f"measured_shm={m['shm']['elapsed_s']:.4f}s,"
          f"shm_speedup={m['shm_speedup_vs_socket']:.2f}", flush=True)
    print(f"io_json,measured_prefetch_socket="
          f"{mp['socket']['elapsed_s']:.4f}s,"
          f"measured_prefetch_shm={mp['shm']['elapsed_s']:.4f}s,"
          f"prefetch_shm_speedup={mp['shm_speedup_vs_socket']:.2f}",
          flush=True)
    print(f"io_json,measured_ckpt_socket={mc['socket']['elapsed_s']:.4f}s,"
          f"measured_ckpt_shm={mc['shm']['elapsed_s']:.4f}s,"
          f"ckpt_shm_speedup={mc['shm_speedup_vs_socket']:.2f}", flush=True)
    print(f"io_json,wire_single={mw['single']['throughput_MBps']:.0f}MB/s,"
          f"wire_striped={mw['striped']['throughput_MBps']:.0f}MB/s,"
          f"wire_rdma={mw['rdma']['throughput_MBps']:.0f}MB/s,"
          f"stripe_speedup={mw['stripe_speedup']:.2f},"
          f"codec_saved={mw['codec']['forced_saved_bytes']}", flush=True)
    print(f"io_json,prefetch_depth_window={pd['window']},"
          f"batched={pd['batched_makespan_s']:.4f}s,"
          f"prefetched={pd['prefetched_makespan_s']:.4f}s,"
          f"deep_prefetch_speedup={pd['prefetch_speedup']:.3f}", flush=True)
    print(f"io_json,failover_kill_node={fo['kill_node']},"
          f"degraded_ratio={fo['degraded_ratio']:.3f},"
          f"reads_failed={fd['reads_failed']},"
          f"injected={fd['injected']},retries={fd['retries']},"
          f"healed_copies={fd['healed_copies']},"
          f"r1_lost={len(r1['lost_partitions'])}", flush=True)
    print(f"io_json,serving_tenants={sv['tenants']},"
          f"serving_nodes={sv['nodes']},"
          f"replication_speedup={sv['replication_speedup']:.2f},"
          f"promoted={len(rsv['promoted_partitions'])},"
          f"peak_inflight={rsv['peak_inflight_bytes']},"
          f"fairness_ratio={rsv['fairness_ratio']:.3f}", flush=True)
    print(f"io_json,wrote={path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig3,scaling,apps,compression,"
                         "fetch,io-json")
    ap.add_argument("--skip", default=None)
    ap.add_argument("--io-json", default=None, metavar="PATH",
                    help="also write the BENCH_io.json perf snapshot here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny io-json variant for the CI fast lane")
    args = ap.parse_args()

    sections = {
        "fig3": lambda: __import__("benchmarks.io_single_node",
                                   fromlist=["main"]).main(),
        "scaling": lambda: __import__("benchmarks.io_scaling",
                                      fromlist=["main"]).main(),
        "apps": lambda: __import__("benchmarks.app_throughput",
                                   fromlist=["main"]).main(),
        "compression": lambda: __import__("benchmarks.compression",
                                          fromlist=["main"]).main(),
        "fig1": lambda: __import__("benchmarks.view_ablation",
                                   fromlist=["main"]).main(),
        "fetch": lambda: __import__("benchmarks.fetch_device",
                                    fromlist=["main"]).main(),
    }
    only = set(args.only.split(",")) if args.only else set(sections)
    skip = set(args.skip.split(",")) if args.skip else set()
    failures = 0
    for name, fn in sections.items():
        if name not in only or name in skip:
            continue
        t0 = time.perf_counter()
        try:
            for line in fn():
                print(line, flush=True)
            print(f"section={name},seconds={time.perf_counter()-t0:.1f}",
                  flush=True)
        except Exception:
            failures += 1
            print(f"section={name},FAILED", flush=True)
            traceback.print_exc()
    # io-json runs when named in --only (works inside a comma list) or when
    # an output path is given; --only io-json alone defaults the path
    if (args.io_json or "io-json" in only) and "io-json" not in skip:
        try:
            write_io_json(args.io_json or "BENCH_io.json", smoke=args.smoke)
        except Exception:
            failures += 1
            print("section=io-json,FAILED", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
