"""Benchmark aggregator — one section per paper table/figure.

  fig1    global vs partitioned dataset view (accuracy/loss gap)
  fig3    single-node bw/throughput: FanStore vs SSD vs FUSE vs SFS
  fig5/6  multi-node scaling (GPU-cluster and CPU-cluster arms)
  fig7-9  application throughput + weak scaling (ResNet/SRGAN/FRNN minis)
  fig10/11 + sec6.3  compression ratio / prep cost / relative throughput
  fetch   device-tier fetch collective bytes (uniform vs stratified)

Prints ``name,metric=value,...`` CSV-ish lines.

``--io-json PATH`` additionally (or, with ``--only io-json``, exclusively)
writes the machine-readable BENCH_io.json perf snapshot: epoch makespan,
hit rates, and bytes moved for the seed / batched / prefetched arms at 8
and 64 nodes, the write half (write_many vs per-file loop, checkpoint
flush makespan with/without prefetch-lane overlap), the
LRU-vs-Belady-vs-2Q cache comparison, the guarded ``cache_policy_sweep``
(all seven eviction policies x three byte budgets x permutation / zipf /
scan traces) and ``cross_epoch`` block (stitched multi-epoch prefetch
schedule vs drain-and-refill), the multi-tenant ``workers`` block
(shared node cache tier vs private per-worker caches at the same total
bytes), the ``measured`` block (read+write, scheduled-prefetch, and
checkpoint-overlap traces over the real socket/shm wires), the
``measured.wire`` block (single-connection vs striped/pipelined socket vs
the one-sided rdma backend on a pure-remote trace, with a pinned
throughput floor and wire-codec engagement truth), the
``prefetch_depth`` block (the slow latency-bound fabric where the
scheduled-prefetch ratio is guarded), and the ``failover`` block (kill a
node mid-epoch at R=2: zero failed reads via replica failover, retry
ledger == injected faults, bounded degraded makespan, plus the R=1
classified-NodeLostError control), and the ``serving`` block (64
read-mostly tenants on 8 nodes replaying a zipfian shard trace through
the admission-gated serving plane: hot-shard replication strictly beats
single-owner makespan, per-tenant attribution ties out exactly, peak
inflight respects ``max_inflight_bytes``, and the within-node fairness
ratio stays under 2x). ``--smoke`` shrinks it to the fast-lane CI
variant (scripts/ci.sh fast).

The io-json emission flows through the observability plane: the bench
blocks are attached to a :class:`repro.fanstore.metrics.MetricsCollector`,
streamed to a JSONL sink next to the output path (``BENCH_io.jsonl``),
and the written ``BENCH_io.json`` is the SNAPSHOT-derived copy (asserted
equal to the source blocks, so the schema stays byte-compatible). The
perf-trajectory guards are the declarative ``IO_SLO_GUARDS`` table below,
evaluated over the reloaded JSONL stream — not assert soup.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time
import traceback

_ROOT = pathlib.Path(__file__).resolve().parents[1]
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:         # `python benchmarks/run.py` from anywhere,
        sys.path.insert(0, _p)     # with or without PYTHONPATH=src

from repro.fanstore.metrics import (JsonlSink, MetricsCollector, Ref,  # noqa: E402
                                    SloGuard, check_slos)

# Every BENCH_io.json perf-trajectory guard, as data. Paths are dotted
# with `*` wildcards; a Ref threshold compares against another path (its
# wildcards bind to the metric path's, leftovers mean "for all", which is
# how "belady >= every policy on the same arm" is spelled). Deterministic
# modeled quantities throughout, except the explicitly measured blocks.
IO_SLO_GUARDS = [
    # fast-fabric arms: direction-only (the GUARDED prefetch ratio lives
    # in prefetch_depth, where the win is structural)
    SloGuard("prefetch_direction", "arms.*.prefetch_speedup_vs_batched",
             ">=", 1.0),
    SloGuard("write_many_beats_loop", "arms.*.write.write_speedup",
             ">", 1.0),
    SloGuard("ckpt_overlap_wins", "arms.*.write.overlapped_makespan_s",
             "<", Ref("arms.*.write.serialized_makespan_s")),
    # cache policies: oracle beats LRU at equal byte budget
    SloGuard("belady_beats_lru", "cache_policies.belady_hit_rate",
             ">", Ref("cache_policies.lru_hit_rate")),
    # online intelligence: adaptive policies never lose to LRU on any
    # (budget, trace) arm; predictor closes >= 40% of the zipf gap;
    # Belady stays the upper bound; 2Q holds the scan trace
    SloGuard("arc_vs_lru_uniform", "cache_policy_sweep.uniform.arms.*.arc",
             ">=", Ref("cache_policy_sweep.uniform.arms.*.lru")),
    SloGuard("arc_vs_lru_zipf", "cache_policy_sweep.zipf.arms.*.arc",
             ">=", Ref("cache_policy_sweep.zipf.arms.*.lru")),
    SloGuard("predictive_vs_lru_uniform",
             "cache_policy_sweep.uniform.arms.*.predictive",
             ">=", Ref("cache_policy_sweep.uniform.arms.*.lru")),
    SloGuard("predictive_vs_lru_zipf",
             "cache_policy_sweep.zipf.arms.*.predictive",
             ">=", Ref("cache_policy_sweep.zipf.arms.*.lru")),
    SloGuard("belady_upper_bound_uniform",
             "cache_policy_sweep.uniform.arms.*.belady",
             ">=", Ref("cache_policy_sweep.uniform.arms.*.*")),
    SloGuard("belady_upper_bound_zipf",
             "cache_policy_sweep.zipf.arms.*.belady",
             ">=", Ref("cache_policy_sweep.zipf.arms.*.*")),
    SloGuard("zipf_gap_closure", "cache_policy_sweep.zipf_gap_closure.*",
             ">=", 0.40),
    SloGuard("twoq_holds_scan", "cache_policy_sweep.scan.2q",
             ">=", Ref("cache_policy_sweep.scan.lru")),
    # cross-epoch stitching: fewer boundary round trips, strictly earlier
    # finish, clean retry ledger
    SloGuard("stitching_beats_drain", "cross_epoch.stitched.makespan_s",
             "<", Ref("cross_epoch.drain_refill.makespan_s")),
    SloGuard("stitching_saves_window",
             "cross_epoch.stitched.prefetch_windows",
             "<", Ref("cross_epoch.drain_refill.prefetch_windows")),
    SloGuard("cross_epoch_clean_retries", "cross_epoch.*.retries",
             "==", 0),
    # multi-tenant workers: shared tier strictly beats private caches of
    # the same total bytes; attribution ledgers tie out
    SloGuard("shared_tier_wins", "workers.shared.makespan_s",
             "<", Ref("workers.private.makespan_s")),
    SloGuard("shared_tier_hit_rate", "workers.shared.cache_hit_rate",
             ">", Ref("workers.private.cache_hit_rate")),
    SloGuard("worker_attribution", "workers.*.attribution_ok", "truthy"),
    # hardware truth: real bytes over real wires, clean teardown, shm
    # beats socket, ledgers == trace bytes exactly
    SloGuard("measured_teardown", "measured.teardown_clean", "truthy"),
    SloGuard("socket_ran", "measured.socket.elapsed_s", ">", 0),
    SloGuard("shm_ran", "measured.shm.elapsed_s", ">", 0),
    SloGuard("socket_makespan", "measured.socket.measured_makespan_s",
             ">", 0),
    SloGuard("shm_makespan", "measured.shm.measured_makespan_s", ">", 0),
    SloGuard("socket_byte_ledger", "measured.socket.measured_bytes",
             "==", Ref("measured.socket.read_bytes")),
    SloGuard("shm_byte_ledger", "measured.shm.measured_bytes",
             "==", Ref("measured.shm.read_bytes")),
    SloGuard("socket_moved_bytes", "measured.socket.read_bytes", ">", 0),
    SloGuard("shm_moved_bytes", "measured.shm.read_bytes", ">", 0),
    SloGuard("shm_beats_socket", "measured.shm_speedup_vs_socket",
             ">", 1.0),
    # measured prefetch arm: nonzero PREFETCH-lane time, ledger == staged
    # bytes, demand reads hit the cache, shm beats socket
    SloGuard("prefetch_teardown", "measured.prefetch.teardown_clean",
             "truthy"),
    SloGuard("prefetch_lane_ran",
             "measured.prefetch.socket.measured_prefetch_s", ">", 0),
    SloGuard("prefetch_lane_ran_shm",
             "measured.prefetch.shm.measured_prefetch_s", ">", 0),
    SloGuard("prefetch_byte_ledger_socket",
             "measured.prefetch.socket.measured_bytes",
             "==", Ref("measured.prefetch.socket.staged_bytes")),
    SloGuard("prefetch_byte_ledger_shm",
             "measured.prefetch.shm.measured_bytes",
             "==", Ref("measured.prefetch.shm.staged_bytes")),
    SloGuard("prefetch_staged_socket",
             "measured.prefetch.socket.staged_bytes", ">", 0),
    SloGuard("prefetch_staged_shm",
             "measured.prefetch.shm.staged_bytes", ">", 0),
    SloGuard("prefetch_cache_hits_socket",
             "measured.prefetch.socket.cache_hits", ">", 0),
    SloGuard("prefetch_cache_hits_shm",
             "measured.prefetch.shm.cache_hits", ">", 0),
    SloGuard("prefetch_shm_beats_socket",
             "measured.prefetch.shm_speedup_vs_socket", ">", 1.0),
    # measured checkpoint arm: BOTH concurrent lanes show time in the
    # same wall window
    SloGuard("ckpt_teardown", "measured.checkpoint.teardown_clean",
             "truthy"),
    SloGuard("ckpt_write_lane", "measured.checkpoint.*.measured_write_s",
             ">", 0),
    SloGuard("ckpt_prefetch_lane",
             "measured.checkpoint.*.measured_prefetch_s", ">", 0),
    SloGuard("ckpt_elapsed", "measured.checkpoint.*.elapsed_s", ">", 0),
    SloGuard("ckpt_makespan", "measured.checkpoint.*.measured_makespan_s",
             ">", 0),
    SloGuard("ckpt_shm_beats_socket",
             "measured.checkpoint.shm_speedup_vs_socket", ">", 1.0),
    # wire gap: the rebuilt socket data plane holds its floor. 300 MB/s
    # is deliberately conservative (>= 4x what the PR-4 wire measured on
    # this trace shape, ~3x under what the striped wire actually does) so
    # CI noise can't flake it while a protocol regression can't hide
    SloGuard("wire_teardown", "measured.wire.teardown_clean", "truthy"),
    SloGuard("striped_floor", "measured.wire.striped.throughput_MBps",
             ">=", 300.0),
    SloGuard("stripe_speedup_multicore", "measured.wire.stripe_speedup",
             ">", 1.0, when=("measured.wire.cpu_count", ">", 1)),
    # one core: stripe threads serialize, so wall-clock parallelism
    # cannot express — bound the overhead instead
    SloGuard("stripe_overhead_unicore", "measured.wire.stripe_speedup",
             ">", 0.4, when=("measured.wire.cpu_count", "<=", 1)),
    SloGuard("striping_on", "measured.wire.striped.stripes_used",
             "min_len", 2),
    SloGuard("single_conn_stripe0", "measured.wire.single.stripes_used",
             "subset", (0,)),
    # codec truth: LZSS engages exactly when the cost model predicts
    SloGuard("codec_engages", "measured.wire.codec.engages_when_predicted",
             "truthy"),
    SloGuard("codec_stays_raw", "measured.wire.codec.raw_when_not_predicted",
             "truthy"),
    # one-sided contract: rdma moves the bytes with ZERO owner serve time
    SloGuard("rdma_one_sided", "measured.wire.rdma.serve_ns", "==", 0),
    SloGuard("rdma_moved_bytes", "measured.wire.rdma.throughput_MBps",
             ">", 0),
    # the guarded prefetch ratio: structural ~1.2x on the slow fabric
    SloGuard("deep_prefetch_win", "prefetch_depth.prefetch_speedup",
             ">", 1.15),
    SloGuard("deep_prefetch_scheduled", "prefetch_depth.prefetch_windows",
             ">", 0),
    # failover: a mid-epoch kill at R=2 is invisible (zero failed reads),
    # fully accounted (retries == injected, exactly), detected, healed,
    # and cheap; the R=1 control fails FAST and CLASSIFIED
    SloGuard("failover_zero_failures", "failover.degraded.reads_failed",
             "==", 0),
    SloGuard("failover_kill_fired", "failover.degraded.injected", ">", 0),
    SloGuard("failover_retry_ledger", "failover.degraded.retries",
             "==", Ref("failover.degraded.injected")),
    SloGuard("failover_detected", "failover.kill_node",
             "in", Ref("failover.degraded.failed_nodes")),
    SloGuard("failover_healed", "failover.degraded.healed_copies", ">", 0),
    SloGuard("failover_bounded", "failover.degraded_ratio", "<=", 1.6),
    SloGuard("r1_classified", "failover.r1.error", "==", "NodeLostError"),
    SloGuard("r1_names_loss", "failover.r1.lost_partitions", "nonempty"),
    # serving plane: stays multi-tenant, replication strictly wins,
    # attribution ties out, admission cap respected, promotion fired,
    # fairness bounded on both arms
    SloGuard("serving_multi_tenant", "serving.tenants", ">=", 64),
    SloGuard("serving_nodes", "serving.nodes", "==", 8),
    SloGuard("replication_wins", "serving.replicated.makespan_s",
             "<", Ref("serving.single.makespan_s")),
    SloGuard("serving_attribution", "serving.*.attribution_ok", "truthy"),
    SloGuard("promotion_fired", "serving.replicated.promoted_partitions",
             "nonempty"),
    SloGuard("inflight_nonzero", "serving.*.peak_inflight_bytes", ">", 0),
    SloGuard("inflight_capped", "serving.*.peak_inflight_bytes",
             "<=", Ref("serving.max_inflight_bytes")),
    SloGuard("no_shedding", "serving.*.admission_shed", "==", 0),
    SloGuard("fairness_bound", "serving.*.fairness_ratio", "<=", 2.0),
]


def write_io_json(path: str, *, smoke: bool = False) -> None:
    from benchmarks.io_scaling import bench_json
    result = bench_json(smoke=smoke)
    # ONE pipeline: attach every bench block to a collector, stream the
    # versioned snapshot to the JSONL sink beside the output path, and
    # write BENCH_io.json from the SNAPSHOT-derived copy (asserted equal
    # to the source blocks under JSON canonicalization, so the emitted
    # schema is unchanged).
    collector = MetricsCollector()
    for block_name, block in result.items():
        collector.record_block(block_name, block)
    jsonl_path = str(pathlib.Path(path).with_suffix(".jsonl"))
    if os.path.exists(jsonl_path):
        os.remove(jsonl_path)  # fresh stream: the CI nonempty check is honest
    with JsonlSink(jsonl_path) as sink:
        snap = sink.flush(collector)
    records = JsonlSink.load(jsonl_path)
    assert records and records[-1]["version"] == snap["version"], (
        "JSONL sink round trip lost the flushed snapshot")
    doc = records[-1]["bench"]
    canonical = json.loads(json.dumps(result, sort_keys=True, default=str))
    assert doc == canonical, (
        "snapshot-derived BENCH blocks diverged from the bench result")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    # perf-trajectory guards: the declarative table over the JSONL stream
    violations = check_slos(doc, IO_SLO_GUARDS)
    if violations:
        raise AssertionError(
            "BENCH_io.json SLO guard violations:\n  "
            + "\n  ".join(violations))
    cp = result["cache_policies"]
    cs = result["cache_policy_sweep"]
    ce = result["cross_epoch"]
    wb = result["workers"]
    m = result["measured"]
    mp = m["prefetch"]
    mc = m["checkpoint"]
    mw = m["wire"]
    pd = result["prefetch_depth"]
    fo = result["failover"]
    fd = fo["degraded"]
    r1 = fo["r1"]
    sv = result["serving"]
    rsv = sv["replicated"]
    for entry in result["arms"]:
        w = entry["write"]
        print(f"io_json,nodes={entry['nodes']},"
              f"batched_speedup={entry['batched_speedup']:.3f},"
              f"prefetch_speedup={entry['prefetch_speedup_vs_batched']:.3f},"
              f"write_speedup={w['write_speedup']:.3f},"
              f"ckpt_overlap_speedup={w['overlap_speedup']:.3f}",
              flush=True)
    print(f"io_json,lru_hit={cp['lru_hit_rate']:.3f},"
          f"belady_hit={cp['belady_hit_rate']:.3f},"
          f"twoq_hit={cp['2q_hit_rate']:.3f}", flush=True)
    for kind in ("uniform", "zipf"):
        for bf, arm in sorted(cs[kind]["arms"].items(),
                              key=lambda kv: int(kv[0])):
            print(f"io_json,sweep={kind},budget_files={bf},"
                  + ",".join(f"{p}_hit={arm[p]:.3f}"
                             for p in cs["policies"]), flush=True)
    print("io_json,"
          + ",".join(f"zipf_gap_closure_{bf}={c:.2f}"
                     for bf, c in sorted(cs["zipf_gap_closure"].items(),
                                         key=lambda kv: int(kv[0])))
          + f",scan_lru_hit={cs['scan']['lru']:.3f}"
          f",scan_twoq_hit={cs['scan']['2q']:.3f}", flush=True)
    print(f"io_json,cross_epoch_stitched="
          f"{ce['stitched']['makespan_s']:.4f}s,"
          f"drain_refill={ce['drain_refill']['makespan_s']:.4f}s,"
          f"stall_speedup={ce['stall_speedup']:.3f},"
          f"windows={ce['stitched']['prefetch_windows']}v"
          f"{ce['drain_refill']['prefetch_windows']}", flush=True)
    print(f"io_json,workers={wb['workers']},nodes={wb['nodes']},"
          f"shared_hit={wb['shared']['cache_hit_rate']:.3f},"
          f"private_hit={wb['private']['cache_hit_rate']:.3f},"
          f"shared_tier_speedup={wb['shared_speedup']:.3f}", flush=True)
    print(f"io_json,measured_socket={m['socket']['elapsed_s']:.4f}s,"
          f"measured_shm={m['shm']['elapsed_s']:.4f}s,"
          f"shm_speedup={m['shm_speedup_vs_socket']:.2f}", flush=True)
    print(f"io_json,measured_prefetch_socket="
          f"{mp['socket']['elapsed_s']:.4f}s,"
          f"measured_prefetch_shm={mp['shm']['elapsed_s']:.4f}s,"
          f"prefetch_shm_speedup={mp['shm_speedup_vs_socket']:.2f}",
          flush=True)
    print(f"io_json,measured_ckpt_socket={mc['socket']['elapsed_s']:.4f}s,"
          f"measured_ckpt_shm={mc['shm']['elapsed_s']:.4f}s,"
          f"ckpt_shm_speedup={mc['shm_speedup_vs_socket']:.2f}", flush=True)
    print(f"io_json,wire_single={mw['single']['throughput_MBps']:.0f}MB/s,"
          f"wire_striped={mw['striped']['throughput_MBps']:.0f}MB/s,"
          f"wire_rdma={mw['rdma']['throughput_MBps']:.0f}MB/s,"
          f"stripe_speedup={mw['stripe_speedup']:.2f},"
          f"codec_saved={mw['codec']['forced_saved_bytes']}", flush=True)
    print(f"io_json,prefetch_depth_window={pd['window']},"
          f"batched={pd['batched_makespan_s']:.4f}s,"
          f"prefetched={pd['prefetched_makespan_s']:.4f}s,"
          f"deep_prefetch_speedup={pd['prefetch_speedup']:.3f}", flush=True)
    print(f"io_json,failover_kill_node={fo['kill_node']},"
          f"degraded_ratio={fo['degraded_ratio']:.3f},"
          f"reads_failed={fd['reads_failed']},"
          f"injected={fd['injected']},retries={fd['retries']},"
          f"healed_copies={fd['healed_copies']},"
          f"r1_lost={len(r1['lost_partitions'])}", flush=True)
    print(f"io_json,serving_tenants={sv['tenants']},"
          f"serving_nodes={sv['nodes']},"
          f"replication_speedup={sv['replication_speedup']:.2f},"
          f"promoted={len(rsv['promoted_partitions'])},"
          f"peak_inflight={rsv['peak_inflight_bytes']},"
          f"fairness_ratio={rsv['fairness_ratio']:.3f}", flush=True)
    print(f"io_json,wrote={path},metrics_jsonl={jsonl_path},"
          f"snapshot_version={snap['version']},"
          f"guards={len(IO_SLO_GUARDS)}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig3,scaling,apps,compression,"
                         "fetch,io-json")
    ap.add_argument("--skip", default=None)
    ap.add_argument("--io-json", default=None, metavar="PATH",
                    help="also write the BENCH_io.json perf snapshot here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny io-json variant for the CI fast lane")
    args = ap.parse_args()

    sections = {
        "fig3": lambda: __import__("benchmarks.io_single_node",
                                   fromlist=["main"]).main(),
        "scaling": lambda: __import__("benchmarks.io_scaling",
                                      fromlist=["main"]).main(),
        "apps": lambda: __import__("benchmarks.app_throughput",
                                   fromlist=["main"]).main(),
        "compression": lambda: __import__("benchmarks.compression",
                                          fromlist=["main"]).main(),
        "fig1": lambda: __import__("benchmarks.view_ablation",
                                   fromlist=["main"]).main(),
        "fetch": lambda: __import__("benchmarks.fetch_device",
                                    fromlist=["main"]).main(),
    }
    only = set(args.only.split(",")) if args.only else set(sections)
    skip = set(args.skip.split(",")) if args.skip else set()
    failures = 0
    for name, fn in sections.items():
        if name not in only or name in skip:
            continue
        t0 = time.perf_counter()
        try:
            for line in fn():
                print(line, flush=True)
            print(f"section={name},seconds={time.perf_counter()-t0:.1f}",
                  flush=True)
        except Exception:
            failures += 1
            print(f"section={name},FAILED", flush=True)
            traceback.print_exc()
    # io-json runs when named in --only (works inside a comma list) or when
    # an output path is given; --only io-json alone defaults the path
    if (args.io_json or "io-json" in only) and "io-json" not in skip:
        try:
            write_io_json(args.io_json or "BENCH_io.json", smoke=args.smoke)
        except Exception:
            failures += 1
            print("section=io-json,FAILED", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
