"""Benchmark aggregator — one section per paper table/figure.

  fig1    global vs partitioned dataset view (accuracy/loss gap)
  fig3    single-node bw/throughput: FanStore vs SSD vs FUSE vs SFS
  fig5/6  multi-node scaling (GPU-cluster and CPU-cluster arms)
  fig7-9  application throughput + weak scaling (ResNet/SRGAN/FRNN minis)
  fig10/11 + sec6.3  compression ratio / prep cost / relative throughput
  fetch   device-tier fetch collective bytes (uniform vs stratified)

Prints ``name,metric=value,...`` CSV-ish lines.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig3,scaling,apps,compression,fetch")
    ap.add_argument("--skip", default=None)
    args = ap.parse_args()

    sections = {
        "fig3": lambda: __import__("benchmarks.io_single_node",
                                   fromlist=["main"]).main(),
        "scaling": lambda: __import__("benchmarks.io_scaling",
                                      fromlist=["main"]).main(),
        "apps": lambda: __import__("benchmarks.app_throughput",
                                   fromlist=["main"]).main(),
        "compression": lambda: __import__("benchmarks.compression",
                                          fromlist=["main"]).main(),
        "fig1": lambda: __import__("benchmarks.view_ablation",
                                   fromlist=["main"]).main(),
        "fetch": lambda: __import__("benchmarks.fetch_device",
                                    fromlist=["main"]).main(),
    }
    only = set(args.only.split(",")) if args.only else set(sections)
    skip = set(args.skip.split(",")) if args.skip else set()
    failures = 0
    for name, fn in sections.items():
        if name not in only or name in skip:
            continue
        t0 = time.perf_counter()
        try:
            for line in fn():
                print(line, flush=True)
            print(f"section={name},seconds={time.perf_counter()-t0:.1f}",
                  flush=True)
        except Exception:
            failures += 1
            print(f"section={name},FAILED", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
