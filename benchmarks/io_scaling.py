"""Figs 5-6: multi-node aggregated bandwidth/throughput scaling.

Simulated cluster (interconnect model accounts per-node timelines; see
repro.fanstore.cluster). GPU-cluster arm: {1,4,8,16} nodes, FDR IB 56 Gb/s.
CPU-cluster arm: {1,64,128,256,512} nodes, OPA 100 Gb/s. Each node reads
every file once (the paper's benchmark), files striped once across nodes
(R=1), so the local hit rate falls as 1/N — exactly the regime Figs 5-6
measure. Reported: aggregated bandwidth, throughput, scaling efficiency vs
the paper's chosen baselines (4 nodes GPU / 64 nodes CPU).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.synthetic import fixed_size_files
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.prepare import prepare_dataset

FILE_SIZES = [128 * 1024, 512 * 1024, 2 * 1024 * 1024, 8 * 1024 * 1024]

GPU_NET = InterconnectModel(latency_s=1.0e-6, bandwidth_Bps=56e9 / 8,
                            disk_bw_Bps=2.0e9)
CPU_NET = InterconnectModel(latency_s=1.5e-6, bandwidth_Bps=100e9 / 8,
                            disk_bw_Bps=2.0e9)


def run_one(nodes: int, file_size: int, count: int,
            net: InterconnectModel, *, replication: int = 1,
            reads_per_node: int = 128) -> Dict:
    # one shared payload per size: content is timing-irrelevant here and
    # generating count x file_size of RNG bytes dominated the wall time
    import numpy as _np0
    payload = bytes(_np0.random.default_rng(1).integers(
        0, 256, file_size, dtype=_np0.uint8))
    files = {f"bench/f_{i:06d}.bin": payload for i in range(count)}
    blobs, _ = prepare_dataset(files, max(nodes, 8), compress=False)
    cluster = FanStoreCluster(nodes, interconnect=net)
    cluster.load_partitions(blobs, replication=replication)
    paths = sorted(files)
    cluster.reset_clocks()
    # each node reads a uniform sample of the directory: the per-node
    # timeline statistics match the paper's read-everything benchmark in
    # expectation while bounding the python-loop cost at 512 nodes
    import numpy as _np
    rng = _np.random.default_rng(nodes)
    m = min(reads_per_node, len(paths))
    for nid in range(nodes):
        for i in rng.choice(len(paths), size=m, replace=False):
            cluster.read(nid, paths[int(i)], materialize=False)
    bw = cluster.aggregate_bandwidth()
    t = cluster.makespan_s()
    return {"nodes": nodes, "file_size": file_size,
            "agg_MBps": bw / 1e6,
            "files_s": nodes * m / t,
            "hit_rate": cluster.local_hit_rate()}


def run(arm: str = "cpu", *, count: int = None) -> List[Dict]:
    if arm == "gpu":
        scales, net = [1, 4, 8, 16], GPU_NET
        count = count or 128
    else:
        scales, net = [1, 64, 128, 256, 512], CPU_NET
        # file count must exceed the node count or the benchmark measures
        # hot-owner serialization instead of scaling (paper uses 2K-128K)
        count = count or 1024
    rows = []
    for size in FILE_SIZES:
        for n in scales:
            # F >= 2N keeps the benchmark in the scaling (not hot-owner)
            # regime while bounding the python-loop cost at large N
            c = min(count, max(256, 2 * n))
            rows.append(run_one(n, size, c, net))
    # efficiency vs the paper's baselines
    base_n = 4 if arm == "gpu" else 64
    for size in FILE_SIZES:
        base = next(r for r in rows
                    if r["file_size"] == size and r["nodes"] == base_n)
        peak = next(r for r in rows
                    if r["file_size"] == size and r["nodes"] == scales[-1])
        peak["efficiency_vs_base"] = (
            peak["agg_MBps"] / peak["nodes"]) / (base["agg_MBps"] / base["nodes"])
    return rows


def main() -> List[str]:
    out = []
    for arm, fig in (("gpu", "fig5"), ("cpu", "fig6")):
        for r in run(arm):
            eff = r.get("efficiency_vs_base")
            out.append(
                f"{fig},arm={arm},nodes={r['nodes']},"
                f"size={r['file_size']//1024}KB,agg_bw={r['agg_MBps']:.0f}MB/s,"
                f"files_s={r['files_s']:.0f},hit={r['hit_rate']:.3f}"
                + (f",scale_eff={eff:.3f}" if eff else ""))
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
