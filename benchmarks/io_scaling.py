"""Figs 5-6: multi-node aggregated bandwidth/throughput scaling.

Simulated cluster (interconnect model accounts per-node timelines; see
repro.fanstore.cluster). GPU-cluster arm: {1,4,8,16} nodes, FDR IB 56 Gb/s.
CPU-cluster arm: {1,64,128,256,512} nodes, OPA 100 Gb/s. Each node reads
every file once (the paper's benchmark), files striped once across nodes
(R=1), so the local hit rate falls as 1/N — exactly the regime Figs 5-6
measure. Reported: aggregated bandwidth, throughput, scaling efficiency vs
the paper's chosen baselines (4 nodes GPU / 64 nodes CPU).

Beyond the paper, three engine axes::

    --batched      route reads through ``read_many`` so all requests for one
                   owner ride a single modeled round trip; reports makespan
                   for both paths and the speedup
    --prefetch     clairvoyant scheduling: the whole epoch trace is turned
                   into an EpochSchedule and driven through window-coalesced
                   async prefetch (one round trip per (requester, owner,
                   window)); demand reads hit the client cache and the
                   makespan models I/O overlapped with compute
    --cache-mb M   per-node client read cache of M MiB (2 epochs so the
                   second pass can hit), reporting cache hit rate
    --write        the write half: every node writes its outputs through
                   the batched ``write_many`` (one round trip per
                   (writer, owner) pair on the concurrent write lane) vs
                   the per-file ``write_file`` loop; reports the makespan
                   win per node count
    --workers K    K co-located workers per node reading overlapping
                   per-node sample sets: the SHARED node cache tier
                   (``cache_scope="node"``) vs private per-worker caches
                   of the same total bytes — reports hit rate and
                   makespan for both (the Hoard shared-tier claim)
    --backend B    run the SAME fixed trace over a real wire
                   (``socket``: framed TCP serving loops; ``shm``:
                   zero-copy co-located fast path) and report MEASURED
                   wall-clock makespans instead of modeled ones — the
                   repo's hardware-truth numbers. Small node counts only
                   (every node is a real serving loop on this host).

``bench_json`` packages the seed / batched / prefetched arms, the
write_many-vs-perfile arm, checkpoint-flush makespan with/without
prefetch-lane overlap, an LRU-vs-Belady hit-rate comparison, the
``workers`` block (shared tier vs private caches at K co-located
workers), and the ``measured`` block (socket vs shm on the read+write
trace PLUS measured prefetch and checkpoint-overlap arms, all
teardown-verified) as the machine-readable dict that
``benchmarks/run.py --io-json`` writes to BENCH_io.json.
"""
from __future__ import annotations

import argparse
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.data.synthetic import fixed_size_files
from repro.fanstore.api import CheckpointWriter, FanStoreSession
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.faults import NodeLostError
from repro.fanstore.prefetch import (EpochSchedule, PrefetchScheduler,
                                     SchedulerGroup)
from repro.fanstore.prepare import prepare_dataset
from repro.fanstore.spec import ClusterSpec

FILE_SIZES = [128 * 1024, 512 * 1024, 2 * 1024 * 1024, 8 * 1024 * 1024]

GPU_NET = InterconnectModel(latency_s=1.0e-6, bandwidth_Bps=56e9 / 8,
                            disk_bw_Bps=2.0e9)
CPU_NET = InterconnectModel(latency_s=1.5e-6, bandwidth_Bps=100e9 / 8,
                            disk_bw_Bps=2.0e9)

BATCH = 32      # samples per coalesced read_many call (one training step)


def _build_cluster(nodes: int, file_size: int, count: int,
                   net: InterconnectModel, *, replication: int,
                   cache_mb: int, cache_policy: str = "lru",
                   backend: str = "modeled", workers: int = 1,
                   cache_scope: str = "node",
                   cache_bytes: Optional[int] = None,
                   backend_options: Optional[Dict] = None,
                   compressible: bool = False) -> FanStoreCluster:
    # one shared payload per size: content is timing-irrelevant here and
    # generating count x file_size of RNG bytes dominated the wall time
    # (the wire-codec arm asks for compressible text instead)
    if compressible:
        payload = (b"FanStore benchmark payload row 0123456789 "
                   * (file_size // 42 + 1))[:file_size]
    else:
        payload = bytes(np.random.default_rng(1).integers(
            0, 256, file_size, dtype=np.uint8))
    files = {f"bench/f_{i:06d}.bin": payload for i in range(count)}
    blobs, _ = prepare_dataset(files, max(nodes, 8), compress=False)
    spec = ClusterSpec(num_nodes=nodes, workers_per_node=workers,
                       replication=replication,
                       cache_bytes=cache_bytes if cache_bytes is not None
                       else cache_mb * 1024 * 1024,
                       cache_scope=cache_scope,
                       cache_policy=cache_policy,
                       backend=backend,
                       backend_options=backend_options or {})
    cluster = FanStoreCluster.from_spec(spec, interconnect=net)
    cluster.load_partitions(blobs)
    return cluster


def run_one(nodes: int, file_size: int, count: int,
            net: InterconnectModel, *, replication: int = 1,
            reads_per_node: int = 128, batched: bool = False,
            prefetch: bool = False, window: int = 4,
            cache_mb: int = 0, cache_policy: str = "lru", epochs: int = 1,
            cluster: Optional[FanStoreCluster] = None) -> Dict:
    if prefetch and cache_mb == 0:
        # the scheduler stages through the client cache; budget one epoch of
        # per-node reads (size-only placeholders under materialize=False)
        m = min(reads_per_node, count)
        cache_mb = (m * file_size) // (1024 * 1024) + 1
    if cluster is None:
        cluster = _build_cluster(nodes, file_size, count, net,
                                 replication=replication, cache_mb=cache_mb,
                                 cache_policy=cache_policy)
    paths = sorted(f"bench/f_{i:06d}.bin" for i in range(count))
    cluster.reset_clocks()
    cluster.clear_caches()
    # each node reads a uniform sample of the directory: the per-node
    # timeline statistics match the paper's read-everything benchmark in
    # expectation while bounding the python-loop cost at 512 nodes
    rng = np.random.default_rng(nodes)
    m = min(reads_per_node, len(paths))
    reads = 0
    for _ in range(epochs):
        traces: Dict[int, List[List[str]]] = {}
        for nid in range(nodes):
            chosen = [paths[int(i)]
                      for i in rng.choice(len(paths), size=m, replace=False)]
            reads += len(chosen)
            traces[nid] = [chosen[s:s + BATCH]
                           for s in range(0, len(chosen), BATCH)]
        if prefetch:
            _drive_prefetched_epoch(cluster, traces, window=window)
        elif batched:
            for nid, steps in traces.items():
                for step_paths in steps:
                    cluster.read_many(nid, step_paths, materialize=False)
        else:
            for nid, steps in traces.items():
                for step_paths in steps:
                    for p in step_paths:
                        cluster.read(nid, p, materialize=False)
    bw = cluster.aggregate_bandwidth()
    t = cluster.makespan_s()
    return {"nodes": nodes, "file_size": file_size,
            "agg_MBps": bw / 1e6,
            "files_s": reads / t,
            "hit_rate": cluster.local_hit_rate(),
            "cache_hit_rate": cluster.cache_hit_rate(),
            "cache_mb": cache_mb,
            "makespan_s": t,
            "bytes_moved": sum(c.bytes_in + c.prefetch_bytes + c.local_bytes
                               for c in cluster.clocks.values()),
            "prefetch_windows": cluster.accounting.prefetch_windows(),
            "batched": batched,
            "prefetch": prefetch}


def _drive_prefetched_epoch(cluster: FanStoreCluster,
                            traces: Dict[int, List[List[str]]], *,
                            window: int) -> None:
    """One epoch with clairvoyant scheduling: windows of `window` steps ride
    ahead of the demand reads, which then hit the client cache.

    The modeled clocks are order-independent (prefetch accrues on its own
    lane), so gating each step on its own window (``wait_ready``) gives
    deterministic cache hits without changing the accounted makespan.
    """
    schedule = EpochSchedule.from_trace(traces, cluster)
    schedulers = {
        nid: PrefetchScheduler(cluster, schedule, nid, window_steps=window,
                               materialize=False)
        for nid in traces}
    num_steps = max((len(s) for s in traces.values()), default=0)
    for step in range(num_steps):
        for nid, pf in schedulers.items():
            pf.ensure(step + window)
            pf.wait_ready(step)
            steps = traces[nid]
            if step < len(steps):
                cluster.read_many(nid, steps[step], materialize=False)
    for pf in schedulers.values():
        pf.close()


def run_measured_one(backend: str, *, nodes: int = 4,
                     file_size: int = 256 * 1024, count: int = 64,
                     reads_per_node: int = 64, write_files: int = 8,
                     write_size: int = 64 * 1024,
                     repeats: int = 3) -> Dict:
    """One REAL-wire arm: drive a fixed read+write trace over ``backend``
    (``socket`` or ``shm``) and report measured wall-clock numbers.

    Unlike every other arm in this file, nothing here is modeled: bytes
    actually cross the backend (TCP frames, or zero-copy views), and the
    reported makespans come from the ``WallClock`` ledgers the backend
    accrued plus the end-to-end loop time. ``repeats`` runs the whole
    trace fresh several times and keeps the fastest (standard
    best-of-N for wall timing). Teardown is verified: a leaked
    ``fanstore-*`` thread fails the benchmark rather than hanging CI.
    """
    already = {t for t in threading.enumerate()
               if t.name.startswith("fanstore")}
    best: Optional[Dict] = None
    for _ in range(repeats):
        with _build_cluster(nodes, file_size, count, CPU_NET, replication=1,
                            cache_mb=0, backend=backend) as cluster:
            paths = sorted(f"bench/f_{i:06d}.bin" for i in range(count))
            rng = np.random.default_rng(7)
            traces = {
                nid: [paths[int(i)] for i in rng.choice(
                    len(paths), size=min(reads_per_node, count),
                    replace=False)]
                for nid in range(nodes)}
            # wire-up cost stays outside the clock: bring the serving
            # loops up AND dial every (requester, owner) connection with
            # one warm-up read per pair before timing starts — otherwise
            # the socket arm pays its TCP handshakes inside the window
            # while the shm arm pays nothing
            warm = [ns.local_paths()[0] for ns in cluster.nodes.values()
                    if ns.local_paths()]
            for nid in range(nodes):
                cluster.read_many(nid, warm)
            cluster.reset_clocks()
            t0 = time.perf_counter()
            read_bytes = 0
            for nid, chosen in traces.items():
                for s in range(0, len(chosen), BATCH):
                    for data in cluster.read_many(nid, chosen[s:s + BATCH]):
                        read_bytes += len(data)
            payload = bytes(write_size)
            for nid in range(nodes):
                cluster.write_many(nid, [
                    (f"out/n{nid:03d}/f{i:04d}.bin", payload)
                    for i in range(write_files)])
            moved = read_bytes + nodes * write_files * write_size
            elapsed = time.perf_counter() - t0
            # the measured ledgers come through the observability plane:
            # one consistent accounting snapshot via cluster.metrics
            agg = cluster.metrics.snapshot()["cluster"]
            row = {"backend": backend, "nodes": nodes,
                   "file_size": file_size, "count": count,
                   "reads_per_node": min(reads_per_node, count),
                   "elapsed_s": elapsed,
                   "measured_makespan_s": agg["measured_makespan_s"],
                   "measured_total_s": agg["measured_total_s"],
                   "measured_bytes": agg["measured_bytes"],
                   "measured_requests": agg["measured_requests"],
                   "read_bytes": read_bytes,
                   "bytes_moved": moved,
                   "throughput_MBps": moved / elapsed / 1e6
                   if elapsed else 0.0,
                   "modeled_makespan_s": agg["makespan_s"]}
        if best is None or row["elapsed_s"] < best["elapsed_s"]:
            best = row
    # only threads THIS function spawned count — a modeled arm elsewhere in
    # the process may hold a lazily-built pool whose workers die with it
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("fanstore") and t.is_alive()
              and t not in already]
    if leaked:
        raise RuntimeError(f"serving-loop teardown leaked threads: {leaked}")
    best["teardown_clean"] = True
    return best


def measured_comparison(*, smoke: bool = False) -> Dict:
    """Socket vs shared-memory on the SAME trace: the co-located zero-copy
    path must beat the framed-TCP path on real wall clocks (the Hoard
    node-local-tier claim, measured instead of modeled)."""
    kw = dict(nodes=4, count=32 if smoke else 64,
              file_size=(128 if smoke else 256) * 1024,
              reads_per_node=32 if smoke else 64,
              write_files=4 if smoke else 8)
    sock = run_measured_one("socket", **kw)
    shm = run_measured_one("shm", **kw)
    return {"config": kw, "socket": sock, "shm": shm,
            "shm_speedup_vs_socket": (
                sock["elapsed_s"] / shm["elapsed_s"]
                if shm["elapsed_s"] else 1.0),
            "teardown_clean": sock["teardown_clean"]
            and shm["teardown_clean"]}


def format_measured_rows(rows: List[Dict]) -> List[str]:
    return [(f"measured,backend={r['backend']},nodes={r['nodes']},"
             f"size={r['file_size']//1024}KB,"
             f"elapsed={r['elapsed_s']:.4f}s,"
             f"measured_makespan={r['measured_makespan_s']:.4f}s,"
             f"throughput={r['throughput_MBps']:.0f}MB/s,"
             f"requests={r['measured_requests']}") for r in rows]


# ---- the wire itself: striped/pipelined socket vs its single-conn self ------
def run_wire_arm(backend: str, *, backend_options: Optional[Dict] = None,
                 file_size: int = 1024 * 1024, count: int = 64,
                 passes: int = 3, repeats: int = 3,
                 compressible: bool = False) -> Dict:
    """Pure wire throughput: node 0 reads every REMOTE path (owned by the
    peer node) in one coalesced batch per pass — no local reads, no cache,
    so elapsed time is the transport data plane and nothing else. Reports
    MB/s plus the per-stripe and wire-codec ledgers."""
    already = {t for t in threading.enumerate()
               if t.name.startswith("fanstore")}
    best: Optional[Dict] = None
    for _ in range(repeats):
        with _build_cluster(2, file_size, count, CPU_NET, replication=1,
                            cache_mb=0, backend=backend,
                            backend_options=backend_options,
                            compressible=compressible) as cluster:
            # replication=1, 2 nodes: node 1's partition is exactly the
            # set node 0 must pull over the wire
            remote = sorted(cluster.nodes[1].local_paths())
            cluster.read_many(0, remote[:2])       # warm dials + pins
            cluster.reset_clocks()
            t0 = time.perf_counter()
            moved = 0
            for _ in range(passes):
                for data in cluster.read_many(0, remote):
                    moved += len(data)
            elapsed = time.perf_counter() - t0
            # stripe / codec / serve ledgers via the observability plane
            snap = cluster.metrics.snapshot()
            agg = snap["cluster"]
            row = {"backend": backend,
                   "options": dict(backend_options or {}),
                   "file_size": file_size, "count": count,
                   "passes": passes, "bytes_moved": moved,
                   "elapsed_s": elapsed,
                   "throughput_MBps": moved / elapsed / 1e6
                   if elapsed else 0.0,
                   "stripes_used": sorted(agg["stripe_bytes"]),
                   "wire_saved_bytes": agg["wire_saved_bytes"],
                   "serve_ns": sum(n["measured"]["serve_ns"]
                                   for n in snap["nodes"].values())}
        if best is None or row["elapsed_s"] < best["elapsed_s"]:
            best = row
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("fanstore") and t.is_alive()
              and t not in already]
    if leaked:
        raise RuntimeError(f"wire arm leaked threads: {leaked}")
    best["teardown_clean"] = True
    return best


def measured_wire_comparison(*, smoke: bool = False) -> Dict:
    """The tentpole's headline block (``measured.wire`` in BENCH_io.json):

    * ``single``  — one connection, no pipelining: the PR-4 wire.
    * ``striped`` — the full data plane (8 stripes, pipelined frames,
      vectored I/O); the guarded claim is striped >> single on the SAME
      trace and host.
    * ``rdma``    — the one-sided backend on the same trace; its serve
      ledger must be exactly zero (no owner CPU on the data path).
    * ``codec``   — LZSS-on-the-wire engages ONLY when the cost model
      predicts a win: a forced-slow modeled wire on compressible payloads
      must save bytes; the honest default policy on the same trace must
      ship everything raw.
    """
    kw = dict(file_size=(256 if smoke else 1024) * 1024,
              count=32 if smoke else 64,
              passes=2 if smoke else 3, repeats=3)
    single = run_wire_arm("socket", backend_options={
        "stripes": 1, "pipeline_depth": 1}, **kw)
    striped = run_wire_arm("socket", backend_options={
        "stripes": 8, "pipeline_depth": 4}, **kw)
    rdma = run_wire_arm("rdma", **kw)
    # codec arms ride a tiny compressible trace: the pure-Python LZSS is
    # ~40 MB/s, so the engagement proof must not dominate the bench
    ckw = dict(file_size=64 * 1024, count=16, passes=1, repeats=1,
               compressible=True)
    forced = run_wire_arm("socket", backend_options={
        "wire_codec": "lzss",
        "wire_policy": {"wire_Bps": 1e6, "compress_Bps": 1e12,
                        "decompress_Bps": 1e12, "min_bytes": 1}}, **ckw)
    honest = run_wire_arm("socket", backend_options={
        "wire_codec": "lzss"}, **ckw)
    return {"config": kw,
            # stripe legs run on threads: with one core they serialize
            # and the speedup honestly reads ~1.0 or below — run.py's
            # stripe guard is conditioned on this
            "cpu_count": os.cpu_count() or 1,
            "single": single, "striped": striped, "rdma": rdma,
            "stripe_speedup": (striped["throughput_MBps"]
                               / single["throughput_MBps"]
                               if single["throughput_MBps"] else 1.0),
            "codec": {
                "forced_saved_bytes": forced["wire_saved_bytes"],
                "honest_saved_bytes": honest["wire_saved_bytes"],
                "engages_when_predicted": forced["wire_saved_bytes"] > 0,
                "raw_when_not_predicted": honest["wire_saved_bytes"] == 0},
            "teardown_clean": single["teardown_clean"]
            and striped["teardown_clean"] and rdma["teardown_clean"]}


# ---- prefetch with room to breathe ------------------------------------------
#: a WAN-ish/parallel-FS-ish fabric: per-message latency dominates, so
#: amortizing round trips across a deep lookahead window is the whole game
#: (the regime the thin ~1-2% smoke-arm prefetch wins never showed)
SLOW_NET = InterconnectModel(latency_s=200e-6, bandwidth_Bps=10e9 / 8,
                             disk_bw_Bps=2.0e9)


def prefetch_depth_comparison(*, smoke: bool = False,
                              window: int = 16) -> Dict:
    """The config where scheduled prefetch shows its SHAPE: a slow,
    latency-bound interconnect and a deep lookahead window. Batched
    demand reads pay one round trip per (step, owner) on the consume
    timeline; the scheduler amortizes the same latency across
    ``window``-step windows AND moves the cost to the overlapped prefetch
    lane — the ratio here is the guarded prefetch win (replacing the thin
    ~1-2% wins of the fast-fabric smoke arms, which this file keeps only
    as direction checks)."""
    nodes = 8
    # small files keep the arm latency-bound (the shape under test):
    # at 64 KiB a transfer is ~50 us against a 200 us round trip, so the
    # win IS the round trips the window amortizes; big files would bury
    # it under bandwidth and serve time common to both arms
    kw = dict(file_size=64 * 1024,
              count=max(128, 2 * nodes), net=SLOW_NET,
              reads_per_node=96 if smoke else 192)
    batched = run_one(nodes, batched=True, **kw)
    prefetched = run_one(nodes, prefetch=True, window=window,
                         cache_policy="belady", **kw)
    return {"nodes": nodes, "window": window,
            "net": {"latency_s": SLOW_NET.latency_s,
                    "bandwidth_Bps": SLOW_NET.bandwidth_Bps},
            "batched_makespan_s": batched["makespan_s"],
            "prefetched_makespan_s": prefetched["makespan_s"],
            "prefetch_windows": prefetched["prefetch_windows"],
            "prefetch_speedup": (batched["makespan_s"]
                                 / prefetched["makespan_s"]
                                 if prefetched["makespan_s"] else 1.0)}


def run_workers_one(nodes: int, workers: int, file_size: int, count: int,
                    net: InterconnectModel, *, shared: bool = True,
                    reads_per_worker: int = 64, epochs: int = 2,
                    cache_policy: str = "lru") -> Dict:
    """K co-located workers per node, each demand-reading its own
    permutation of the node's sample pool through its own session —
    the multi-tenant regime the paper actually runs (§3).

    ``shared=True`` gives every node ONE cache tier its workers share
    (``cache_scope="node"``); ``shared=False`` splits the SAME total
    byte budget into private per-worker caches (``cache_scope="worker"``)
    — the like-for-like baseline. With overlapping worker traces the
    shared tier both dedupes payloads (worker A's fetch is worker B's
    RAM hit) and pools the budget, so its hit rate is strictly higher
    and the modeled makespan strictly lower (pinned in tests and by the
    io-json guards). All quantities are deterministic modeled clocks.
    """
    pool_size = min(reads_per_worker, count)
    # budget one node pool in TOTAL: the shared tier holds the whole pool,
    # each private cache holds pool/workers — same total bytes
    budget = pool_size * file_size + file_size
    cluster = _build_cluster(nodes, file_size, count, net, replication=1,
                             cache_mb=0, cache_bytes=budget,
                             cache_policy=cache_policy, workers=workers,
                             cache_scope="node" if shared else "worker")
    paths = sorted(f"bench/f_{i:06d}.bin" for i in range(count))
    cluster.reset_clocks()
    # per-node pool; each worker walks its own per-epoch permutation of it
    # (co-located data-parallel workers sampling one node-assigned shard)
    pools = {n: [paths[int(i)] for i in np.random.default_rng(n).choice(
        len(paths), size=pool_size, replace=False)] for n in range(nodes)}
    reads = 0
    for ep in range(epochs):
        traces: Dict = {}
        for n in range(nodes):
            for w in range(workers):
                rng = np.random.default_rng((n, w, ep))
                chosen = [pools[n][int(i)]
                          for i in rng.permutation(pool_size)]
                reads += len(chosen)
                traces[(n, w)] = [chosen[s:s + BATCH]
                                  for s in range(0, len(chosen), BATCH)]
        num_steps = max(len(s) for s in traces.values())
        for step in range(num_steps):     # workers interleave per step
            for (n, w), steps in traces.items():
                if step < len(steps):
                    cluster.read_many(n, steps[step], worker_id=w,
                                      materialize=False)
    # attribution must tie out three ways: per-worker sums == tier totals
    # (cache truth) == NodeClock totals (timeline mirror)
    attribution_ok = True
    per_worker_hits: Dict[str, int] = {}
    for n, tier in cluster.cache_tiers.items():
        tsum = sum(s.hits for s in tier.worker_stats.values())
        msum = sum(s.misses for s in tier.worker_stats.values())
        clock = cluster.clocks[n]
        attribution_ok &= (tsum == tier.stats.hits == clock.cache_hits)
        attribution_ok &= (msum == tier.stats.misses == clock.cache_misses)
        attribution_ok &= (
            sum(clock.worker_cache_hits.values()) == clock.cache_hits)
        for w, s in tier.worker_stats.items():
            per_worker_hits[f"n{n}w{w}"] = s.hits
    return {"nodes": nodes, "workers": workers,
            "cache_scope": "node" if shared else "worker",
            "file_size": file_size, "reads": reads,
            "budget_bytes": budget,
            "makespan_s": cluster.makespan_s(),
            "cache_hit_rate": cluster.cache_hit_rate(),
            "local_hit_rate": cluster.local_hit_rate(),
            "bytes_moved": sum(c.bytes_in + c.local_bytes
                               for c in cluster.clocks.values()),
            "attribution_ok": attribution_ok,
            "per_worker_hits": per_worker_hits}


def workers_comparison(*, nodes: int = 8, workers: int = 2,
                       smoke: bool = False) -> Dict:
    """Shared node tier vs private per-worker caches on the SAME traces
    and the SAME total byte budget — the ``workers`` block of
    BENCH_io.json (guarded: shared strictly beats private on both hit
    rate and makespan)."""
    kw = dict(file_size=(64 if smoke else 256) * 1024,
              count=max(128, 2 * nodes), net=CPU_NET,
              reads_per_worker=32 if smoke else 64, epochs=2)
    shared = run_workers_one(nodes, workers, shared=True, **kw)
    private = run_workers_one(nodes, workers, shared=False, **kw)
    return {"nodes": nodes, "workers": workers,
            "config": {k: v for k, v in kw.items() if k != "net"},
            "shared": shared, "private": private,
            "shared_speedup": (private["makespan_s"] / shared["makespan_s"]
                               if shared["makespan_s"] else 1.0),
            "hit_rate_gain": (shared["cache_hit_rate"]
                              - private["cache_hit_rate"])}


def format_workers_rows(rows: List[Dict]) -> List[str]:
    return [(f"workers,nodes={r['nodes']},workers={r['workers']},"
             f"scope={r['cache_scope']},"
             f"makespan={r['makespan_s']:.6f}s,"
             f"cache_hit={r['cache_hit_rate']:.3f},"
             f"attribution_ok={r['attribution_ok']}") for r in rows]


def run_measured_prefetch(backend: str, *, nodes: int = 4,
                          file_size: int = 128 * 1024, count: int = 64,
                          reads_per_node: int = 48, window: int = 4,
                          repeats: int = 2) -> Dict:
    """MEASURED (wall-clock) arm for the prefetch benchmark: drive a
    clairvoyant schedule over a real wire (``socket``/``shm``) with
    ``materialize=True`` so every window's bytes actually cross the
    backend, then demand-read the same trace out of the client cache.

    Mirrors :func:`run_measured_one`'s guarantees: nonzero measured time
    on the PREFETCH lane specifically, a byte ledger that ties out
    (wall-clock ``bytes_in`` == the schedulers' staged bytes — traces
    are sampled without replacement so nothing is skipped as already
    cached), and verified serving-loop teardown.
    """
    already = {t for t in threading.enumerate()
               if t.name.startswith("fanstore")}
    best: Optional[Dict] = None
    for _ in range(repeats):
        budget = min(reads_per_node, count) * file_size + file_size
        with _build_cluster(nodes, file_size, count, CPU_NET, replication=1,
                            cache_mb=0, cache_bytes=budget,
                            backend=backend) as cluster:
            paths = sorted(f"bench/f_{i:06d}.bin" for i in range(count))
            rng = np.random.default_rng(11)
            traces = {
                nid: [[paths[int(i)] for i in rng.choice(
                    len(paths), size=min(reads_per_node, count),
                    replace=False)][s:s + BATCH]
                    for s in range(0, min(reads_per_node, count), BATCH)]
                for nid in range(nodes)}
            # dial every (requester, owner) connection outside the timed
            # window, then drop the warm-up's cache/clock footprint
            warm = [ns.local_paths()[0] for ns in cluster.nodes.values()
                    if ns.local_paths()]
            for nid in range(nodes):
                cluster.read_many(nid, warm)
            cluster.clear_caches()
            cluster.reset_clocks()
            schedule = EpochSchedule.from_trace(traces, cluster)
            group = SchedulerGroup.for_schedule(cluster, schedule,
                                                window_steps=window)
            t0 = time.perf_counter()
            num_steps = max(len(s) for s in traces.values())
            for step in range(num_steps):
                group.ensure(step + window)
                group.wait_ready(step)
                for nid, steps in traces.items():
                    if step < len(steps):
                        cluster.read_many(nid, steps[step])
            group.close()
            elapsed = time.perf_counter() - t0
            # lane ledgers via the observability plane's consistent copy
            snap = cluster.metrics.snapshot()
            agg = snap["cluster"]
            per_node = snap["nodes"].values()
            row = {"backend": backend, "nodes": nodes,
                   "file_size": file_size,
                   "elapsed_s": elapsed,
                   "measured_prefetch_s": sum(
                       n["measured"]["prefetch_ns"]
                       for n in per_node) / 1e9,
                   "measured_makespan_s": agg["measured_makespan_s"],
                   "measured_bytes": agg["measured_bytes"],
                   "staged_bytes": group.bytes_scheduled,
                   "cache_hits": sum(n["modeled"]["cache_hits"]
                                     for n in per_node),
                   "cache_hit_rate": agg["cache_hit_rate"],
                   "windows": group.windows_issued}
        if best is None or row["elapsed_s"] < best["elapsed_s"]:
            best = row
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("fanstore") and t.is_alive()
              and t not in already]
    if leaked:
        raise RuntimeError(f"prefetch arm leaked threads: {leaked}")
    best["teardown_clean"] = True
    return best


def measured_prefetch_comparison(*, smoke: bool = False) -> Dict:
    """Socket vs shared-memory on the SAME scheduled trace, measured.
    The speedup compares the PREFETCH-LANE wall time (the wire leg of
    the scheduled windows, summed across nodes) — end-to-end elapsed is
    reported too, but it is diluted by identical Python drive overhead
    on both arms and would make a flaky guard."""
    kw = dict(nodes=4, count=32 if smoke else 64,
              file_size=(64 if smoke else 128) * 1024,
              reads_per_node=32 if smoke else 48)
    sock = run_measured_prefetch("socket", **kw)
    shm = run_measured_prefetch("shm", **kw)
    return {"config": kw, "socket": sock, "shm": shm,
            "shm_speedup_vs_socket": (
                sock["measured_prefetch_s"] / shm["measured_prefetch_s"]
                if shm["measured_prefetch_s"] else 1.0),
            "teardown_clean": sock["teardown_clean"]
            and shm["teardown_clean"]}


def run_measured_ckpt(backend: str, *, nodes: int = 2,
                      file_size: int = 64 * 1024, count: int = 32,
                      reads_per_node: int = 32, window: int = 4,
                      shard_bytes: int = 1 << 20,
                      chunk_bytes: int = 256 * 1024,
                      repeats: int = 2) -> Dict:
    """MEASURED arm for the checkpoint-overlap benchmark: every node's
    session streams a checkpoint shard in fsync'd chunks WHILE its
    prefetch windows are in flight, over a real wire. The wall ledgers
    must show BOTH concurrent lanes nonzero (prefetch AND write — the
    measured counterpart of the modeled overlap claim), and teardown is
    verified exactly like the other measured arms."""
    already = {t for t in threading.enumerate()
               if t.name.startswith("fanstore")}
    best: Optional[Dict] = None
    for _ in range(repeats):
        budget = min(reads_per_node, count) * file_size + file_size
        with _build_cluster(nodes, file_size, count, CPU_NET, replication=1,
                            cache_mb=0, cache_bytes=budget,
                            backend=backend) as cluster:
            paths = sorted(f"bench/f_{i:06d}.bin" for i in range(count))
            rng = np.random.default_rng(13)
            traces = {
                nid: [[paths[int(i)] for i in rng.choice(
                    len(paths), size=min(reads_per_node, count),
                    replace=False)][s:s + BATCH]
                    for s in range(0, min(reads_per_node, count), BATCH)]
                for nid in range(nodes)}
            warm = [ns.local_paths()[0] for ns in cluster.nodes.values()
                    if ns.local_paths()]
            for nid in range(nodes):
                cluster.read_many(nid, warm)
            cluster.clear_caches()
            cluster.reset_clocks()
            schedule = EpochSchedule.from_trace(traces, cluster)
            group = SchedulerGroup.for_schedule(cluster, schedule,
                                                window_steps=window)
            payload = bytes(shard_bytes)
            t0 = time.perf_counter()
            group.ensure(max(len(s) for s in traces.values()) + window)
            # shards ship while the windows above are still in flight:
            # both scheduled lanes are live in the same wall window
            for nid in range(nodes):
                writer = CheckpointWriter(cluster.connect(nid),
                                          chunk_bytes=chunk_bytes)
                writer.write_shard(f"ckpt/n{nid:03d}/shard.bin", payload)
            group.close()
            elapsed = time.perf_counter() - t0
            # both concurrent lanes read from one consistent snapshot
            snap = cluster.metrics.snapshot()
            per_node = snap["nodes"].values()
            row = {"backend": backend, "nodes": nodes,
                   "shard_bytes": shard_bytes,
                   "elapsed_s": elapsed,
                   "measured_prefetch_s": sum(
                       n["measured"]["prefetch_ns"]
                       for n in per_node) / 1e9,
                   "measured_write_s": sum(
                       n["measured"]["write_ns"]
                       for n in per_node) / 1e9,
                   "measured_makespan_s":
                       snap["cluster"]["measured_makespan_s"],
                   "measured_requests":
                       snap["cluster"]["measured_requests"]}
        if best is None or row["elapsed_s"] < best["elapsed_s"]:
            best = row
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("fanstore") and t.is_alive()
              and t not in already]
    if leaked:
        raise RuntimeError(f"checkpoint arm leaked threads: {leaked}")
    best["teardown_clean"] = True
    return best


def measured_ckpt_comparison(*, smoke: bool = False) -> Dict:
    """Socket vs shared-memory checkpoint-overlap, measured. As with the
    prefetch arm, the guard-backing speedup compares the two concurrent
    SCHEDULED lanes' wall time (prefetch + write, the actual wire legs)
    rather than elapsed."""
    kw = dict(nodes=2, count=16 if smoke else 32,
              file_size=(32 if smoke else 64) * 1024,
              reads_per_node=16 if smoke else 32,
              shard_bytes=(1 << 19) if smoke else (1 << 20))
    sock = run_measured_ckpt("socket", **kw)
    shm = run_measured_ckpt("shm", **kw)

    def lanes(r: Dict) -> float:
        return r["measured_prefetch_s"] + r["measured_write_s"]

    return {"config": kw, "socket": sock, "shm": shm,
            "shm_speedup_vs_socket": (lanes(sock) / lanes(shm)
                                      if lanes(shm) else 1.0),
            "teardown_clean": sock["teardown_clean"]
            and shm["teardown_clean"]}


def run_write_one(nodes: int, file_size: int, files_per_node: int,
                  net: InterconnectModel, *, batched: bool = True) -> Dict:
    """Every node writes its own output files. ``batched=True`` drives the
    engine's ``write_many`` (one round trip per (writer, owner) pair, the
    concurrent write lane); ``batched=False`` is the per-file
    ``write_file`` loop (one round trip per file on the serialized demand
    lane) — the seed's synchronous writer."""
    cluster = FanStoreCluster(nodes, interconnect=net)
    payload = bytes(file_size)      # shared object: single-chunk writes are
    cluster.reset_clocks()          # zero-copy, so 512 nodes stay cheap
    files = 0
    for nid in range(nodes):
        entries = [(f"out/n{nid:03d}/f{i:05d}.bin", payload)
                   for i in range(files_per_node)]
        if batched:
            cluster.write_many(nid, entries)
        else:
            for p, d in entries:
                cluster.write_file(nid, p, d)
        files += len(entries)
    return {"nodes": nodes, "file_size": file_size, "files": files,
            "makespan_s": cluster.makespan_s(),
            "write_bytes": cluster.accounting.write_bytes(),
            "write_rpcs": cluster.accounting.write_rpcs(),
            "batched": batched}


def run_checkpoint_overlap(nodes: int, file_size: int, count: int,
                           net: InterconnectModel, *,
                           reads_per_node: int = 64, window: int = 4,
                           shard_bytes: int = 4 * 1024 * 1024,
                           chunk_bytes: int = 1 * 1024 * 1024) -> Dict:
    """Checkpoint flush DURING an active prefetch window vs serialized
    write-then-prefetch.

    Overlapped: one run where every node drives a prefetched epoch while a
    ``CheckpointWriter`` streams one shard in fsync'd chunks on the
    concurrent write lane — per-node makespan is
    ``max(consume, serve, prefetch, write)``. Serialized: the same two
    workloads accrued in isolation, summed — what a writer that parks the
    data plane would pay. The modeled clocks are order-independent, so
    both are exact, deterministic quantities.
    """
    def build():
        cache_mb = (min(reads_per_node, count) * file_size) // (1 << 20) + 1
        cluster = _build_cluster(nodes, file_size, count, net, replication=1,
                                 cache_mb=cache_mb, cache_policy="belady")
        rng = np.random.default_rng(nodes)
        paths = sorted(f"bench/f_{i:06d}.bin" for i in range(count))
        m = min(reads_per_node, len(paths))
        traces = {}
        for nid in range(nodes):
            chosen = [paths[int(i)]
                      for i in rng.choice(len(paths), size=m, replace=False)]
            traces[nid] = [chosen[s:s + BATCH]
                           for s in range(0, len(chosen), BATCH)]
        return cluster, traces

    def write_shards(cluster):
        payload = bytes(shard_bytes)
        for nid in range(cluster.num_nodes):
            writer = FanStoreSession(cluster, nid).checkpoint_writer(
                chunk_bytes=chunk_bytes)
            writer.write_shard(f"ckpt/step_0/shard_{nid:03d}.npy", payload)

    # overlapped: both workloads on one set of clocks, concurrent lanes
    cluster, traces = build()
    cluster.reset_clocks()
    _drive_prefetched_epoch(cluster, traces, window=window)
    write_shards(cluster)
    overlapped = cluster.makespan_s()
    # serialized: prefetch epoch alone + write alone, summed
    cluster, traces = build()
    cluster.reset_clocks()
    _drive_prefetched_epoch(cluster, traces, window=window)
    prefetch_only = cluster.makespan_s()
    cluster2, _ = build()
    cluster2.reset_clocks()
    write_shards(cluster2)
    write_only = cluster2.makespan_s()
    serialized = prefetch_only + write_only
    return {"nodes": nodes, "shard_bytes": shard_bytes,
            "overlapped_makespan_s": overlapped,
            "serialized_makespan_s": serialized,
            "prefetch_makespan_s": prefetch_only,
            "write_makespan_s": write_only,
            "overlap_speedup": serialized / overlapped if overlapped else 1.0}


def run_write(arm: str = "cpu", *, files_per_node: int = 32,
              file_size: int = 64 * 1024) -> List[Dict]:
    scales, net = ([1, 4, 8, 16], GPU_NET) if arm == "gpu" else \
        ([1, 64, 128, 256, 512], CPU_NET)
    rows = []
    for n in scales:
        batched = run_write_one(n, file_size, files_per_node, net,
                                batched=True)
        perfile = run_write_one(n, file_size, files_per_node, net,
                                batched=False)
        batched["makespan_perfile_s"] = perfile["makespan_s"]
        batched["write_speedup"] = (
            perfile["makespan_s"] / batched["makespan_s"]
            if batched["makespan_s"] > 0 else 1.0)
        rows.append(batched)
    return rows


def format_write_rows(arm: str, rows: List[Dict]) -> List[str]:
    return [(f"write,arm={arm},nodes={r['nodes']},"
             f"size={r['file_size']//1024}KB,files={r['files']},"
             f"makespan_write_many={r['makespan_s']:.6f}s,"
             f"makespan_perfile={r['makespan_perfile_s']:.6f}s,"
             f"write_speedup={r['write_speedup']:.3f},"
             f"write_rpcs={r['write_rpcs']}") for r in rows]


def run(arm: str = "cpu", *, count: int = None, batched: bool = False,
        prefetch: bool = False, window: int = 4,
        cache_mb: int = 0, epochs: int = 1) -> List[Dict]:
    if arm == "gpu":
        scales, net = [1, 4, 8, 16], GPU_NET
        count = count or 128
    else:
        scales, net = [1, 64, 128, 256, 512], CPU_NET
        # file count must exceed the node count or the benchmark measures
        # hot-owner serialization instead of scaling (paper uses 2K-128K)
        count = count or 1024
    rows = []
    for size in FILE_SIZES:
        for n in scales:
            # F >= 2N keeps the benchmark in the scaling (not hot-owner)
            # regime while bounding the python-loop cost at large N
            c = min(count, max(256, 2 * n))
            # the prefetch arm needs its own cluster (Belady cache enabled);
            # every other arm shares one baseline build so the dataset is
            # packed once per (size, n), as before — clocks + caches are
            # reset between runs
            baseline = None
            if not prefetch:
                baseline = _build_cluster(n, size, c, net, replication=1,
                                          cache_mb=cache_mb)
            row = run_one(n, size, c, net, batched=batched,
                          prefetch=prefetch, window=window,
                          cache_mb=cache_mb,
                          cache_policy="belady" if prefetch else "lru",
                          epochs=epochs,
                          cluster=None if prefetch else baseline)
            if batched or prefetch:
                if baseline is None:
                    baseline = _build_cluster(n, size, c, net, replication=1,
                                              cache_mb=cache_mb)
                base = run_one(n, size, c, net, batched=False,
                               cache_mb=cache_mb, epochs=epochs,
                               cluster=baseline)
                row["makespan_perfile_s"] = base["makespan_s"]
                row["batched_speedup"] = (
                    base["makespan_s"] / row["makespan_s"]
                    if row["makespan_s"] > 0 else 1.0)
                if prefetch:
                    batch_arm = run_one(n, size, c, net, batched=True,
                                        cache_mb=cache_mb, epochs=epochs,
                                        cluster=baseline)
                    row["makespan_batched_s"] = batch_arm["makespan_s"]
                    row["prefetch_speedup"] = (
                        batch_arm["makespan_s"] / row["makespan_s"]
                        if row["makespan_s"] > 0 else 1.0)
            rows.append(row)
    # efficiency vs the paper's baselines
    base_n = 4 if arm == "gpu" else 64
    for size in FILE_SIZES:
        base = next(r for r in rows
                    if r["file_size"] == size and r["nodes"] == base_n)
        peak = next(r for r in rows
                    if r["file_size"] == size and r["nodes"] == scales[-1])
        peak["efficiency_vs_base"] = (
            peak["agg_MBps"] / peak["nodes"]) / (base["agg_MBps"] / base["nodes"])
    return rows


def format_rows(arm: str, fig: str, rows: List[Dict]) -> List[str]:
    out = []
    for r in rows:
        eff = r.get("efficiency_vs_base")
        line = (
            f"{fig},arm={arm},nodes={r['nodes']},"
            f"size={r['file_size']//1024}KB,agg_bw={r['agg_MBps']:.0f}MB/s,"
            f"files_s={r['files_s']:.0f},hit={r['hit_rate']:.3f}")
        if r.get("batched"):
            line += (f",makespan_batched={r['makespan_s']:.6f}s,"
                     f"makespan_perfile={r['makespan_perfile_s']:.6f}s,"
                     f"batched_speedup={r['batched_speedup']:.3f}")
        if r.get("prefetch"):
            line += (f",makespan_prefetch={r['makespan_s']:.6f}s,"
                     f"makespan_batched={r['makespan_batched_s']:.6f}s,"
                     f"prefetch_speedup={r['prefetch_speedup']:.3f},"
                     f"windows={r['prefetch_windows']}")
        if r.get("cache_mb"):       # cache enabled: report even a 0.0 rate
            line += f",cache_hit={r['cache_hit_rate']:.3f}"
        if eff:
            line += f",scale_eff={eff:.3f}"
        out.append(line)
    return out


def cache_policy_comparison(*, num_files: int = 64, file_size: int = 4096,
                            cache_files: int = 16, accesses: int = 512,
                            seed: int = 0) -> Dict:
    """LRU vs Belady vs 2Q client-cache hit rate at one byte budget on a
    uniform-random (with reuse) epoch trace — the access pattern the paper
    says defeats LRU. Belady gets the trace as its future oracle. (Legacy
    single-budget arm kept for pinning tests; ``cache_policy_sweep`` is
    the guarded BENCH block.)"""
    rng = np.random.default_rng(seed)
    paths = [f"bench/f_{i:06d}.bin" for i in range(num_files)]
    trace = [paths[int(i)]
             for i in rng.integers(0, num_files, size=accesses)]
    budget = cache_files * file_size
    out: Dict = {"budget_bytes": budget, "accesses": accesses}
    for policy in ("lru", "belady", "2q"):
        payload = bytes(file_size)
        files = {p: payload for p in paths}
        blobs, _ = prepare_dataset(files, 8, compress=False)
        cluster = FanStoreCluster(2, interconnect=CPU_NET,
                                  cache_bytes=budget, cache_policy=policy)
        cluster.load_partitions(blobs, replication=1)
        if policy == "belady":
            EpochSchedule.from_trace({1: [[p] for p in trace]}
                                     ).install_futures(cluster)
        for p in trace:
            cluster.read_many(1, [p], materialize=False)
        out[f"{policy}_hit_rate"] = cluster.caches[1].stats.hit_rate
    return out


#: the policies the sweep scores, online first, the oracle last
SWEEP_POLICIES = ("lru", "2q", "lfu", "arc", "gdsf", "predictive", "belady")


def policy_trace(kind: str, num_files: int, epochs: int,
                 seed: int = 0) -> List[str]:
    """Deterministic DL-shaped access traces (one requester):

    * ``"uniform"`` — per-epoch uniform permutation: every file exactly
      once per epoch in a fresh shuffled order. This is the paper's
      actual access pattern (global shuffle, sampling WITHOUT
      replacement), and it is adversarial for LRU: the most recently
      read file is the FARTHEST from reuse (~one full epoch away).
    * ``"zipf"`` — per-epoch zipf multiset permutation: file i appears
      ``k_i`` times per epoch with zipf-shaped ``k_i`` (the oversampled
      hot head that class-balancing / replay sampling produces),
      shuffled within the epoch. Skew + without-replacement structure:
      frequency-aware policies win, and reuse gaps are learnable.
    * ``"scan"`` — a hot working set re-read every round with one-shot
      cold scan segments interleaved: the probation-queue case 2Q
      exists for (LRU lets every scan evict the hot set).
    """
    rng = np.random.default_rng(seed)
    paths = [f"bench/f_{i:06d}.bin" for i in range(num_files)]
    trace: List[str] = []
    if kind == "uniform":
        for _ in range(epochs):
            trace.extend(paths[int(i)]
                         for i in rng.permutation(num_files))
    elif kind == "zipf":
        w = [1.0 / (i + 1) ** 1.1 for i in range(num_files)]
        reps = [max(1, round(x * 8 / w[0])) for x in w]
        epoch = [paths[i] for i in range(num_files)
                 for _ in range(reps[i])]
        for _ in range(epochs):
            order = rng.permutation(len(epoch))
            trace.extend(epoch[int(i)] for i in order)
    elif kind == "scan":
        hot = paths[:num_files // 8]
        cold = paths[num_files // 8:]
        ci = 0
        for _ in range(epochs * 4):
            hs = list(hot)
            rng.shuffle(hs)
            trace.extend(hs)
            for _ in range(max(1, len(cold) // 12)):
                trace.append(cold[ci % len(cold)])
                ci += 1
    else:
        raise ValueError(f"unknown trace kind {kind!r}")
    return trace


def cache_policy_sweep(*, num_files: int = 64, file_size: int = 4096,
                       budgets_files=(8, 16, 32), epochs: int = 6,
                       seed: int = 0, smoke: bool = False) -> Dict:
    """The guarded cache-policy BENCH block: every registered policy x
    three byte budgets x the uniform-permutation and zipf traces, driven
    through the FULL cluster read path (placement, transport accounting,
    NodeClock mirroring — not a bare ByteCache loop), plus a scan-trace
    arm pinning 2Q's probation win over LRU.

    Guarded downstream (benchmarks/run.py): ARC >= LRU and Predictive >=
    LRU on every (budget, trace) arm, Predictive closes >= 40% of the
    LRU->Belady hit-rate gap on every zipf arm, Belady stays the upper
    bound everywhere, and 2Q >= LRU on the scan arm."""
    if smoke:
        epochs = max(3, epochs // 2)
    payload = bytes(file_size)
    paths = [f"bench/f_{i:06d}.bin" for i in range(num_files)]
    files = {p: payload for p in paths}
    blobs, _ = prepare_dataset(files, 8, compress=False)

    def drive(policy: str, trace: List[str], budget_files: int) -> float:
        cluster = FanStoreCluster(2, interconnect=CPU_NET,
                                  cache_bytes=budget_files * file_size,
                                  cache_policy=policy)
        cluster.load_partitions(blobs, replication=1)
        if policy == "belady":
            EpochSchedule.from_trace({1: [[p] for p in trace]}
                                     ).install_futures(cluster)
        for p in trace:
            cluster.read_many(1, [p], materialize=False)
        hr = cluster.caches[1].stats.hit_rate
        # the NodeClock mirror must agree with the tier truth for EVERY
        # policy — the "counters mirrored identically to LRU" contract
        clock = cluster.clocks[1]
        st = cluster.cache_tiers[1].stats
        assert clock.cache_hits == st.hits, (policy, "hit mirror")
        assert clock.cache_misses == st.misses, (policy, "miss mirror")
        cluster.close()
        return hr

    out: Dict = {"num_files": num_files, "file_size": file_size,
                 "budgets_files": list(budgets_files),
                 "policies": list(SWEEP_POLICIES), "epochs": epochs}
    for kind in ("uniform", "zipf"):
        trace = policy_trace(kind, num_files, epochs, seed)
        arms: Dict = {}
        for bf in budgets_files:
            arms[str(bf)] = {pol: drive(pol, trace, bf)
                             for pol in SWEEP_POLICIES}
        out[kind] = {"accesses": len(trace), "arms": arms}
    # zipf gap closure per budget: (pred - lru) / (belady - lru)
    out["zipf_gap_closure"] = {
        bf: ((a["predictive"] - a["lru"]) / (a["belady"] - a["lru"])
             if a["belady"] > a["lru"] else 1.0)
        for bf, a in out["zipf"]["arms"].items()}
    # scan arm at a tight budget: 2Q's probation keeps the hot set
    # resident through one-shot scans that flush LRU
    scan = policy_trace("scan", num_files, epochs, seed)
    out["scan"] = {"accesses": len(scan),
                   "budget_files": num_files // 6,
                   "lru": drive("lru", scan, num_files // 6),
                   "2q": drive("2q", scan, num_files // 6)}
    return out


def cross_epoch_comparison(*, num_files: int = 24, file_size: int = 8192,
                           epochs: int = 3, steps_per_epoch: int = 6,
                           window: int = 4, cache_files: int = 16,
                           seed: int = 0, smoke: bool = False) -> Dict:
    """Cross-epoch prefetch stitching vs drain-and-refill, guarded.

    One requester reads every file once per epoch (fresh permutation) in
    ``steps_per_epoch`` batched steps, prefetched through lookahead
    windows on a latency-bound fabric, with a cache that holds 2/3 of
    the dataset (so every epoch must re-stage the evicted tail).
    ``window`` deliberately does NOT divide ``steps_per_epoch``: the
    drain-and-refill baseline (one schedule per epoch, fully drained at
    each boundary) cuts ``epochs * ceil(S/w)`` windows — a partial
    window round trip at EVERY epoch boundary — while the stitched arm
    materializes ONE multi-epoch schedule whose windows flow across the
    boundary, cutting only ``ceil(epochs*S/w)``. Both arms are
    prefetch-lane-bound (identical hit rates and consume lanes), so the
    boundary stall shows up directly in makespan: stitched must be
    STRICTLY below drain-and-refill (the guard), with retries == 0 on
    both (faults off).
    """
    # no smoke shrink: the arm is modeled (sub-second) and the boundary
    # margin needs all three epochs to be structural rather than thin
    del smoke
    # latency-bound: round trips dominate, so the extra boundary windows
    # and boundary demand misses are visible in makespan structurally
    net = InterconnectModel(latency_s=2e-3, bandwidth_Bps=100e9 / 8,
                            disk_bw_Bps=2.0e9)
    payload = bytes(file_size)
    paths = [f"bench/f_{i:06d}.bin" for i in range(num_files)]
    files = {p: payload for p in paths}
    blobs, _ = prepare_dataset(files, 8, compress=False)
    per_step = num_files // steps_per_epoch
    rng = np.random.default_rng(seed)
    epoch_steps: List[List[List[str]]] = []
    for _ in range(epochs):
        perm = [paths[int(i)] for i in rng.permutation(num_files)]
        epoch_steps.append([perm[s * per_step:(s + 1) * per_step]
                            for s in range(steps_per_epoch)])

    def build() -> FanStoreCluster:
        cluster = FanStoreCluster(2, interconnect=net,
                                  cache_bytes=cache_files * file_size,
                                  cache_policy="belady")
        cluster.load_partitions(blobs, replication=1)
        return cluster

    def run_stitched() -> Dict:
        cluster = build()
        flat = [b for ep in epoch_steps for b in ep]
        sched = EpochSchedule.from_trace({1: flat}, cluster)
        pf = PrefetchScheduler(cluster, sched, 1, window_steps=window)
        for gstep, batch in enumerate(flat):
            pf.ensure(gstep + window)
            pf.wait_ready(gstep)
            cluster.read_many(1, batch, materialize=False)
        pf.close()
        res = _cross_epoch_result(cluster, pf.windows_issued)
        cluster.close()
        return res

    def run_drain_refill() -> Dict:
        cluster = build()
        windows = 0
        for ep in epoch_steps:
            sched = EpochSchedule.from_trace({1: ep}, cluster)
            pf = PrefetchScheduler(cluster, sched, 1, window_steps=window)
            for s, batch in enumerate(ep):
                pf.ensure(s + window)
                pf.wait_ready(s)
                cluster.read_many(1, batch, materialize=False)
            pf.close()                  # the boundary stall: full drain,
            windows += pf.windows_issued  # then refill from scratch
        res = _cross_epoch_result(cluster, windows)
        cluster.close()
        return res

    stitched = run_stitched()
    drain = run_drain_refill()
    return {"epochs": epochs, "steps_per_epoch": steps_per_epoch,
            "window": window, "num_files": num_files,
            "cache_files": cache_files,
            "stitched": stitched, "drain_refill": drain,
            "stall_speedup": drain["makespan_s"] / stitched["makespan_s"]}


def _cross_epoch_result(cluster: FanStoreCluster, windows: int) -> Dict:
    clock = cluster.clocks[1]
    return {"makespan_s": cluster.makespan_s(),
            "cache_hit_rate": cluster.cache_hit_rate(),
            "prefetch_windows": windows,
            "prefetch_s": clock.prefetch_s,
            "consume_s": clock.consume_s,
            "retries": cluster.accounting.retries()}


def _drive_failover_epoch(cluster: FanStoreCluster,
                          traces: Dict[int, List[List[str]]], *,
                          victim: Optional[int] = None,
                          kill_step: Optional[int] = None
                          ) -> Tuple[int, List[int], Optional[str]]:
    """Drive one epoch step-by-step through the fault clock. A node in
    the failure set (or the designated victim once the kill step passes —
    a dead node stops issuing reads, it does not only stop serving) skips
    its batches. Returns (reads_failed, lost partition ids, error name);
    at R>=2 failover keeps reads_failed at zero, at R=1 the classified
    ``NodeLostError`` is caught and tallied here."""
    steps = max((len(s) for s in traces.values()), default=0)
    reads_failed = 0
    lost: List[int] = []
    error: Optional[str] = None
    for step in range(steps):
        cluster.tick_step(step)
        for nid, node_steps in sorted(traces.items()):
            if nid in cluster.failed or step >= len(node_steps):
                continue
            if (victim is not None and kill_step is not None
                    and nid == victim and step >= kill_step):
                continue
            try:
                cluster.read_many(nid, node_steps[step], materialize=False)
            except NodeLostError as e:
                reads_failed += len(node_steps[step])
                lost.extend(e.partitions)
                error = type(e).__name__
    return reads_failed, sorted(set(lost)), error


def failover_comparison(*, nodes: int = 8, smoke: bool = False,
                        kill_node: Optional[int] = None,
                        seed: int = 7) -> Dict:
    """Kill-a-node arm: the same trace driven over a healthy R=2 cluster
    and one whose FaultPolicy kills a node mid-epoch. The degraded run
    must finish every read via replica failover (zero client-visible
    errors), its retry ledger must equal the injector's raise count
    exactly, and its makespan stays within a small factor of healthy.
    The R=1 control shows the failure mode replication buys out of: the
    same kill surfaces as a classified ``NodeLostError`` naming the lost
    partitions — never a hang, never silent corruption."""
    file_size = 32 * 1024 if smoke else 256 * 1024
    reads_per_node = 96 if smoke else 128
    count = max(128, 2 * nodes)
    payload = bytes(np.random.default_rng(1).integers(
        0, 256, file_size, dtype=np.uint8))
    files = {f"bench/f_{i:06d}.bin": payload for i in range(count)}
    # enough partitions that every ring seat owns several — killing a
    # node must actually take data offline, not an empty seat
    blobs, _ = prepare_dataset(files, max(4 * nodes, 16), compress=False)
    paths = sorted(files)
    m = min(reads_per_node, count)
    steps = max(1, m // BATCH)
    kill_step = steps // 2
    if kill_node is None:
        # kill the most-loaded primary (ring placement is deterministic,
        # so this probe predicts every run below): the worst case, and a
        # guarantee the kill hits live traffic
        probe = FanStoreCluster.from_spec(ClusterSpec(
            num_nodes=nodes, replication=1, placement="ring"))
        probe.load_partitions(blobs, by_placement=True)
        victim = max(range(nodes),
                     key=lambda n: len(probe.nodes[n].partition_ids))
        probe.close()
    else:
        victim = kill_node

    rng = np.random.default_rng(nodes)
    traces: Dict[int, List[List[str]]] = {}
    for nid in range(nodes):
        chosen = [paths[int(i)]
                  for i in rng.choice(count, size=m, replace=False)]
        traces[nid] = [chosen[s:s + BATCH] for s in range(0, m, BATCH)]

    def run(replication: int, faults: Optional[Dict]) -> Dict:
        spec = ClusterSpec(num_nodes=nodes, replication=replication,
                           placement="ring", faults=faults)
        cluster = FanStoreCluster.from_spec(spec, interconnect=CPU_NET)
        cluster.load_partitions(blobs, by_placement=True)
        failed, lost, err = _drive_failover_epoch(
            cluster, traces,
            victim=victim if faults else None,
            kill_step=kill_step if faults else None)
        makespan = cluster.makespan_s()
        stats = cluster.fault_stats()
        healed = 0
        if faults and replication >= 2:
            # repair AFTER the epoch's makespan is captured: heal() ships
            # copies on the write lane, which is a separate story
            healed = cluster.heal()
        cluster.close()
        return {"makespan_s": makespan, "reads_failed": failed,
                "lost_partitions": lost, "error": err,
                "injected": stats["injected"], "retries": stats["retries"],
                "failed_nodes": stats["failed_nodes"],
                "healed_copies": healed}

    kill = {"kill_node": victim, "kill_at_step": kill_step, "seed": seed}
    healthy = run(2, None)
    degraded = run(2, kill)
    r1 = run(1, kill)
    return {"nodes": nodes, "steps": steps, "kill_node": victim,
            "kill_at_step": kill_step, "reads_per_node": m,
            "healthy": healthy, "degraded": degraded, "r1": r1,
            "degraded_ratio": (degraded["makespan_s"]
                               / healthy["makespan_s"])}


def format_failover_rows(fo: Dict) -> List[str]:
    d, r1 = fo["degraded"], fo["r1"]
    return [
        f"failover nodes={fo['nodes']} kill_node={fo['kill_node']} "
        f"kill_at_step={fo['kill_at_step']}/{fo['steps']}",
        f"  healthy  R=2 makespan={fo['healthy']['makespan_s'] * 1e3:.3f}ms",
        f"  degraded R=2 makespan={d['makespan_s'] * 1e3:.3f}ms "
        f"ratio={fo['degraded_ratio']:.2f}x reads_failed={d['reads_failed']} "
        f"injected={d['injected']} retries={d['retries']} "
        f"healed_copies={d['healed_copies']}",
        f"  control  R=1 error={r1['error']} "
        f"reads_failed={r1['reads_failed']} "
        f"lost_partitions={r1['lost_partitions']}",
    ]


def bench_json(*, nodes_list=(8, 64), smoke: bool = False) -> Dict:
    """Machine-readable perf snapshot: seed (per-file) / batched /
    prefetched arms at each node count, plus the cache-policy comparison.
    Written to BENCH_io.json by ``benchmarks/run.py --io-json`` so the perf
    trajectory is tracked from PR 2 on."""
    # reads span multiple BATCH-sized steps so a lookahead window has
    # batches to coalesce across (the whole point of the prefetch arm)
    file_size = 64 * 1024 if smoke else 512 * 1024
    reads_per_node = 96 if smoke else 128
    files_per_node = 16 if smoke else 32
    # small files: the latency/request-handling-bound regime where write
    # fan-in matters (the paper's many-small-files story, write side)
    write_size = 8 * 1024 if smoke else 16 * 1024
    # overlap arm: shard size comparable to the (halved) read phase, so
    # neither lane degenerates — when owner-side serve dominates BOTH
    # phases on the same node the overlap win collapses to ~0 by
    # construction (serve sums across lanes; that is the honest model)
    shard_bytes = (1 if smoke else 8) * 1024 * 1024
    overlap_reads = reads_per_node // 2
    window = 4
    results: Dict = {"config": {"file_size": file_size,
                                "reads_per_node": reads_per_node,
                                "batch": BATCH, "window": window,
                                "write_file_size": write_size,
                                "write_files_per_node": files_per_node,
                                "ckpt_shard_bytes": shard_bytes,
                                "smoke": smoke},
                     "arms": []}
    for nodes in nodes_list:
        count = max(128, 2 * nodes)
        kw = dict(file_size=file_size, count=count, net=CPU_NET,
                  reads_per_node=reads_per_node)
        seed_arm = run_one(nodes, batched=False, **kw)
        batched_arm = run_one(nodes, batched=True, **kw)
        prefetched_arm = run_one(nodes, prefetch=True, window=window,
                                 cache_policy="belady", **kw)
        entry = {"nodes": nodes, "count": count}
        for name, r in (("seed", seed_arm), ("batched", batched_arm),
                        ("prefetched", prefetched_arm)):
            entry[name] = {"makespan_s": r["makespan_s"],
                           "local_hit_rate": r["hit_rate"],
                           "cache_hit_rate": r["cache_hit_rate"],
                           "bytes_moved": r["bytes_moved"],
                           "prefetch_windows": r["prefetch_windows"]}
        entry["batched_speedup"] = (
            seed_arm["makespan_s"] / batched_arm["makespan_s"])
        entry["prefetch_speedup_vs_batched"] = (
            batched_arm["makespan_s"] / prefetched_arm["makespan_s"])
        # write half: batched write_many vs the per-file write_file loop,
        # plus checkpoint flush with/without prefetch-lane overlap
        wm = run_write_one(nodes, write_size, files_per_node, CPU_NET,
                           batched=True)
        wp = run_write_one(nodes, write_size, files_per_node, CPU_NET,
                           batched=False)
        ov = run_checkpoint_overlap(nodes, file_size, count, CPU_NET,
                                    reads_per_node=overlap_reads,
                                    window=window, shard_bytes=shard_bytes,
                                    chunk_bytes=max(shard_bytes // 4, 1))
        entry["write"] = {
            "write_many_makespan_s": wm["makespan_s"],
            "perfile_makespan_s": wp["makespan_s"],
            "write_speedup": wp["makespan_s"] / wm["makespan_s"],
            "write_rpcs": wm["write_rpcs"],
            "overlapped_makespan_s": ov["overlapped_makespan_s"],
            "serialized_makespan_s": ov["serialized_makespan_s"],
            "overlap_speedup": ov["overlap_speedup"]}
        results["arms"].append(entry)
    results["cache_policies"] = cache_policy_comparison()
    # the online-intelligence block: every registered policy x three byte
    # budgets x permutation + zipf traces (guarded: ARC/Predictive >= LRU
    # everywhere, Predictive closes >= 40% of the LRU->Belady zipf gap,
    # Belady upper bound, 2Q >= LRU on the scan arm)
    results["cache_policy_sweep"] = cache_policy_sweep(smoke=smoke)
    # cross-epoch prefetch stitching vs drain-and-refill (guarded:
    # stitched makespan strictly below, retries == 0 on both arms)
    results["cross_epoch"] = cross_epoch_comparison(smoke=smoke)
    # multi-tenant block: K co-located workers per node, shared cache
    # tier vs private per-worker budgets of the same total bytes
    results["workers"] = workers_comparison(smoke=smoke)
    # the hardware-truth block: the same trace over real wires (socket vs
    # shared memory), measured wall clocks — not modeled predictions.
    # Beside the read+write trace, the prefetch and checkpoint-overlap
    # benchmarks now carry their own measured arms with matching guards.
    results["measured"] = measured_comparison(smoke=smoke)
    results["measured"]["prefetch"] = measured_prefetch_comparison(
        smoke=smoke)
    results["measured"]["checkpoint"] = measured_ckpt_comparison(
        smoke=smoke)
    # the wire-gap block: single-conn vs striped/pipelined socket vs the
    # one-sided rdma backend on a pure-remote trace, plus wire-codec
    # engagement truth (cost-model-predicted only)
    results["measured"]["wire"] = measured_wire_comparison(smoke=smoke)
    # the prefetch-shape block: a slow latency-bound fabric with a deep
    # window — where the scheduler's win is structural, not a ~1% smoke
    # artifact (this is the guarded prefetch ratio)
    results["prefetch_depth"] = prefetch_depth_comparison(smoke=smoke)
    # the robustness block: kill a node mid-epoch at R=2 (every read must
    # finish via replica failover, retry ledger == injected faults,
    # bounded makespan inflation) with the R=1 classified-loss control
    results["failover"] = failover_comparison(smoke=smoke)
    # the serving-plane block: 64 read-mostly tenants on 8 nodes replaying
    # a zipfian shard trace through admission-gated tenant sessions —
    # hot-shard replication vs single-owner, per-tenant attribution
    # tie-out, and the inflight-byte cap (benchmarks/app_throughput.py;
    # smoke shrinks per-tenant request counts, never the tenant count)
    from benchmarks.app_throughput import serving_comparison
    results["serving"] = serving_comparison(smoke=smoke)
    return results


def main(*, batched: bool = False, prefetch: bool = False, window: int = 4,
         cache_mb: int = 0, epochs: Optional[int] = None,
         arms: Optional[List[str]] = None, write: bool = False,
         backend: str = "modeled", workers: int = 0,
         kill_node: bool = False) -> List[str]:
    if epochs is None:
        epochs = 2 if cache_mb else 1
    if kill_node:
        return format_failover_rows(failover_comparison())
    if workers:
        # shared node tier vs private per-worker caches, modeled, at a
        # few node counts (same total bytes either way)
        rows = []
        for n in (4, 8, 16):
            rows.append(run_workers_one(n, workers, 256 * 1024,
                                        max(128, 2 * n), CPU_NET,
                                        shared=True))
            rows.append(run_workers_one(n, workers, 256 * 1024,
                                        max(128, 2 * n), CPU_NET,
                                        shared=False))
        return format_workers_rows(rows)
    if backend != "modeled":
        # real wires: every node is an actual serving loop on this host,
        # so the measured axis sweeps small node counts only
        rows = [run_measured_one(backend, nodes=n) for n in (1, 2, 4, 8)]
        return format_measured_rows(rows)
    out = []
    for arm, fig in (("gpu", "fig5"), ("cpu", "fig6")):
        if arms and arm not in arms:
            continue
        if write:
            out.extend(format_write_rows(arm, run_write(arm)))
            continue
        rows = run(arm, batched=batched, prefetch=prefetch, window=window,
                   cache_mb=cache_mb, epochs=epochs)
        out.extend(format_rows(arm, fig, rows))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batched", action="store_true",
                    help="read through read_many (coalesced round trips) and "
                         "report the makespan win over the per-file path")
    ap.add_argument("--prefetch", action="store_true",
                    help="clairvoyant window prefetch (EpochSchedule + "
                         "PrefetchScheduler + Belady cache) and report the "
                         "makespan win over the batched path")
    ap.add_argument("--window", type=int, default=4,
                    help="prefetch lookahead window in training steps")
    ap.add_argument("--cache-mb", type=int, default=0,
                    help="per-node client read cache budget in MiB")
    ap.add_argument("--epochs", type=int, default=None,
                    help="read passes per node (default 1; 2 when caching)")
    ap.add_argument("--arm", choices=["gpu", "cpu"], default=None,
                    help="run a single arm instead of both")
    ap.add_argument("--write", action="store_true",
                    help="write-path scaling: batched write_many (one round "
                         "trip per (writer, owner) pair, write lane) vs the "
                         "per-file write_file loop")
    ap.add_argument("--backend", choices=["modeled", "socket", "shm", "rdma"],
                    default="modeled",
                    help="transport backend: 'modeled' runs the paper-scale "
                         "modeled sweeps; 'socket'/'shm'/'rdma' drive a real "
                         "wire and report MEASURED wall-clock makespans")
    ap.add_argument("--workers", type=int, default=0, metavar="K",
                    help="K co-located workers per node: shared node "
                         "cache tier vs private per-worker caches at the "
                         "same total byte budget (hit rate + makespan)")
    ap.add_argument("--kill-node", action="store_true",
                    help="fault-tolerance arm: kill one node mid-epoch at "
                         "R=2 (reads must all finish via replica failover) "
                         "vs the R=1 control (classified NodeLostError)")
    args = ap.parse_args()
    for line in main(batched=args.batched, prefetch=args.prefetch,
                     window=args.window, cache_mb=args.cache_mb,
                     epochs=args.epochs,
                     arms=[args.arm] if args.arm else None,
                     write=args.write, backend=args.backend,
                     workers=args.workers, kill_node=args.kill_node):
        print(line)
