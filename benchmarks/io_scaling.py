"""Figs 5-6: multi-node aggregated bandwidth/throughput scaling.

Simulated cluster (interconnect model accounts per-node timelines; see
repro.fanstore.cluster). GPU-cluster arm: {1,4,8,16} nodes, FDR IB 56 Gb/s.
CPU-cluster arm: {1,64,128,256,512} nodes, OPA 100 Gb/s. Each node reads
every file once (the paper's benchmark), files striped once across nodes
(R=1), so the local hit rate falls as 1/N — exactly the regime Figs 5-6
measure. Reported: aggregated bandwidth, throughput, scaling efficiency vs
the paper's chosen baselines (4 nodes GPU / 64 nodes CPU).

Beyond the paper, two engine axes::

    --batched      route reads through ``read_many`` so all requests for one
                   owner ride a single modeled round trip; reports makespan
                   for both paths and the speedup
    --cache-mb M   per-node client LRU read cache of M MiB (2 epochs so the
                   second pass can hit), reporting cache hit rate
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Optional

import numpy as np

from repro.data.synthetic import fixed_size_files
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.prepare import prepare_dataset

FILE_SIZES = [128 * 1024, 512 * 1024, 2 * 1024 * 1024, 8 * 1024 * 1024]

GPU_NET = InterconnectModel(latency_s=1.0e-6, bandwidth_Bps=56e9 / 8,
                            disk_bw_Bps=2.0e9)
CPU_NET = InterconnectModel(latency_s=1.5e-6, bandwidth_Bps=100e9 / 8,
                            disk_bw_Bps=2.0e9)

BATCH = 32      # samples per coalesced read_many call (one training step)


def _build_cluster(nodes: int, file_size: int, count: int,
                   net: InterconnectModel, *, replication: int,
                   cache_mb: int) -> FanStoreCluster:
    # one shared payload per size: content is timing-irrelevant here and
    # generating count x file_size of RNG bytes dominated the wall time
    payload = bytes(np.random.default_rng(1).integers(
        0, 256, file_size, dtype=np.uint8))
    files = {f"bench/f_{i:06d}.bin": payload for i in range(count)}
    blobs, _ = prepare_dataset(files, max(nodes, 8), compress=False)
    cluster = FanStoreCluster(nodes, interconnect=net,
                              cache_bytes=cache_mb * 1024 * 1024)
    cluster.load_partitions(blobs, replication=replication)
    return cluster


def run_one(nodes: int, file_size: int, count: int,
            net: InterconnectModel, *, replication: int = 1,
            reads_per_node: int = 128, batched: bool = False,
            cache_mb: int = 0, epochs: int = 1,
            cluster: Optional[FanStoreCluster] = None) -> Dict:
    if cluster is None:
        cluster = _build_cluster(nodes, file_size, count, net,
                                 replication=replication, cache_mb=cache_mb)
    paths = sorted(f"bench/f_{i:06d}.bin" for i in range(count))
    cluster.reset_clocks()
    for c in cluster.caches.values():
        c.clear()
    # each node reads a uniform sample of the directory: the per-node
    # timeline statistics match the paper's read-everything benchmark in
    # expectation while bounding the python-loop cost at 512 nodes
    rng = np.random.default_rng(nodes)
    m = min(reads_per_node, len(paths))
    reads = 0
    for _ in range(epochs):
        for nid in range(nodes):
            chosen = [paths[int(i)]
                      for i in rng.choice(len(paths), size=m, replace=False)]
            reads += len(chosen)
            if batched:
                for s in range(0, len(chosen), BATCH):
                    cluster.read_many(nid, chosen[s:s + BATCH],
                                      materialize=False)
            else:
                for p in chosen:
                    cluster.read(nid, p, materialize=False)
    bw = cluster.aggregate_bandwidth()
    t = cluster.makespan_s()
    return {"nodes": nodes, "file_size": file_size,
            "agg_MBps": bw / 1e6,
            "files_s": reads / t,
            "hit_rate": cluster.local_hit_rate(),
            "cache_hit_rate": cluster.cache_hit_rate(),
            "cache_mb": cache_mb,
            "makespan_s": t,
            "batched": batched}


def run(arm: str = "cpu", *, count: int = None, batched: bool = False,
        cache_mb: int = 0, epochs: int = 1) -> List[Dict]:
    if arm == "gpu":
        scales, net = [1, 4, 8, 16], GPU_NET
        count = count or 128
    else:
        scales, net = [1, 64, 128, 256, 512], CPU_NET
        # file count must exceed the node count or the benchmark measures
        # hot-owner serialization instead of scaling (paper uses 2K-128K)
        count = count or 1024
    rows = []
    for size in FILE_SIZES:
        for n in scales:
            # F >= 2N keeps the benchmark in the scaling (not hot-owner)
            # regime while bounding the python-loop cost at large N
            c = min(count, max(256, 2 * n))
            cluster = _build_cluster(n, size, c, net, replication=1,
                                     cache_mb=cache_mb)
            row = run_one(n, size, c, net, batched=batched,
                          cache_mb=cache_mb, epochs=epochs, cluster=cluster)
            if batched:
                # same workload through per-file round trips on the same
                # cluster (clocks + caches reset): the coalescing win is the
                # makespan ratio, without paying the dataset build twice
                base = run_one(n, size, c, net, batched=False,
                               cache_mb=cache_mb, epochs=epochs,
                               cluster=cluster)
                row["makespan_perfile_s"] = base["makespan_s"]
                row["batched_speedup"] = (
                    base["makespan_s"] / row["makespan_s"]
                    if row["makespan_s"] > 0 else 1.0)
            rows.append(row)
    # efficiency vs the paper's baselines
    base_n = 4 if arm == "gpu" else 64
    for size in FILE_SIZES:
        base = next(r for r in rows
                    if r["file_size"] == size and r["nodes"] == base_n)
        peak = next(r for r in rows
                    if r["file_size"] == size and r["nodes"] == scales[-1])
        peak["efficiency_vs_base"] = (
            peak["agg_MBps"] / peak["nodes"]) / (base["agg_MBps"] / base["nodes"])
    return rows


def format_rows(arm: str, fig: str, rows: List[Dict]) -> List[str]:
    out = []
    for r in rows:
        eff = r.get("efficiency_vs_base")
        line = (
            f"{fig},arm={arm},nodes={r['nodes']},"
            f"size={r['file_size']//1024}KB,agg_bw={r['agg_MBps']:.0f}MB/s,"
            f"files_s={r['files_s']:.0f},hit={r['hit_rate']:.3f}")
        if r.get("batched"):
            line += (f",makespan_batched={r['makespan_s']:.6f}s,"
                     f"makespan_perfile={r['makespan_perfile_s']:.6f}s,"
                     f"batched_speedup={r['batched_speedup']:.3f}")
        if r.get("cache_mb"):       # cache enabled: report even a 0.0 rate
            line += f",cache_hit={r['cache_hit_rate']:.3f}"
        if eff:
            line += f",scale_eff={eff:.3f}"
        out.append(line)
    return out


def main(*, batched: bool = False, cache_mb: int = 0,
         epochs: Optional[int] = None, arms: Optional[List[str]] = None
         ) -> List[str]:
    if epochs is None:
        epochs = 2 if cache_mb else 1
    out = []
    for arm, fig in (("gpu", "fig5"), ("cpu", "fig6")):
        if arms and arm not in arms:
            continue
        rows = run(arm, batched=batched, cache_mb=cache_mb, epochs=epochs)
        out.extend(format_rows(arm, fig, rows))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batched", action="store_true",
                    help="read through read_many (coalesced round trips) and "
                         "report the makespan win over the per-file path")
    ap.add_argument("--cache-mb", type=int, default=0,
                    help="per-node client LRU read cache budget in MiB")
    ap.add_argument("--epochs", type=int, default=None,
                    help="read passes per node (default 1; 2 when caching)")
    ap.add_argument("--arm", choices=["gpu", "cpu"], default=None,
                    help="run a single arm instead of both")
    args = ap.parse_args()
    for line in main(batched=args.batched, cache_mb=args.cache_mb,
                     epochs=args.epochs,
                     arms=[args.arm] if args.arm else None):
        print(line)
