"""Fig 3: single-node bandwidth/throughput — FanStore vs SSD vs FUSE vs SFS.

The paper's benchmark (§6.2): file sizes {128 KB, 512 KB, 2 MB, 8 MB} with
counts {128K, 32K, 8K, 2K} scaled down by --scale for CPU-container wall
time. "SSD" here is the container's filesystem via direct open/read;
"SSD-fuse" adds the paper-measured user/kernel crossing overhead per op
(FUSE is 2.9-4.4x slower in the paper; we model the crossing cost);
"SFS" uses the interconnect model's shared-filesystem path (single metadata
server + shared bandwidth). FanStore reads go through the real Python
store (partition index + refcount cache + decompress-if-packed).

Engine axes (beyond the paper): ``--batched`` drives the reads through the
``read_many`` batched API in training-step-sized chunks, ``--cache-mb``
enables the per-node client read cache with a second epoch so repeated
reads are served from RAM instead of the partition store, ``--prefetch``
stages upcoming steps into the cache through the clairvoyant window
scheduler (EpochSchedule + PrefetchScheduler) so the demand loop reads RAM
while the staging runs ahead, and ``--checkpoint`` streams checkpoint
shards through the session's CheckpointWriter DURING the prefetched epoch
— the modeled makespan (write lane concurrent with prefetch/consume) is
reported against the serialized write-then-prefetch sum.
"""
from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.data.synthetic import fixed_size_files
from repro.fanstore.api import FanStoreSession
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.prefetch import EpochSchedule, PrefetchScheduler
from repro.fanstore.prepare import prepare_dataset

FILE_SIZES = [128 * 1024, 512 * 1024, 2 * 1024 * 1024, 8 * 1024 * 1024]
BASE_COUNTS = [1024, 256, 64, 16]          # paper counts / 128 (CPU container)

FUSE_CROSSING_S = 60e-6      # per-op user<->kernel<->user cost (Vangoor'17)
SFS_LATENCY_S = 450e-6       # shared-FS per-op metadata+RPC cost (Lustre-ish)
SFS_BW = 1.2e9               # shared-FS client bandwidth


BATCH = 32      # samples per read_many call in --batched mode


def bench_fanstore(files: Dict[str, bytes], *, batched: bool = False,
                   cache_mb: int = 0, epochs: int = 1,
                   prefetch: bool = False, window: int = 4
                   ) -> Tuple[float, float]:
    blobs, _ = prepare_dataset(files, 4, compress=False)
    if prefetch and cache_mb == 0:
        cache_mb = sum(len(v) for v in files.values()) // (1024 * 1024) + 1
    cluster = FanStoreCluster(1, cache_bytes=cache_mb * 1024 * 1024,
                              cache_policy="belady" if prefetch else "lru")
    cluster.load_partitions(blobs, replication=1)
    paths = sorted(files)
    steps = [paths[s:s + BATCH] for s in range(0, len(paths), BATCH)]
    t0 = time.perf_counter()
    total = 0
    for _ in range(epochs):
        if prefetch:
            pf = PrefetchScheduler(
                cluster, EpochSchedule.from_trace({0: steps}, cluster), 0,
                window_steps=window)
            for step, chunk in enumerate(steps):
                pf.ensure(step + window)
                pf.wait_ready(step)     # demand reads must not race staging
                for data in cluster.read_many(0, chunk):
                    total += len(data)
            pf.close()
        elif batched:
            for chunk in steps:
                for data in cluster.read_many(0, chunk):
                    total += len(data)
        else:
            for p in paths:
                total += len(cluster.read(0, p))
    dt = time.perf_counter() - t0
    return total / dt, epochs * len(paths) / dt


def bench_checkpoint_overlap(files: Dict[str, bytes], *,
                             shard_bytes: int = 8 * 1024 * 1024,
                             num_shards: int = 4,
                             chunk_bytes: int = 1024 * 1024,
                             window: int = 4) -> Dict:
    """Single-node checkpoint/prefetch overlap: stream ``num_shards``
    checkpoint shards through the session's CheckpointWriter while the
    clairvoyant scheduler stages the epoch. On one node the writer IS the
    placement owner, so the whole flush books on the concurrent write lane
    and the modeled makespan is max(consume, prefetch, write) — reported
    against the serialized write-then-prefetch sum."""
    def build():
        blobs, _ = prepare_dataset(files, 4, compress=False)
        cache_mb = sum(len(v) for v in files.values()) // (1024 * 1024) + 1
        cluster = FanStoreCluster(1, cache_bytes=cache_mb * 1024 * 1024,
                                  cache_policy="belady")
        cluster.load_partitions(blobs, replication=1)
        return cluster

    def drive_epoch(cluster):
        paths = sorted(files)
        steps = [paths[s:s + BATCH] for s in range(0, len(paths), BATCH)]
        pf = PrefetchScheduler(
            cluster, EpochSchedule.from_trace({0: steps}, cluster), 0,
            window_steps=window)
        for step, chunk in enumerate(steps):
            pf.ensure(step + window)
            pf.wait_ready(step)
            cluster.read_many(0, chunk, materialize=False)
        pf.close()

    def write_ckpt(cluster):
        writer = FanStoreSession(cluster, 0).checkpoint_writer(
            chunk_bytes=chunk_bytes)
        payload = bytes(shard_bytes)
        for i in range(num_shards):
            writer.write_shard(f"ckpt/step_0/shard_{i:03d}.npy", payload)

    overlap_cluster = build()
    overlap_cluster.reset_clocks()
    drive_epoch(overlap_cluster)
    write_ckpt(overlap_cluster)
    overlapped = overlap_cluster.makespan_s()

    c1 = build()
    c1.reset_clocks()
    drive_epoch(c1)
    prefetch_only = c1.makespan_s()
    c2 = build()
    c2.reset_clocks()
    write_ckpt(c2)
    write_only = c2.makespan_s()
    serialized = prefetch_only + write_only
    return {"overlapped_s": overlapped, "serialized_s": serialized,
            "prefetch_s": prefetch_only, "write_s": write_only,
            "ckpt_bytes": shard_bytes * num_shards,
            "overlap_speedup": serialized / overlapped if overlapped else 1.0}


def bench_disk(files: Dict[str, bytes], *, crossing_s: float = 0.0
               ) -> Tuple[float, float]:
    root = tempfile.mkdtemp(prefix="fsbench_")
    try:
        for p, data in files.items():
            full = os.path.join(root, p.replace("/", "_"))
            with open(full, "wb") as f:
                f.write(data)
        paths = sorted(os.listdir(root))
        t0 = time.perf_counter()
        total = 0
        for p in paths:
            with open(os.path.join(root, p), "rb") as f:
                total += len(f.read())
            if crossing_s:
                time.sleep(0)       # accounted below, not slept
        dt = time.perf_counter() - t0
        # FUSE adds ~3 crossings per file (open/read/close)
        dt += crossing_s * 3 * len(paths)
        return total / dt, len(paths) / dt
    finally:
        shutil.rmtree(root, ignore_errors=True)


def bench_sfs_model(files: Dict[str, bytes]) -> Tuple[float, float]:
    """Shared-FS analytic model: per-op latency + shared bandwidth."""
    nbytes = sum(len(v) for v in files.values())
    nops = len(files)
    dt = nops * SFS_LATENCY_S + nbytes / SFS_BW
    return nbytes / dt, nops / dt


def run(scale: float = 1.0, *, batched: bool = False, cache_mb: int = 0,
        epochs: int = 1, prefetch: bool = False) -> List[Dict]:
    rows = []
    for size, count in zip(FILE_SIZES, BASE_COUNTS):
        count = max(4, int(count * scale))
        files = fixed_size_files(size, count, entropy_bits=8)
        fs_bw, fs_tp = bench_fanstore(files, batched=batched,
                                      cache_mb=cache_mb, epochs=epochs,
                                      prefetch=prefetch)
        ssd_bw, ssd_tp = bench_disk(files)
        fuse_bw, fuse_tp = bench_disk(files, crossing_s=FUSE_CROSSING_S)
        sfs_bw, sfs_tp = bench_sfs_model(files)
        rows.append({
            "file_size": size, "count": count,
            "fanstore_MBps": fs_bw / 1e6, "ssd_MBps": ssd_bw / 1e6,
            "fuse_MBps": fuse_bw / 1e6, "sfs_MBps": sfs_bw / 1e6,
            "fanstore_files_s": fs_tp, "ssd_files_s": ssd_tp,
            "fuse_files_s": fuse_tp, "sfs_files_s": sfs_tp,
            "fanstore_vs_ssd": fs_bw / ssd_bw,
            "fanstore_vs_fuse": fs_bw / fuse_bw,
            "fanstore_vs_sfs": fs_bw / sfs_bw,
        })
    return rows


def main(scale: float = 0.25, *, batched: bool = False, cache_mb: int = 0,
         epochs: int = None, prefetch: bool = False,
         checkpoint: bool = False) -> List[str]:
    if epochs is None:
        epochs = 2 if cache_mb else 1
    if checkpoint:
        out = ["table=fig3_checkpoint_overlap"]
        for size, count in zip(FILE_SIZES[:2], BASE_COUNTS[:2]):
            files = fixed_size_files(size, max(4, int(count * scale)),
                                     entropy_bits=8)
            r = bench_checkpoint_overlap(files)
            out.append(
                f"fig3ckpt,size={size//1024}KB,"
                f"overlapped={r['overlapped_s']:.6f}s,"
                f"serialized={r['serialized_s']:.6f}s,"
                f"prefetch_only={r['prefetch_s']:.6f}s,"
                f"write_only={r['write_s']:.6f}s,"
                f"overlap_speedup={r['overlap_speedup']:.3f}")
        return out
    out = ["table=fig3_single_node"]
    for r in run(scale, batched=batched, cache_mb=cache_mb, epochs=epochs,
                 prefetch=prefetch):
        out.append(
            f"fig3,size={r['file_size']//1024}KB,"
            f"fanstore={r['fanstore_MBps']:.0f}MB/s,"
            f"ssd={r['ssd_MBps']:.0f}MB/s,fuse={r['fuse_MBps']:.0f}MB/s,"
            f"sfs={r['sfs_MBps']:.0f}MB/s,"
            f"vs_ssd={r['fanstore_vs_ssd']:.2f},"
            f"vs_fuse={r['fanstore_vs_fuse']:.2f},"
            f"vs_sfs={r['fanstore_vs_sfs']:.2f}"
            + (f",batched=1" if batched else "")
            + (f",prefetch=1" if prefetch else "")
            + (f",cache_mb={cache_mb}" if cache_mb else ""))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--batched", action="store_true",
                    help="read through the batched read_many API")
    ap.add_argument("--prefetch", action="store_true",
                    help="stage steps ahead through the clairvoyant window "
                         "scheduler; demand reads hit the client cache")
    ap.add_argument("--cache-mb", type=int, default=0,
                    help="client read cache budget in MiB")
    ap.add_argument("--epochs", type=int, default=None,
                    help="read passes (default 1; 2 when caching)")
    ap.add_argument("--checkpoint", action="store_true",
                    help="stream checkpoint shards through CheckpointWriter "
                         "during the prefetched epoch; report overlapped vs "
                         "serialized modeled makespan")
    args = ap.parse_args()
    for line in main(args.scale, batched=args.batched,
                     cache_mb=args.cache_mb, epochs=args.epochs,
                     prefetch=args.prefetch, checkpoint=args.checkpoint):
        print(line)
