"""Figs 4/7/8/9 + serving plane: application throughput across storage
options, node counts, and (new) a multi-tenant read-mostly serving trace.

Mini versions of the paper's three applications, driven through the real
data plane (FanStore cluster + PrefetchLoader) with an analytic per-item
compute cost calibrated to the paper's measured ratios:

  ResNet-50  — I/O-heavy (the paper's 544 files/s case; FanStore >> SFS)
  SRGAN      — compute-bound (identical across storage options, Fig 4)
  FRNN       — small files, broadcast-replicated (Fig 9, ~linear scaling)

Per-node timelines come from the cluster's interconnect accounting; the
compute term is overlapped with I/O exactly like the paper's prefetching
pipeline (per-node step time = max(io, compute)).

``serving_comparison`` is the ROADMAP's serving-workload arm: 64 tenants
on 8 nodes replaying a zipfian shard trace through the serving plane
(:mod:`repro.fanstore.serving`) — admission-gated, per-tenant attributed,
with hot-shard promotion. Two arms, same trace:

  single      every shard single-owner, least-loaded selection — the
              zipf head's owner serializes the whole hot tail
  replicated  hot-shard promotion + power-of-two-choices selection —
              the head spreads over ``hot_shard_replication`` replicas

The guarded claims (benchmarks/run.py): replicated strictly beats
single-owner makespan; per-tenant attribution ties out exactly; measured
peak inflight never exceeds ``max_inflight_bytes``; the slowest tenant
stays within a 2x fairness bound of the mean.
"""
from __future__ import annotations

import threading
from typing import Dict, List

import numpy as np

from repro.data.synthetic import fixed_size_files
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.layout import pack_partition
from repro.fanstore.prepare import prepare_dataset
from repro.fanstore.serving import ServeGroup
from repro.fanstore.spec import ClusterSpec

APPS = {
    #            file_sz   files  compute_s/item  broadcast
    "resnet50": (108 * 1024, 256, 1.0 / 140, False),   # 140 items/s/node peak
    "srgan":    (800 * 1024, 64, 1.0 / 26, False),     # compute-dominated
    "frnn":     (320 * 1024, 128, 1.0 / 60, True),     # fits locally -> bcast
}

# shared-FS model: ONE metadata server serializes per-file ops (the paper's
# core scaling argument, §3.3); 130us/op calibrated so ResNet-50@64 nodes
# lands at the paper's measured 1.17x FanStore advantage.
SFS_META_S = 130e-6
SFS_BW_TOTAL = 4.0e9        # shared FS aggregate client bandwidth


def run_app(app: str, nodes: int, *, storage: str = "fanstore") -> Dict:
    size, count, compute, bcast = APPS[app]
    files = fixed_size_files(size, count, entropy_bits=8, prefix=app)
    net = InterconnectModel(latency_s=1.5e-6, bandwidth_Bps=100e9 / 8)
    spec = ClusterSpec(num_nodes=nodes, replication=1)
    cluster = FanStoreCluster.from_spec(spec, interconnect=net)
    blobs, _ = prepare_dataset(files, max(8, nodes), compress=False)
    cluster.load_partitions(blobs)
    if bcast and storage == "fanstore":
        cluster.broadcast_directory(app)
    paths = sorted(files)
    cluster.reset_clocks()
    # one epoch: every node reads its shard of the global batch stream
    for nid in range(nodes):
        for p in paths:
            cluster.read(nid, p, materialize=False)
    items = nodes * len(paths)
    if storage == "fanstore":
        io_s = cluster.makespan_s()
    else:  # shared filesystem model: serialized metadata + shared bandwidth
        nbytes = items * size
        io_s = items * SFS_META_S + nbytes / SFS_BW_TOTAL
    compute_s = len(paths) * compute          # per node, fully parallel
    step_s = max(io_s, compute_s)             # prefetch overlap (paper §3.4)
    return {"app": app, "nodes": nodes, "storage": storage,
            "items_s": items / step_s,
            "io_bound": io_s > compute_s}


# ---- the serving-plane arm -------------------------------------------------

def _zipf_trace(num_files: int, tenants: int, requests: int,
                files_per_request: int, *, s: float = 1.2
                ) -> Dict[str, List[List[str]]]:
    """Per-tenant request lists over a zipf(s) file popularity: file 0 is
    the global head, and with 16-file contiguous partitions the head
    partition carries ~45% of all reads — the hot shard the promotion
    machinery exists for. Deterministic per tenant (seeded)."""
    ranks = np.arange(1, num_files + 1, dtype=np.float64)
    p = ranks ** -s
    p /= p.sum()
    trace: Dict[str, List[List[str]]] = {}
    for t in range(tenants):
        rng = np.random.RandomState(1000 + t)
        picks = rng.choice(num_files, size=requests * files_per_request, p=p)
        trace[f"tenant-{t:04d}"] = [
            [f"serve/shard_{i:04d}.bin"
             for i in picks[r * files_per_request:(r + 1) * files_per_request]]
            for r in range(requests)]
    return trace


def _run_serving_arm(parts: List[bytes], trace: Dict[str, List[List[str]]],
                     *, nodes: int, tenants: int, cap: int,
                     promote: bool) -> Dict:
    spec = ClusterSpec(
        num_nodes=nodes,
        selector="power-of-two" if promote else "least-loaded",
        max_inflight_bytes=cap,
        serve_quantum_bytes=cap // 2,
        hot_shard_threshold=tenants if promote else 0,
        hot_shard_replication=3)
    with FanStoreCluster.from_spec(spec) as cluster:
        cluster.load_partitions(parts)
        cluster.reset_clocks()
        group = ServeGroup(cluster, tenants)
        errors: List[BaseException] = []

        def drive(tenant: str) -> None:
            try:
                for req in trace[tenant]:
                    group.read_many(tenant, req, materialize=False)
            except BaseException as exc:      # surfaced after the join
                errors.append(exc)

        threads = [threading.Thread(target=drive, args=(t,),
                                    name=f"serve-{t}")
                   for t in group.tenants]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if errors:
            raise errors[0]
        # fairness is judged WITHIN each node: co-located tenants share a
        # gate and a locality profile, so their serve-time spread is what
        # the DRR scheduler controls; cross-node spread reflects shard
        # placement (a tenant living on the zipf head's owner reads it
        # locally and cheaply), not scheduling
        fairness = 0.0
        for clock in cluster.clocks.values():
            vals = list(clock.tenant_serve_s.values())
            if vals:
                mean = sum(vals) / len(vals)
                if mean:
                    fairness = max(fairness, max(vals) / mean)
        gs = group.stats()
        return {
            "promote": promote,
            "makespan_s": cluster.makespan_s(),
            "attribution_ok": group.attribution_ok(),
            "peak_inflight_bytes": group.peak_inflight_bytes(),
            "admission_waits": gs["waits"],
            "admission_shed": gs["shed"],
            "promoted_partitions": gs["promoted_partitions"],
            "fairness_ratio": fairness,
            "serve_app_bytes": gs["serve_app_bytes"],
            "serve_app_requests": gs["serve_app_requests"],
        }


def serving_comparison(*, nodes: int = 8, tenants: int = 64,
                       smoke: bool = False) -> Dict:
    """The guarded serving block: same zipfian trace, single-owner vs
    hot-shard-replicated. Smoke shrinks the per-tenant request count only
    — tenants and nodes stay at 64 / 8 so the multi-tenant claims hold in
    the CI fast lane too."""
    file_size = 64 * 1024
    num_files = 256
    per_part = 16
    requests = 6 if smoke else 24
    files_per_request = 4
    cap = 8 * file_size           # 8 tenants/node x 4-file requests: gated
    # contiguous packing on purpose: prepare_dataset round-robins paths
    # across partitions, which would smear the zipf head over every node
    # and erase the hot shard this benchmark measures
    payload = bytes(file_size)
    parts = [pack_partition(
        [(f"serve/shard_{i:04d}.bin", payload)
         for i in range(p * per_part, (p + 1) * per_part)], compress=False)
        for p in range(num_files // per_part)]
    trace = _zipf_trace(num_files, tenants, requests, files_per_request)
    single = _run_serving_arm(parts, trace, nodes=nodes, tenants=tenants,
                              cap=cap, promote=False)
    replicated = _run_serving_arm(parts, trace, nodes=nodes,
                                  tenants=tenants, cap=cap, promote=True)
    return {
        "nodes": nodes,
        "tenants": tenants,
        "requests_per_tenant": requests,
        "files_per_request": files_per_request,
        "file_size": file_size,
        "max_inflight_bytes": cap,
        "single": single,
        "replicated": replicated,
        "replication_speedup": (single["makespan_s"]
                                / replicated["makespan_s"]),
    }


def format_serving_rows(sv: Dict) -> List[str]:
    s, r = sv["single"], sv["replicated"]
    return [
        f"serving,tenants={sv['tenants']},nodes={sv['nodes']},"
        f"single_makespan={s['makespan_s']:.4f}s,"
        f"replicated_makespan={r['makespan_s']:.4f}s,"
        f"replication_speedup={sv['replication_speedup']:.2f},"
        f"promoted={len(r['promoted_partitions'])},"
        f"peak_inflight={r['peak_inflight_bytes']},"
        f"waits={r['admission_waits']},"
        f"fairness_ratio={r['fairness_ratio']:.3f}"]


def run(*, smoke: bool = False) -> List[Dict]:
    node_counts = (1, 4) if smoke else (1, 4, 16, 64)
    rows = []
    for app in APPS:
        for nodes in node_counts:
            rows.append(run_app(app, nodes, storage="fanstore"))
        rows.append(run_app(app, 4, storage="sfs"))
        rows.append(run_app(app, node_counts[-1], storage="sfs"))
    return rows


def main(*, smoke: bool = False) -> List[str]:
    rows = run(smoke=smoke)
    top = 4 if smoke else 64
    out = []
    for app in APPS:
        app_rows = [r for r in rows if r["app"] == app]
        fs = {r["nodes"]: r["items_s"] for r in app_rows
              if r["storage"] == "fanstore"}
        sfs = {r["nodes"]: r["items_s"] for r in app_rows
               if r["storage"] == "sfs"}
        eff = (fs[top] / top) / (fs[4] / 4)
        out.append(
            f"fig7-9,app={app},items_s@1={fs[1]:.0f},"
            f"items_s@{top}={fs[top]:.0f},"
            f"weak_eff_{top}v4={eff:.3f},"
            f"speedup_vs_sfs@{top}={fs[top]/sfs[top]:.2f}")
    out.extend(format_serving_rows(serving_comparison(smoke=smoke)))
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrink node counts and per-tenant request counts")
    args = ap.parse_args()
    for line in main(smoke=args.smoke):
        print(line)
