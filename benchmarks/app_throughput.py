"""Figs 4/7/8/9: application training throughput (items/s) across storage
options and node counts.

Mini versions of the paper's three applications, driven through the real
data plane (FanStore cluster + PrefetchLoader) with an analytic per-item
compute cost calibrated to the paper's measured ratios:

  ResNet-50  — I/O-heavy (the paper's 544 files/s case; FanStore >> SFS)
  SRGAN      — compute-bound (identical across storage options, Fig 4)
  FRNN       — small files, broadcast-replicated (Fig 9, ~linear scaling)

Per-node timelines come from the cluster's interconnect accounting; the
compute term is overlapped with I/O exactly like the paper's prefetching
pipeline (per-node step time = max(io, compute)).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.synthetic import fixed_size_files
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.prepare import prepare_dataset

APPS = {
    #            file_sz   files  compute_s/item  broadcast
    "resnet50": (108 * 1024, 256, 1.0 / 140, False),   # 140 items/s/node peak
    "srgan":    (800 * 1024, 64, 1.0 / 26, False),     # compute-dominated
    "frnn":     (320 * 1024, 128, 1.0 / 60, True),     # fits locally -> bcast
}

# shared-FS model: ONE metadata server serializes per-file ops (the paper's
# core scaling argument, §3.3); 130us/op calibrated so ResNet-50@64 nodes
# lands at the paper's measured 1.17x FanStore advantage.
SFS_META_S = 130e-6
SFS_BW_TOTAL = 4.0e9        # shared FS aggregate client bandwidth


def run_app(app: str, nodes: int, *, storage: str = "fanstore") -> Dict:
    size, count, compute, bcast = APPS[app]
    files = fixed_size_files(size, count, entropy_bits=8, prefix=app)
    net = InterconnectModel(latency_s=1.5e-6, bandwidth_Bps=100e9 / 8)
    cluster = FanStoreCluster(nodes, interconnect=net)
    blobs, _ = prepare_dataset(files, max(8, nodes), compress=False)
    cluster.load_partitions(blobs, replication=1)
    if bcast and storage == "fanstore":
        cluster.broadcast_directory(app)
    paths = sorted(files)
    cluster.reset_clocks()
    # one epoch: every node reads its shard of the global batch stream
    for nid in range(nodes):
        for p in paths:
            cluster.read(nid, p, materialize=False)
    items = nodes * len(paths)
    if storage == "fanstore":
        io_s = cluster.makespan_s()
    else:  # shared filesystem model: serialized metadata + shared bandwidth
        nbytes = items * size
        io_s = items * SFS_META_S + nbytes / SFS_BW_TOTAL
    compute_s = len(paths) * compute          # per node, fully parallel
    step_s = max(io_s, compute_s)             # prefetch overlap (paper §3.4)
    return {"app": app, "nodes": nodes, "storage": storage,
            "items_s": items / step_s,
            "io_bound": io_s > compute_s}


def run() -> List[Dict]:
    rows = []
    for app in APPS:
        for nodes in (1, 4, 16, 64):
            rows.append(run_app(app, nodes, storage="fanstore"))
        rows.append(run_app(app, 4, storage="sfs"))
        rows.append(run_app(app, 64, storage="sfs"))
    return rows


def main() -> List[str]:
    rows = run()
    out = []
    for app in APPS:
        app_rows = [r for r in rows if r["app"] == app]
        fs = {r["nodes"]: r["items_s"] for r in app_rows
              if r["storage"] == "fanstore"}
        sfs = {r["nodes"]: r["items_s"] for r in app_rows
               if r["storage"] == "sfs"}
        eff = (fs[64] / 64) / (fs[4] / 4)
        out.append(
            f"fig7-9,app={app},items_s@1={fs[1]:.0f},items_s@64={fs[64]:.0f},"
            f"weak_eff_64v4={eff:.3f},speedup_vs_sfs@64={fs[64]/sfs[64]:.2f}")
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
