"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

``split_stages`` reshapes every scanned-parameter leaf ``(L, ...)`` into
``(S, L/S, ...)`` so stage ``s`` owns layer group ``s``. ``pipeline_apply``
runs the classic microbatch schedule: M microbatches flow through S stages
in M + S - 1 ticks; stage 0 injects a fresh microbatch each tick, every
stage applies its layer group, activations shift one stage forward via
``ppermute``, and the last stage collects results. The bubble fraction is
(S-1)/(M+S-1), as in the paper (Huang et al., 2019).

The stage function must be shape- and dtype-preserving on activations
(hidden-state in, hidden-state out), which is what a layer group is.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map


def split_stages(params: Any, num_stages: int) -> Any:
    """(L, ...) leaves -> (num_stages, L/num_stages, ...) leaves."""
    def split(x):
        if x.shape[0] % num_stages:
            raise ValueError(
                f"leading dim {x.shape[0]} not divisible by {num_stages} stages")
        return x.reshape((num_stages, x.shape[0] // num_stages) + x.shape[1:])
    return jax.tree.map(split, params)


def pipeline_apply(fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   staged_params: Any, x: jnp.ndarray, *,
                   mesh, microbatches: int,
                   stage_axis: str = "stage") -> jnp.ndarray:
    """Apply ``fn(stage_params, h) -> h`` through all stages of ``mesh``.

    ``staged_params`` leaves carry a leading stage dim (from
    :func:`split_stages`); ``x`` is the full batch, split into
    ``microbatches`` along dim 0 (must divide the batch).
    """
    num_stages = mesh.shape[stage_axis]
    batch = x.shape[0]
    if batch % microbatches:
        raise ValueError(f"batch {batch} not divisible by {microbatches}")
    xs = x.reshape((microbatches, batch // microbatches) + x.shape[1:])
    shift = [(i, i + 1) for i in range(num_stages - 1)]

    def local_fn(params, xs):
        params = jax.tree.map(lambda a: a[0], params)   # drop local stage dim
        stage = lax.axis_index(stage_axis)
        acts0 = jnp.zeros(xs.shape[1:], xs.dtype)
        out0 = jnp.zeros(xs.shape, xs.dtype)

        def tick(carry, t):
            acts, out = carry
            inject = xs[jnp.clip(t, 0, microbatches - 1)]
            h = jnp.where(stage == 0, inject, acts)
            y = fn(params, h)
            idx = t - (num_stages - 1)                  # microbatch draining
            collect = (stage == num_stages - 1) & (idx >= 0)
            out = jnp.where(collect, out.at[jnp.clip(idx, 0)].set(y), out)
            y = lax.ppermute(y, stage_axis, shift)      # hand to next stage
            return (y, out), None

        ticks = jnp.arange(microbatches + num_stages - 1)
        (_, out), _ = lax.scan(tick, (acts0, out0), ticks)
        # only the last stage holds real outputs; replicate them everywhere
        keep = (stage == num_stages - 1).astype(out.dtype)
        return lax.psum(out * keep, stage_axis)

    stage_spec = jax.tree.map(lambda _: P(stage_axis), staged_params)
    result = shard_map(local_fn, mesh=mesh,
                       in_specs=(stage_spec, P()), out_specs=P(),
                       check_vma=False)(staged_params, xs)
    return result.reshape((batch,) + x.shape[1:])
