"""Distribution layer: sharding rules + pipeline parallelism.

  sharding      ShardingRules / make_rules — divisibility-driven specs for
                batches, activations, expert blocks, and parameter trees
  pipeline_par  GPipe-style microbatch pipelining over a 'stage' mesh axis
"""
from repro.dist.sharding import ShardingRules, make_rules
from repro.dist.pipeline_par import pipeline_apply, split_stages

__all__ = ["ShardingRules", "make_rules", "pipeline_apply", "split_stages"]
