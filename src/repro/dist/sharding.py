"""Sharding rules: divisibility-driven placement for batches, activations,
expert blocks, and parameter trees.

One policy object serves every (arch x shape x mesh) cell of the dry-run
grid, so nothing here is arch-specific: every decision is made from shapes
and mesh-axis divisibility at trace time.

  * batch dim takes the data axes when divisible; otherwise the sequence
    dim does (the long-context, batch=1 case) — mirroring the cache policy
    in :mod:`repro.serve.kvcache`;
  * activation hidden dim takes the model axis when divisible;
  * expert blocks (E, cap, D) are expert-parallel over the model axis when
    E divides, else model-parallel inside the expert FFN (see
    repro.models.moe);
  * parameter leaves shard exactly one dim on the model axis — the last
    divisible one, skipping the scan-over-layers leading dim — and stay
    replicated over the data axes (grads are synced by the train step).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    dp_axes: Tuple[str, ...]
    tp_axis: str = "model"
    seq_shard: bool = False

    @property
    def dp_size(self) -> int:
        size = 1
        for a in self.dp_axes:
            size *= self.mesh.shape[a]
        return size

    @property
    def tp_size(self) -> int:
        return self.mesh.shape.get(self.tp_axis, 1)

    # ---- batches -----------------------------------------------------------
    def batch_spec(self, kind: str, global_batch: int,
                   seq_len: Optional[int] = None) -> P:
        """Spec for a (B, T, ...) input batch."""
        if not self.dp_axes or self.dp_size == 1:
            return P()
        if self.seq_shard and seq_len and seq_len % self.dp_size == 0 \
                and kind != "decode":
            return P(None, self.dp_axes)
        if global_batch % self.dp_size == 0:
            return P(self.dp_axes)
        if seq_len and seq_len % self.dp_size == 0 and kind != "decode":
            return P(None, self.dp_axes)
        return P()

    # ---- activations -------------------------------------------------------
    def _tp_if(self, n: int):
        return self.tp_axis if self.tp_size > 1 and n % self.tp_size == 0 \
            else None

    def act_constraint(self, x: jnp.ndarray) -> jnp.ndarray:
        """Pin (B, ..., D) activations: batch on data, hidden on model."""
        if x.ndim < 2:
            return x
        dp = self.dp_axes if (self.dp_axes and
                              x.shape[0] % self.dp_size == 0) else None
        spec = [dp] + [None] * (x.ndim - 2) + [self._tp_if(x.shape[-1])]
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def expert_constraint(self, x: jnp.ndarray) -> jnp.ndarray:
        """Pin (E, cap, D) expert blocks: EP over model when E divides."""
        if x.ndim != 3:
            return x
        if self.tp_size > 1 and x.shape[0] % self.tp_size == 0:
            spec = P(self.tp_axis, None, None)
        else:
            spec = P(None, None, self._tp_if(x.shape[-1]))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    # ---- parameters --------------------------------------------------------
    def _param_spec(self, shape: Tuple[int, ...]) -> P:
        spec = [None] * len(shape)
        if self.tp_size <= 1 or not shape:
            return P(*spec)
        # skip the leading dim of scanned stacks (rank >= 3: (L, ..., ...));
        # shard the last dim divisible by the model-axis size
        first = 1 if len(shape) >= 3 else 0
        for d in range(len(shape) - 1, first - 1, -1):
            if shape[d] % self.tp_size == 0 and shape[d] >= self.tp_size:
                spec[d] = self.tp_axis
                break
        return P(*spec)

    def params_shardings(self, shapes: Any, cfg: Any = None) -> Any:
        """NamedSharding pytree aligned with a ShapeDtypeStruct pytree."""
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, self._param_spec(s.shape)),
            shapes)


def make_rules(mesh: Mesh, *, seq_shard: bool = False,
               tp_axis: str = "model") -> ShardingRules:
    """Data axes = every mesh axis except the model axis (pod included)."""
    dp = tuple(a for a in mesh.axis_names if a != tp_axis)
    return ShardingRules(mesh=mesh, dp_axes=dp, tp_axis=tp_axis,
                         seq_shard=seq_shard)
