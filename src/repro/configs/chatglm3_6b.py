"""chatglm3-6b [dense] — GLM block with 2d (half-dim) RoPE, GQA kv=2.

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 [arXiv:2406.12793].
kv=2 does not divide the 16-way model axis -> KV heads replicate under TP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, vocab_size=65024,
    num_heads=32, num_kv_heads=2, head_dim=128,
    d_ff=13696, rope="half", rope_theta=10_000.0, qkv_bias=True,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, vocab_size=128,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128)
