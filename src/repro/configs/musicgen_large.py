"""musicgen-large [audio] — decoder-only LM over EnCodec tokens.

48L d_model=2048 32H d_ff=8192 vocab=2048, 4 codebooks [arXiv:2306.05284].
The EnCodec frontend is a STUB: input_specs() supplies the (B, T, 4) token
grid directly (delay-pattern flattening is a host-side detail).
GELU MLP + LayerNorm, sinusoidal positions via rope="none".
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, vocab_size=2048,
    num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, mlp="gelu", norm="ln", rope="none",
    num_codebooks=4,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, vocab_size=64,
                      num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                      num_codebooks=4)
