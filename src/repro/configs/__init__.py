"""Config registry: one module per assigned architecture.

``get_config(name)`` returns the full published config; ``get_smoke(name)``
returns the reduced same-family config used by CPU smoke tests.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable

_REGISTRY: Dict[str, "module"] = {}

ARCH_IDS: List[str] = [
    "falcon-mamba-7b",
    "granite-moe-3b-a800m",
    "deepseek-v2-236b",
    "musicgen-large",
    "internvl2-76b",
    "chatglm3-6b",
    "qwen2-72b",
    "qwen1.5-32b",
    "nemotron-4-15b",
    "hymba-1.5b",
]

_MODULES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "musicgen-large": "musicgen_large",
    "internvl2-76b": "internvl2_76b",
    "chatglm3-6b": "chatglm3_6b",
    "qwen2-72b": "qwen2_72b",
    "qwen1.5-32b": "qwen1_5_32b",
    "nemotron-4-15b": "nemotron_4_15b",
    "hymba-1.5b": "hymba_1_5b",
}


def _module(name: str):
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    if name not in _REGISTRY:
        _REGISTRY[name] = importlib.import_module(
            f"repro.configs.{_MODULES[name]}")
    return _REGISTRY[name]


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "ARCH_IDS", "get_config", "get_smoke"]
