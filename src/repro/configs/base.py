"""Model/config schema shared by every architecture.

One frozen dataclass covers all six families (dense / moe / ssm / hybrid /
audio / vlm); family-specific fields are zero/empty when unused. Configs are
data — models are built from them by ``repro.models.transformer.build_model``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    # attention (0s for attention-free families)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope: str = "full"                # full | half | none
    rope_theta: float = 10_000.0
    window: Optional[int] = None      # sliding-window size (SWA layers)
    global_layers: Tuple[int, ...] = ()   # layer ids with full attention
    attn_logit_softcap: float = 0.0
    # mlp
    d_ff: int = 0
    mlp: str = "swiglu"               # swiglu | gelu | sqrelu
    norm: str = "rms"                 # rms | ln
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    router_aux_coef: float = 0.001
    moe_capacity_factor: float = 1.25
    moe_block_tokens: int = 4096      # token block for blocked dispatch
    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0
    # hybrid (hymba): parallel attn+ssm heads in every layer
    hybrid: bool = False
    # audio (musicgen): decoder over EnCodec codebooks
    num_codebooks: int = 0
    # vlm (internvl): precomputed patch embeddings prepended to text
    num_patches: int = 0
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # training-memory policy
    remat: bool = True
    loss_chunk: int = 2048            # tokens per chunked-CE step
    # attention memory optimizations (§Perf hillclimb; off = paper-period
    # baseline): fold the softmax scale into q (one fewer score-sized
    # materialization) and keep the exp/probs chain in bf16 (f32 stats).
    attn_scale_in_q: bool = False
    attn_probs_bf16: bool = False
    # dry-run cost accounting: unroll every inner scan so cost_analysis sees
    # the full op count (XLA does not multiply while bodies by trip count).
    # Used only by depth-variant compiles; never for the full-depth model.
    unroll: bool = False

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM state or windowed attn)"""
        return self.family in ("ssm", "hybrid")

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests (same family/topology, tiny dims)."""
        return replace(self, **overrides)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered and with which step."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is this (arch x shape) cell runnable? (DESIGN.md §Arch-applicability)"""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 524k-context decode is "
                       "quadratic-cost; run only for ssm/hybrid")
    return True, ""
