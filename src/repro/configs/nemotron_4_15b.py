"""nemotron-4-15b [dense] — squared-ReLU MLP, LayerNorm, GQA.

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000 [arXiv:2402.16819].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, vocab_size=256000,
    num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=24576, mlp="sqrelu", norm="ln", rope="full", rope_theta=10_000.0,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, vocab_size=256,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128)
