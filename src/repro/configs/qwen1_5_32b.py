"""qwen1.5-32b [dense] — QKV bias, MHA-ish GQA (kv=40 == heads).

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064 [hf:Qwen/Qwen1.5].
40 heads do not divide the 16-way model axis -> GSPMD uneven head sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, vocab_size=152064,
    num_heads=40, num_kv_heads=40, head_dim=128,
    d_ff=27392, qkv_bias=True, rope="full", rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, vocab_size=128,
                      num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128)
