"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676]. SWA (1024) everywhere except 3 full-attention layers
(first / middle / last) -> sub-quadratic, long_500k RUNS.
25 heads do not divide the 16-way model axis -> uneven head sharding.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", hybrid=True,
    num_layers=32, d_model=1600, vocab_size=32001,
    num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, window=1024, global_layers=(0, 15, 31),
    ssm_state=16, ssm_conv=4, ssm_expand=2, dt_rank=100,
    rope="full", rope_theta=10_000.0,
)

SMOKE = CONFIG.scaled(num_layers=4, d_model=64, vocab_size=128,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      window=32, global_layers=(0, 3), dt_rank=8)
