"""falcon-mamba-7b [ssm] — attention-free Mamba-1 LM.

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16 [arXiv:2410.05355].
Pure SSM decode is O(1)/token, so the long_500k cell RUNS for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2, dt_rank=256,
    tie_embeddings=True,
)

SMOKE = CONFIG.scaled(num_layers=4, d_model=64, vocab_size=128, dt_rank=8)
