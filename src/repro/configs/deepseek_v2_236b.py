"""deepseek-v2-236b [moe] — MLA + fine-grained MoE.

60L d_model=5120 128H, MLA kv_lora=512 (qk_nope=128 qk_rope=64 v=128,
q_lora=1536), 2 shared + 160 routed experts top-6, expert d_ff=1536,
first layer dense (d_ff 12288), vocab=102400 [arXiv:2405.04434].
160 experts divide the 16-way model axis -> EP (10 experts/shard).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, vocab_size=102400,
    num_heads=128, num_kv_heads=128, head_dim=192,   # qk head (nope+rope)
    d_ff=12288,                                      # the first dense layer
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    num_experts=160, experts_top_k=6, num_shared_experts=2, moe_d_ff=1536,
    first_dense_layers=1,
    rope="full", rope_theta=10_000.0,
)

SMOKE = CONFIG.scaled(num_layers=3, d_model=64, vocab_size=128,
                      num_heads=4, num_kv_heads=4, head_dim=24, d_ff=128,
                      q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                      num_experts=8, experts_top_k=2, num_shared_experts=1,
                      moe_d_ff=32, moe_block_tokens=64)
