"""internvl2-76b [vlm] — InternViT frontend (STUB) + 76B LM backbone.

Backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[arXiv:2404.16821]. input_specs() provides precomputed patch embeddings
(B, num_patches, d_model); the model prepends them through a connector
projection and trains CE on text positions only.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, vocab_size=128256,
    num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, rope="full", rope_theta=500_000.0,
    num_patches=256,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, vocab_size=128,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      num_patches=8)
