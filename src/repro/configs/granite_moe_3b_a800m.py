"""granite-moe-3b-a800m [moe] — IBM Granite 3.0 MoE.

32L d_model=1536 24H (GQA kv=8) expert_d_ff=512 vocab=49155, 40 experts
top-8 [hf:ibm-granite]. Note: the assignment lists "MoE 40e top-8" and
"32 experts" in two places; we follow the first (40 routed experts).
40 does not divide the 16-way model axis -> TP-inside-expert sharding
(d_ff=512 divides 16); see dist/sharding.py.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, vocab_size=49155,
    num_heads=24, num_kv_heads=8, head_dim=64,
    num_experts=40, experts_top_k=8, moe_d_ff=512,
    rope="full", rope_theta=10_000.0, tie_embeddings=True,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=64, vocab_size=128,
                      num_heads=4, num_kv_heads=2, head_dim=16,
                      num_experts=8, experts_top_k=2, moe_d_ff=32,
                      moe_block_tokens=64)
