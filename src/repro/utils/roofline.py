"""Roofline terms from a compiled dry-run artifact (TPU v5e constants).

  compute term    = HLO_FLOPs / peak_FLOPs            (per device)
  memory term     = HLO_bytes / HBM_bw                (per device)
  collective term = wire_bytes / link_bw              (per device)

cost_analysis() on the SPMD-partitioned module reports per-device FLOPs and
bytes. Collective wire bytes are parsed from the partitioned HLO text:
per-op local shapes x a ring-algorithm wire factor per collective kind
(all-reduce moves ~2x its local payload; gather/scatter/all-to-all ~1x; a
collective-permute exactly 1x). Replica-group size D refines (D-1)/D.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (one active ICI link, conservative)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _op_kind(line: str) -> Optional[str]:
    m = re.search(r"=\s+(?:\([^)]*\)|\S+)\s+([\w-]+)\(", line)
    if not m:
        return None
    op = m.group(1).rstrip(".0123456789")
    for kind in COLLECTIVE_KINDS:
        if op.startswith(kind):
            return kind
    return None


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    wire_bytes: float = 0.0
    count: int = 0

    def add(self, kind: str, nbytes: int, wire: float) -> None:
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + nbytes
        self.wire_bytes += wire
        self.count += 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device collective payloads from partitioned HLO text.

    The *result* region (everything between '=' and the op name) is summed —
    collectives may return tuples (shard_map all_to_all lowers to a 16-ary
    tuple op), so every shape there counts. Operand shapes are generally
    printed as operand *names*, so per-kind wire factors are derived from
    the result: a reduce-scatter's input is result x D, an all-gather's
    result is already the gathered full, etc.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        kind = _op_kind(s)
        if kind is None or s.startswith("//") or "-done" in s:
            continue
        cut = s.find(f" {kind}")
        result_region = s[:cut] if cut > 0 else s
        shapes = _SHAPE_RE.findall(result_region)
        if not shapes:
            continue
        result_b = sum(_shape_bytes(*sh) for sh in shapes
                       if sh[0] in _DTYPE_BYTES)
        d = _group_size(s)
        frac = (d - 1) / d if d > 1 else 1.0
        if kind == "all-reduce":
            wire = 2.0 * result_b * frac
            nbytes = result_b
        elif kind == "all-gather":
            wire = result_b * frac          # result is the gathered full
            nbytes = result_b
        elif kind == "reduce-scatter":
            operand_b = result_b * d        # input is D x the scattered out
            wire = operand_b * frac
            nbytes = operand_b
        elif kind == "all-to-all":
            wire = result_b * frac          # tuple in == tuple out
            nbytes = result_b
        else:  # collective-permute
            wire = float(result_b)
            nbytes = result_b
        stats.add(kind, nbytes, wire)
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_global: float
    collectives: Dict[str, int]
    peak_memory_bytes: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips x peak x bound step time)."""
        t = self.step_time_lower_bound_s
        if t <= 0:
            return 0.0
        return self.model_flops_global / (self.chips * PEAK_FLOPS * t)

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu,
            "collectives": self.collectives,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def build_report(*, arch: str, shape: str, mesh_name: str, chips: int,
                 cost: Dict, hlo_text: str, model_flops_global: float,
                 peak_memory: Optional[float] = None) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    stats = parse_collectives(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=nbytes,
        wire_bytes_per_device=stats.wire_bytes,
        compute_s=flops / PEAK_FLOPS,
        memory_s=nbytes / HBM_BW,
        collective_s=stats.wire_bytes / LINK_BW,
        model_flops_global=model_flops_global,
        collectives=dict(stats.bytes_by_kind),
        peak_memory_bytes=peak_memory,
    )
