"""Version-compatibility shims for jax APIs that moved between releases.

``jax.shard_map`` is the stable entry point from jax 0.6 on; older releases
(this container ships 0.4.37) only have ``jax.experimental.shard_map`` with
the pre-rename keyword surface (``check_rep`` instead of ``check_vma``,
``auto`` instead of ``axis_names``). All repo code calls this wrapper with
the *new* keyword names.
"""
from __future__ import annotations

from typing import Optional, Set

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names: Optional[Set] = None):
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    # Old jax: partial-manual mode (`auto=` complement of axis_names) hits an
    # XLA crash (Check failed: sharding.IsManualSubgroup) at 0.4.x, so the
    # fallback treats every mesh axis as manual and axis_names is effectively
    # ignored. That is semantically equivalent for functions whose in/out
    # specs are replicated over the would-be-auto axes (all current in-repo
    # callers); a function that instead relies on the compiler to partition
    # those axes (e.g. an internal with_sharding_constraint naming them)
    # computes redundantly per shard on old jax.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
