"""Sharded, atomic, resumable checkpointing (the paper's §5.6 substrate).

Layout: ``<dir>/step_<N>/`` holding one ``arrays.npz`` (flattened pytree,
key = joined path) + ``manifest.json`` (step, pytree structure, sampler
cursor, wall time). Writes go to ``step_<N>.tmp`` then ``os.rename`` so a
crash mid-write never corrupts the latest checkpoint — users resume from
the newest complete manifest, exactly the paper's recommended recovery
story. An async writer thread keeps the train loop off the write path;
``keep`` bounds retained checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for k in path:
            if hasattr(k, "key"):
                keys.append(str(k.key))
            elif hasattr(k, "idx"):
                keys.append(str(k.idx))
            else:
                keys.append(str(k))
        flat["/".join(keys)] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(ckpt_dir: str, step: int, state: Any, *,
                    extra: Optional[Dict] = None) -> str:
    """Atomic checkpoint write; returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten_with_names(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "keys": sorted(arrays),
                "time": time.time(), "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_checkpoints(ckpt_dir: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, name)
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(full, "manifest.json")):
            out.append((int(name.split("_")[1]), full))
    return sorted(out)


def restore_checkpoint(ckpt_dir: str, target: Any, *,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target``; returns (state, manifest).

    ``shardings``: optional matching pytree of NamedShardings for placement.
    """
    ckpts = list_checkpoints(ckpt_dir)
    if not ckpts:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    if step is None:
        step, path = ckpts[-1]
    else:
        match = [p for s, p in ckpts if s == step]
        if not match:
            raise FileNotFoundError(f"step {step} not in {ckpt_dir}")
        path = match[0]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat_target = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for p, leaf in flat_target[0]:
        keys = []
        for k in p:
            if hasattr(k, "key"):
                keys.append(str(k.key))
            elif hasattr(k, "idx"):
                keys.append(str(k.idx))
            else:
                keys.append(str(k))
        name = "/".join(keys)
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = arrays[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings)
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, sh_leaves)]
    else:
        leaves = [jax.device_put(a) for a in leaves]
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves)
    return state, manifest


class CheckpointManager:
    """Async writer + retention. save() returns immediately."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, state: Any, *, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def _write():
            try:
                save_checkpoint(self.ckpt_dir, step, host_state, extra=extra)
                self._gc()
            except BaseException as e:
                self._err = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _gc(self) -> None:
        ckpts = list_checkpoints(self.ckpt_dir)
        for _, path in ckpts[:-self.keep]:
            shutil.rmtree(path, ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        ckpts = list_checkpoints(self.ckpt_dir)
        return ckpts[-1][0] if ckpts else None
