"""Sharded, atomic, resumable checkpointing (the paper's §5.6 substrate).

Layout: ``<dir>/step_<N>/`` holding one ``arrays.npz`` (flattened pytree,
key = joined path) + ``manifest.json`` (step, pytree structure, sampler
cursor, wall time). Writes go to ``step_<N>.tmp`` then ``os.rename`` so a
crash mid-write never corrupts the latest checkpoint — users resume from
the newest complete manifest, exactly the paper's recommended recovery
story. An async writer thread keeps the train loop off the write path;
``keep`` bounds retained checkpoints.

Beyond the on-disk path, checkpoints can stream through the FanStore
engine itself (``save_to_session``/``restore_from_session``): one shard
per pytree leaf written via :class:`repro.fanstore.api.CheckpointWriter`,
so shard bytes ride the concurrent write lane to their placement owners
(overlapping prefetch/compute) and the manifest — written LAST — is the
commit marker, mirroring the atomic-rename story. Restores are one
batched ``read_many`` (one round trip per owner).
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for k in path:
            if hasattr(k, "key"):
                keys.append(str(k.key))
            elif hasattr(k, "idx"):
                keys.append(str(k.idx))
            else:
                keys.append(str(k))
        flat["/".join(keys)] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(ckpt_dir: str, step: int, state: Any, *,
                    extra: Optional[Dict] = None) -> str:
    """Atomic checkpoint write; returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten_with_names(state)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "keys": sorted(arrays),
                "time": time.time(), "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_checkpoints(ckpt_dir: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, name)
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(full, "manifest.json")):
            out.append((int(name.split("_")[1]), full))
    return sorted(out)


def restore_checkpoint(ckpt_dir: str, target: Any, *,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target``; returns (state, manifest).

    ``shardings``: optional matching pytree of NamedShardings for placement.
    """
    ckpts = list_checkpoints(ckpt_dir)
    if not ckpts:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    if step is None:
        step, path = ckpts[-1]
    else:
        match = [p for s, p in ckpts if s == step]
        if not match:
            raise FileNotFoundError(f"step {step} not in {ckpt_dir}")
        path = match[0]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat_target = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for p, leaf in flat_target[0]:
        keys = []
        for k in p:
            if hasattr(k, "key"):
                keys.append(str(k.key))
            elif hasattr(k, "idx"):
                keys.append(str(k.idx))
            else:
                keys.append(str(k))
        name = "/".join(keys)
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = arrays[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings)
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, sh_leaves)]
    else:
        leaves = [jax.device_put(a) for a in leaves]
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves)
    return state, manifest


# ---- FanStore-session checkpoints (write path through the engine) ----------

def _npy_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def save_to_session(session, step: int, state: Any, *,
                    extra: Optional[Dict] = None, prefix: str = "ckpt",
                    chunk_bytes: int = 1 << 20) -> str:
    """Stream a checkpoint into the FanStore output tier: one shard per
    pytree leaf through the session's :class:`CheckpointWriter` (chunked
    ``write``+``fsync`` on the concurrent write lane), manifest last as
    the commit marker. Returns the checkpoint's store directory.

    FanStore outputs are single-write: saving the same step twice raises
    ``PermissionError`` (checkpoints are immutable once committed).
    """
    root = f"{prefix}/step_{step:08d}"
    arrays = _flatten_with_names(state)
    writer = session.checkpoint_writer(chunk_bytes=chunk_bytes)
    for name in sorted(arrays):
        writer.write_shard(f"{root}/arrays/{name}.npy",
                           _npy_bytes(arrays[name]))
    manifest = {"step": step, "keys": sorted(arrays), "extra": extra or {}}
    writer.write_json(f"{root}/manifest.json", manifest)
    return root


def list_session_checkpoints(session, *, prefix: str = "ckpt"
                             ) -> List[Tuple[int, str]]:
    """Complete (manifest-visible) checkpoints in the store, sorted by step."""
    if not session.exists(prefix):
        return []
    out = []
    for name in session.listdir(prefix):
        if not name.startswith("step_"):
            continue
        full = f"{prefix}/{name}"
        if session.exists(f"{full}/manifest.json"):
            out.append((int(name.split("_")[1]), full))
    return sorted(out)


def restore_from_session(session, target: Any, *, step: Optional[int] = None,
                         prefix: str = "ckpt") -> Tuple[Any, Dict]:
    """Restore a session-written checkpoint into ``target``'s structure.

    All shards are fetched with ONE batched ``read_many`` (one modeled
    round trip per owning node) instead of a per-leaf open/read loop.
    """
    ckpts = list_session_checkpoints(session, prefix=prefix)
    if not ckpts:
        raise FileNotFoundError(f"no checkpoints under {prefix}")
    if step is None:
        step, root = ckpts[-1]
    else:
        match = [p for s, p in ckpts if s == step]
        if not match:
            raise FileNotFoundError(f"step {step} not in {prefix}")
        root = match[0]
    manifest = json.loads(
        session.read_many([f"{root}/manifest.json"])[0].decode())
    shard_paths = [f"{root}/arrays/{k}.npy" for k in manifest["keys"]]
    payloads = session.read_many(shard_paths)
    arrays = {k: np.load(io.BytesIO(p), allow_pickle=False)
              for k, p in zip(manifest["keys"], payloads)}
    flat_target = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    for p, leaf in flat_target[0]:
        keys = []
        for k in p:
            if hasattr(k, "key"):
                keys.append(str(k.key))
            elif hasattr(k, "idx"):
                keys.append(str(k.idx))
            else:
                keys.append(str(k))
        name = "/".join(keys)
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = arrays[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {leaf.shape}")
        leaves.append(jax.device_put(arr))
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves)
    return state, manifest


class CheckpointManager:
    """Async writer + retention. save() returns immediately."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, state: Any, *, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def _write():
            try:
                save_checkpoint(self.ckpt_dir, step, host_state, extra=extra)
                self._gc()
            except BaseException as e:
                self._err = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def _gc(self) -> None:
        ckpts = list_checkpoints(self.ckpt_dir)
        for _, path in ckpts[:-self.keep]:
            shutil.rmtree(path, ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        ckpts = list_checkpoints(self.ckpt_dir)
        return ckpts[-1][0] if ckpts else None
