from repro.train.optimizer import adamw_init, adamw_update, OptimizerConfig, lr_schedule
from repro.train.train_step import make_train_step, TrainState
from repro.train.checkpoint import save_checkpoint, restore_checkpoint, CheckpointManager
from repro.train.grad_comm import compressed_psum, quantize_ef

__all__ = ["adamw_init", "adamw_update", "OptimizerConfig", "lr_schedule",
           "make_train_step", "TrainState", "save_checkpoint",
           "restore_checkpoint", "CheckpointManager", "compressed_psum",
           "quantize_ef"]
