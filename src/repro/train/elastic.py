"""Elastic membership: rebalance plans + batch rescale on node churn.

The paper (§5.6) halts on failure and resumes from a checkpoint because a
smaller world size changes the effective batch (accuracy-sensitive). At
1000+ nodes that policy wastes too much capacity, so this module adds what a
production deployment layers on top:

  * ``RebalancePlan`` — when membership changes, which partitions must move
    or re-replicate, computed from the consistent-hash ring so the moved
    set is O(changed/total), not a full reshuffle;
  * batch handling on shrink: keep the global batch constant by raising the
    per-node microbatch count (grad accumulation), never by shrinking the
    batch — which preserves the convergence contract the paper worries
    about;
  * straggler policy: replicated partitions let reads fail over to the
    least-loaded owner (implemented in fanstore.cluster); the planner here
    decides *what* to re-replicate first (partitions whose replica count
    dropped below target).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.placement import ConsistentHashRing


@dataclass
class RebalancePlan:
    moves: List[Tuple[int, int, int]]      # (partition_id, src_node, dst_node)
    re_replicate: List[Tuple[int, int]]    # (partition_id, new_owner)
    lost_partitions: List[int]             # no surviving replica (need SFS refill)
    total_partitions: int = 0              # denominator for the fractions
    # output tier (committed checkpoints etc.): same repair story as
    # partitions, keyed by path — PR-7 left outputs single-owner, so a
    # node loss used to take its committed outputs with it
    re_replicate_outputs: List[Tuple[str, int]] = field(default_factory=list)
    lost_outputs: List[str] = field(default_factory=list)

    @property
    def bytes_moved_fraction(self) -> float:
        """Fraction of the cluster's partitions this plan moves — the
        consistent-hashing selling point is that this stays O(changed/N)."""
        if not self.moves or not self.total_partitions:
            return 0.0
        return len(self.moves) / self.total_partitions

    @property
    def re_replicate_fraction(self) -> float:
        """Fraction of partitions the plan copies to restore replication."""
        if not self.re_replicate or not self.total_partitions:
            return 0.0
        return len(self.re_replicate) / self.total_partitions


def partition_owners(cluster: FanStoreCluster) -> Dict[int, List[int]]:
    owners: Dict[int, List[int]] = {}
    for nid, node in cluster.nodes.items():
        for pid in node.partition_ids:
            owners.setdefault(pid, []).append(nid)
    return owners


def output_owners(cluster: FanStoreCluster) -> Dict[str, List[int]]:
    """Committed output path -> nodes holding its payload (primary first)."""
    owners: Dict[str, List[int]] = {}
    for path in cluster.output_ns.paths():
        _, loc = cluster.output_ns.lookup(path)
        owners[path] = list(loc.all_owners)
    return owners


def plan_rebalance(cluster: FanStoreCluster, *, target_replication: int = 1
                   ) -> RebalancePlan:
    """Plan repair after failures: restore every partition AND committed
    output to the target replica count using surviving copies, spreading
    load by ring order."""
    owners = partition_owners(cluster)
    live = set(cluster.live_nodes())
    ring = ConsistentHashRing(sorted(live))
    re_rep: List[Tuple[int, int]] = []
    lost: List[int] = []
    load: Dict[int, int] = {n: 0 for n in live}
    for nid in live:
        load[nid] = len(cluster.nodes[nid].partition_ids)
    for pid, owns in sorted(owners.items()):
        alive = [o for o in owns if o in live]
        if not alive:
            lost.append(pid)
            continue
        deficit = target_replication - len(alive)
        if deficit <= 0:
            continue
        candidates = ring.owners(f"partition:{pid}", min(len(live), len(live)))
        for c in candidates:
            if deficit == 0:
                break
            if c not in alive:
                re_rep.append((pid, c))
                load[c] += 1
                alive.append(c)
                deficit -= 1
    # output tier: same deficit walk keyed by path (the PR-7 debt — a
    # checkpoint must survive its owner like an input partition does)
    out_rep: List[Tuple[str, int]] = []
    out_lost: List[str] = []
    for path, owns in sorted(output_owners(cluster).items()):
        alive = [o for o in owns if o in live]
        if not alive:
            out_lost.append(path)
            continue
        deficit = target_replication - len(alive)
        if deficit <= 0:
            continue
        for c in ring.owners(f"output:{path}", len(live)):
            if deficit == 0:
                break
            if c not in alive:
                out_rep.append((path, c))
                alive.append(c)
                deficit -= 1
    return RebalancePlan(moves=[], re_replicate=re_rep, lost_partitions=lost,
                         total_partitions=len(owners),
                         re_replicate_outputs=out_rep,
                         lost_outputs=out_lost)


def execute_rebalance(cluster: FanStoreCluster, plan: RebalancePlan) -> int:
    """Execute a plan's re-replication THROUGH the engine: each copy ships
    src -> dst over the transport's write lane
    (``cluster.replicate_partition``), paying real/modeled wire cost, and
    extends the metadata replica sets so failover reads route to the
    restored copy immediately. The least-loaded surviving owner sources
    each copy. Returns copies made; lost partitions (no surviving
    replica) are the caller's problem — they need an SFS refill."""
    owners = partition_owners(cluster)
    live = set(cluster.live_nodes())
    done = 0
    for pid, dst in plan.re_replicate:
        srcs = [o for o in owners.get(pid, []) if o in live and o != dst]
        if not srcs:
            continue
        src = min(srcs, key=lambda o: cluster.clocks[o].serve_s)
        cluster.replicate_partition(pid, src, dst)
        owners.setdefault(pid, []).append(dst)
        done += 1
    out_owners = output_owners(cluster)
    for path, dst in plan.re_replicate_outputs:
        srcs = [o for o in out_owners.get(path, []) if o in live and o != dst]
        if not srcs:
            continue
        src = min(srcs, key=lambda o: cluster.clocks[o].serve_s)
        cluster.replicate_output(path, src, dst)
        out_owners.setdefault(path, []).append(dst)
        done += 1
    return done


def apply_rebalance(cluster: FanStoreCluster, plan: RebalancePlan) -> int:
    """Execute re-replication from surviving owners; returns copies made.
    Delegates to :func:`execute_rebalance` (the engine path: wire cost on
    the write lane + metadata replica-set repair); kept as the historical
    entry point."""
    return execute_rebalance(cluster, plan)


@dataclass
class BatchPlan:
    global_batch: int
    num_workers: int
    per_worker: int
    microbatches: int

    @property
    def effective_batch(self) -> int:
        return self.per_worker * self.num_workers * self.microbatches


def rescale_batch(global_batch: int, old_workers: int, new_workers: int, *,
                  old_microbatches: int = 1) -> BatchPlan:
    """Keep the *global* batch constant across a world-size change.

    Shrink: per-worker slice grows via more grad-accumulation microbatches.
    Grow: microbatches shrink (floor 1). Raises if divisibility breaks.
    """
    if global_batch % new_workers:
        raise ValueError(f"global batch {global_batch} must divide new world "
                         f"{new_workers}")
    total_micro = old_microbatches * old_workers
    new_micro = max(1, total_micro // new_workers)
    per_worker = global_batch // (new_workers * new_micro)
    if per_worker * new_workers * new_micro != global_batch:
        new_micro = 1
        per_worker = global_batch // new_workers
    return BatchPlan(global_batch=global_batch, num_workers=new_workers,
                     per_worker=per_worker, microbatches=new_micro)
