"""Elastic membership: rebalance plans + batch rescale on node churn.

The paper (§5.6) halts on failure and resumes from a checkpoint because a
smaller world size changes the effective batch (accuracy-sensitive). At
1000+ nodes that policy wastes too much capacity, so this module adds what a
production deployment layers on top:

  * ``RebalancePlan`` — when membership changes, which partitions must move
    or re-replicate, computed from the consistent-hash ring so the moved
    set is O(changed/total), not a full reshuffle;
  * batch handling on shrink: keep the global batch constant by raising the
    per-node microbatch count (grad accumulation), never by shrinking the
    batch — which preserves the convergence contract the paper worries
    about;
  * straggler policy: replicated partitions let reads fail over to the
    least-loaded owner (implemented in fanstore.cluster); the planner here
    decides *what* to re-replicate first (partitions whose replica count
    dropped below target).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.placement import ConsistentHashRing


@dataclass
class RebalancePlan:
    moves: List[Tuple[int, int, int]]      # (partition_id, src_node, dst_node)
    re_replicate: List[Tuple[int, int]]    # (partition_id, new_owner)
    lost_partitions: List[int]             # no surviving replica (need SFS refill)

    @property
    def bytes_moved_fraction(self) -> float:
        return 0.0 if not self.moves else len(self.moves)


def partition_owners(cluster: FanStoreCluster) -> Dict[int, List[int]]:
    owners: Dict[int, List[int]] = {}
    for nid, node in cluster.nodes.items():
        for pid in node.partition_ids:
            owners.setdefault(pid, []).append(nid)
    return owners


def plan_rebalance(cluster: FanStoreCluster, *, target_replication: int = 1
                   ) -> RebalancePlan:
    """Plan repair after failures: restore every partition to the target
    replica count using surviving copies, spreading load by ring order."""
    owners = partition_owners(cluster)
    live = set(cluster.live_nodes())
    ring = ConsistentHashRing(sorted(live))
    re_rep: List[Tuple[int, int]] = []
    lost: List[int] = []
    load: Dict[int, int] = {n: 0 for n in live}
    for nid in live:
        load[nid] = len(cluster.nodes[nid].partition_ids)
    for pid, owns in sorted(owners.items()):
        alive = [o for o in owns if o in live]
        if not alive:
            lost.append(pid)
            continue
        deficit = target_replication - len(alive)
        if deficit <= 0:
            continue
        candidates = ring.owners(f"partition:{pid}", min(len(live), len(live)))
        for c in candidates:
            if deficit == 0:
                break
            if c not in alive:
                re_rep.append((pid, c))
                load[c] += 1
                alive.append(c)
                deficit -= 1
    return RebalancePlan(moves=[], re_replicate=re_rep, lost_partitions=lost)


def apply_rebalance(cluster: FanStoreCluster, plan: RebalancePlan) -> int:
    """Execute re-replication from surviving owners; returns copies made."""
    owners = partition_owners(cluster)
    live = set(cluster.live_nodes())
    done = 0
    for pid, dst in plan.re_replicate:
        srcs = [o for o in owners.get(pid, []) if o in live]
        if not srcs:
            continue
        blob = cluster.nodes[srcs[0]]._partitions[pid]
        cluster.nodes[dst].load_partition(pid, blob)
        done += 1
    return done


@dataclass
class BatchPlan:
    global_batch: int
    num_workers: int
    per_worker: int
    microbatches: int

    @property
    def effective_batch(self) -> int:
        return self.per_worker * self.num_workers * self.microbatches


def rescale_batch(global_batch: int, old_workers: int, new_workers: int, *,
                  old_microbatches: int = 1) -> BatchPlan:
    """Keep the *global* batch constant across a world-size change.

    Shrink: per-worker slice grows via more grad-accumulation microbatches.
    Grow: microbatches shrink (floor 1). Raises if divisibility breaks.
    """
    if global_batch % new_workers:
        raise ValueError(f"global batch {global_batch} must divide new world "
                         f"{new_workers}")
    total_micro = old_microbatches * old_workers
    new_micro = max(1, total_micro // new_workers)
    per_worker = global_batch // (new_workers * new_micro)
    if per_worker * new_workers * new_micro != global_batch:
        new_micro = 1
        per_worker = global_batch // new_workers
    return BatchPlan(global_batch=global_batch, num_workers=new_workers,
                     per_worker=per_worker, microbatches=new_micro)
