"""Train-step factory: grad accumulation, remat, and two gradient-sync modes.

  grad_sync="auto"  — GSPMD inserts the (bf16/fp32) gradient all-reduce that
                      falls out of the batch sharding. Paper-faithful
                      baseline: FanStore does not touch gradient traffic.
  grad_sync="int8"  — beyond-paper: the step runs inside shard_map over the
                      data axes (model axis stays GSPMD-auto) and gradients
                      are mean-reduced by repro.train.grad_comm's int8
                      reduce-scatter/all-gather with error feedback. 4x
                      fewer collective bytes than fp32, 2x vs bf16; §Perf
                      quantifies against the roofline collective term.

Microbatching (grad accumulation) runs as a lax.scan over microbatch slices
with fp32 accumulators — compute of microbatch i overlaps XLA's scheduling
of the previous slice's collectives.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.compat import shard_map
from repro.train.grad_comm import make_compressed_psum, _flatten_grads, \
    _unflatten_grads
from repro.train.optimizer import OptimizerConfig, adamw_init, adamw_update


@dataclass
class TrainState:
    params: Any
    opt: Dict
    ef: Optional[jnp.ndarray] = None     # flat error-feedback residual (int8 mode)

    def tree_flatten(self):
        return (self.params, self.opt, self.ef), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def init_state(model, key, opt_cfg: OptimizerConfig, *,
               grad_sync: str = "auto") -> TrainState:
    params = model.init(key)
    opt = adamw_init(params)
    ef = None
    if grad_sync == "int8":
        n = sum(int(p.size) for p in jax.tree.leaves(params))
        ef = jnp.zeros((n,), jnp.float32)
    return TrainState(params=params, opt=opt, ef=ef)


def _microbatch(batch: Dict, m: int) -> Dict:
    def split(x):
        g = x.shape[0]
        return x.reshape(m, g // m, *x.shape[1:])
    return {k: split(v) for k, v in batch.items()}


def _accumulate_grads(loss_fn, params, batch: Dict, m: int):
    """lax.scan over microbatches; returns (mean_loss, mean_grads, aux)."""
    if m == 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, grads, metrics
    micro = _microbatch(batch, m)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def step(carry, mb):
        acc, loss_acc = carry
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
        return (acc, loss_acc + loss), None

    (grads, loss_sum), _ = lax.scan(step, (zeros, jnp.zeros(())), micro)
    inv = 1.0 / m
    grads = jax.tree.map(lambda g: g * inv, grads)
    return loss_sum * inv, grads, {}


def make_train_step(model, opt_cfg: OptimizerConfig, *,
                    mesh: Optional[Mesh] = None,
                    dp_axes: Tuple[str, ...] = ("data",),
                    grad_sync: str = "auto",
                    microbatches: int = 1,
                    loss_fn: Optional[Callable] = None
                    ) -> Callable:
    """Returns ``step(state, batch) -> (state, metrics)`` (jit-able)."""
    base_loss = loss_fn or (lambda p, b: model.loss(p, b))

    def _loss(p, b):
        loss, metrics = base_loss(p, b)
        return loss, metrics

    if grad_sync == "auto":
        def step(state: TrainState, batch: Dict):
            loss, grads, _ = _accumulate_grads(_loss, state.params, batch,
                                               microbatches)
            params, opt, om = adamw_update(opt_cfg, state.params, grads,
                                           state.opt)
            metrics = {"loss": loss, **om}
            return TrainState(params, opt, state.ef), metrics
        return step

    if grad_sync != "int8":
        raise ValueError(grad_sync)
    if mesh is None:
        raise ValueError("int8 grad sync needs the mesh")
    auto_axes = frozenset(a for a in mesh.axis_names if a not in dp_axes)
    ax = dp_axes[0] if len(dp_axes) == 1 else dp_axes
    cp_inner = None  # built lazily inside (needs shard count only)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    world = 1
    for a in dp_axes:
        world *= sizes[a]

    def local_step(state: TrainState, batch: Dict):
        # per-dp-shard gradients: batch is the LOCAL slice in here
        loss, grads, _ = _accumulate_grads(_loss, state.params, batch,
                                           microbatches)
        flat, tdef, shapes = _flatten_grads(grads)
        n = flat.shape[0]
        chunk = -(-n // world)
        pad = chunk * world - n
        flat_p = jnp.pad(flat, (0, pad)).reshape(world, chunk)
        res_p = jnp.pad(state.ef, (0, pad)).reshape(world, chunk)
        from repro.train.grad_comm import quantize_ef
        q, scale, new_res = quantize_ef(flat_p, res_p, axis=-1)
        q_rx = lax.all_to_all(q, ax, 0, 0, tiled=False).reshape(world, chunk)
        s_rx = lax.all_to_all(scale, ax, 0, 0, tiled=False).reshape(world, 1)
        shard = jnp.sum(q_rx.astype(jnp.float32) * s_rx, axis=0)
        q2, scale2, _ = quantize_ef(shard[None], None, axis=-1)
        qg = lax.all_gather(q2[0], ax, tiled=False).reshape(world, chunk)
        sg = lax.all_gather(scale2[0], ax, tiled=False).reshape(world, 1)
        mean = ((qg.astype(jnp.float32) * sg).reshape(-1)[:n]) / world
        grads = _unflatten_grads(mean, tdef, shapes)
        params, opt, om = adamw_update(opt_cfg, state.params, grads, state.opt)
        loss = lax.pmean(loss, ax)
        metrics = {"loss": loss, **om}
        return TrainState(params, opt, new_res.reshape(-1)[:n]), metrics

    def step(state: TrainState, batch: Dict):
        state_specs = TrainState(
            params=jax.tree.map(lambda _: P(), state.params),
            opt=jax.tree.map(lambda _: P(), state.opt),
            ef=P())
        batch_specs = {k: P(dp_axes) for k in batch}
        out = shard_map(
            local_step, mesh=mesh,
            in_specs=(state_specs, batch_specs),
            out_specs=(state_specs, {"loss": P(), "grad_norm": P(), "lr": P()}),
            check_vma=False, axis_names=set(dp_axes))(state, batch)
        return out

    return step
