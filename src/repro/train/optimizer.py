"""AdamW + LR schedules, built from scratch (no optax in this environment).

State is a pytree mirroring params: {m, v} fp32 + scalar step. ZeRO-1 is a
sharding decision, not an algorithm change: ``zero1_shardings`` further
shards the (m, v) trees over the data axis where divisible, which is what
drops optimizer HBM by the DP degree at 512 chips.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"        # cosine | linear | constant
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, cfg.warmup_steps))
    frac = jnp.clip((s - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def adamw_init(params: Any) -> Dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptimizerConfig, params: Any, grads: Any,
                 state: Dict) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else jnp.asarray(1.0)
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.betas
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


def zero1_leaf_sharding(mesh, dp_axes: Tuple[str, ...]):
    """ZeRO-1: returns f(param_named_sharding, leaf) -> opt-state sharding.

    Keeps the parameter's TP spec and additionally shards the first free,
    divisible dim over the data axes — optimizer HBM drops by the DP degree.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in dp_axes:
        dp *= sizes[a]

    def per_leaf(ns: NamedSharding, leaf) -> NamedSharding:
        spec = list(ns.spec)
        spec += [None] * (leaf.ndim - len(spec))
        for i, s in enumerate(spec):
            if s is None and leaf.shape[i] % dp == 0 and leaf.shape[i] >= dp:
                spec[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                break
        return NamedSharding(mesh, P(*spec))

    return per_leaf
