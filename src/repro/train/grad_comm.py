"""Compressed gradient all-reduce (int8 wire) with error feedback.

The all-reduce is decomposed as reduce-scatter + all-gather, both carried
over the wire in int8 (4x fewer collective bytes than fp32, 2x vs bf16):

  1. flatten grads -> (D, chunk) layout; quantize per-chunk (absmax scale,
     error-feedback residual folded in before rounding),
  2. all_to_all the int8 chunks (this IS the reduce-scatter's data motion),
  3. each device sums its received column in fp32 -> its reduced shard,
  4. re-quantize the shard and all_gather int8 + scales,
  5. dequantize, unflatten, divide by D.

Error feedback keeps the quantization *unbiased over time*: the residual
(what rounding lost this step) is added to next step's gradient, which is
what keeps convergence intact at int8 (1-bit Adam lineage). The residual
pytree is threaded through the train step as part of TrainState.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.utils.compat import shard_map


def quantize_ef(x: jnp.ndarray, residual: Optional[jnp.ndarray], *,
                axis: int = -1) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """int8 absmax quantization with error feedback.

    Returns (q int8, scale f32 (per leading slice), new_residual)."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    absmax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.rint(xf / scale), -127, 127).astype(jnp.int8)
    new_res = xf - q.astype(jnp.float32) * scale
    return q, scale, new_res


def _flatten_grads(grads: Any) -> Tuple[jnp.ndarray, Any, list]:
    leaves, tdef = jax.tree.flatten(grads)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    shapes = [(l.shape, l.dtype) for l in leaves]
    return flat, tdef, shapes


def _unflatten_grads(flat: jnp.ndarray, tdef, shapes) -> Any:
    out = []
    off = 0
    for shape, dtype in shapes:
        n = 1
        for s in shape:
            n *= s
        out.append(flat[off: off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(tdef, out)


def make_compressed_psum(mesh: Mesh, axes: Tuple[str, ...]):
    """Build ``cpsum(flat_grads, residual) -> (mean_grads, new_residual)``.

    ``flat_grads``: (N,) fp32, replicated over ``axes`` is WRONG input — it
    must be the *local* (unsummed) gradient, identical shape per device.
    Runs inside shard_map; callers use :func:`compressed_psum` below.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    world = 1
    for a in axes:
        world *= sizes[a]
    ax = axes[0] if len(axes) == 1 else axes

    def local_fn(flat, res):
        n = flat.shape[0]
        chunk = -(-n // world)
        pad = chunk * world - n
        flat_p = jnp.pad(flat, (0, pad)).reshape(world, chunk)
        res_p = jnp.pad(res, (0, pad)).reshape(world, chunk)
        # 1) quantize my contribution per destination chunk (+EF)
        q, scale, new_res = quantize_ef(flat_p, res_p, axis=-1)
        # 2) reduce-scatter data motion: int8 chunks + f32 scales
        q_rx = lax.all_to_all(q, ax, 0, 0, tiled=False).reshape(world, chunk)
        s_rx = lax.all_to_all(scale, ax, 0, 0, tiled=False).reshape(world, 1)
        # 3) local fp32 reduction of my shard
        shard = jnp.sum(q_rx.astype(jnp.float32) * s_rx, axis=0)   # (chunk,)
        # 4) second quantization + all-gather (no EF: error is transient)
        q2, scale2, _ = quantize_ef(shard[None], None, axis=-1)
        qg = lax.all_gather(q2[0], ax, tiled=False).reshape(world, chunk)
        sg = lax.all_gather(scale2[0], ax, tiled=False).reshape(world, 1)
        total = (qg.astype(jnp.float32) * sg).reshape(-1)[:n]
        return total / world, new_res.reshape(-1)[:n]

    return shard_map(local_fn, mesh=mesh,
                     in_specs=(P(), P()), out_specs=(P(), P()),
                     check_vma=False)


def compressed_psum(grads: Any, residual: Any, mesh: Mesh,
                    axes: Tuple[str, ...]) -> Tuple[Any, Any]:
    """Mean-reduce a gradient pytree over ``axes`` with an int8 wire.

    ``residual``: same-structure fp32 pytree (error feedback), or zeros.
    NOTE: inputs must be unreduced per-device gradients with identical
    pytree structure; use inside jit under the mesh.
    """
    flat, tdef, shapes = _flatten_grads(grads)
    res_flat, _, _ = _flatten_grads(residual)
    cpsum = make_compressed_psum(mesh, axes)
    out, new_res = cpsum(flat, res_flat)
    return _unflatten_grads(out, tdef, shapes), \
        _unflatten_grads(new_res, tdef, [(s, jnp.float32) for s, _ in shapes])
