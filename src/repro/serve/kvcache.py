"""Cache placement: ShapeDtypeStructs + shardings for every cache family.

Four cache layouts exist across the assigned archs (DESIGN.md §6):
  full KV        (L, B, S, KV, dh)   dense/moe attention
  sliding KV     ring buffer, S=window
  MLA latent     (L, B, S, kv_lora) + (L, B, S, qk_rope)
  SSM state      (L, B, d_inner, ssm_state) + conv window

Sharding policy: batch over the data axes when divisible; otherwise the
sequence dim of seq-bearing caches takes the data axes (the long_500k,
batch=1 case). Head/channel dims take the model axis when divisible.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingRules


def cache_specs(model, batch: int, max_len: int) -> List[Dict]:
    """ShapeDtypeStruct pytree matching model.init_cache (no allocation)."""
    caches = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    return caches


def _spec_for(key: str, shape, rules: ShardingRules) -> P:
    dp, tp = rules.dp_axes, rules.tp_axis
    bdiv = shape[1] % rules.dp_size == 0
    def tp_if(n):
        return tp if n % rules.tp_size == 0 else None
    if key in ("k", "v"):                      # (L, B, S, KV, dh)
        if bdiv:
            return P(None, dp, None, tp_if(shape[3]), None)
        return P(None, None, dp, tp_if(shape[3]), None)
    if key in ("c_kv", "k_rope"):              # (L, B, S, R)
        if bdiv:
            return P(None, dp, None, None)
        return P(None, None, dp, None)
    if key == "h":                             # (L, B, di, st)
        return P(None, dp if bdiv else None, tp_if(shape[2]), None)
    if key == "conv":                          # (L, B, K-1, di)
        return P(None, dp if bdiv else None, None, tp_if(shape[3]))
    return P(*([None] * len(shape)))


def cache_shardings(model, batch: int, max_len: int, rules: ShardingRules
                    ) -> List[Dict]:
    """NamedSharding pytree aligned with init_cache's structure."""
    shapes = cache_specs(model, batch, max_len)
    out: List[Dict] = []
    for seg in shapes:
        out.append({k: NamedSharding(rules.mesh, _spec_for(k, v.shape, rules))
                    for k, v in seg.items()})
    return out
