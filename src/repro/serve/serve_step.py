"""Serving entry points: prefill / decode step factories + a generate loop.

``decode_*`` input shapes lower these (not train_step): decode is one new
token against a cache of seq_len entries. The decode step is memory-bound
(reads the whole cache + all params per token) — the roofline table shows
its memory term dominating for every dense arch, and the MLA/SSM caches
shrinking it; that contrast is one of the three §Perf hillclimb cells.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


def make_prefill_step(model: Model, max_len: int) -> Callable:
    """(params, batch) -> (last-position logits, caches)."""
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill


def make_decode_step(model: Model, *, sample: str = "greedy",
                     temperature: float = 1.0) -> Callable:
    """(params, tokens, caches, cache_len[, key]) -> (next_token, logits, caches)."""
    def decode(params, tokens, caches, cache_len, key=None):
        logits, caches = model.decode_step(params, tokens, caches, cache_len)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                key, logits.astype(jnp.float32) / temperature, axis=-1
            ).astype(jnp.int32)
        return nxt, logits, caches
    return decode


def generate(model: Model, params, prompt: Dict, *, steps: int,
             max_len: Optional[int] = None, sample: str = "greedy",
             key=None) -> jnp.ndarray:
    """Batched greedy/sampled generation. Returns (B, steps[, C]) tokens."""
    cfg = model.cfg
    tokens = prompt["tokens"]
    b, t = tokens.shape[0], tokens.shape[1]
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    max_len = max_len or (prefix + t + steps)
    prefill = jax.jit(make_prefill_step(model, max_len))
    decode = jax.jit(make_decode_step(model, sample=sample))
    logits, caches = prefill(params, prompt)
    if cfg.family == "audio":
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, C)
        nxt = nxt[:, None, :]
    else:
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [nxt]
    cache_len = prefix + t
    for s in range(steps - 1):
        if key is not None:
            key, sub = jax.random.split(key)
        else:
            sub = None
        tok, logits, caches = decode(params, nxt, caches,
                                     jnp.int32(cache_len), sub)
        nxt = tok[:, None, :] if cfg.family == "audio" else tok[:, None]
        out.append(nxt)
        cache_len += 1
    return jnp.concatenate(out, axis=1)
