from repro.serve.serve_step import make_prefill_step, make_decode_step, generate
from repro.serve.kvcache import cache_specs, cache_shardings

__all__ = ["make_prefill_step", "make_decode_step", "generate",
           "cache_specs", "cache_shardings"]
