"""Device-resident FanStore — the paper's idea mapped onto a TPU pod.

The paper aggregates node-local SSDs into one transient store and serves
random sample access over the fabric. On a TPU pod the fast local tier is
HBM and the fabric is ICI, so:

  * ``device_store``  — the dataset packed to fixed-size sample records and
    sharded across the mesh (data x model axes; replicated or sharded over
    pods = the paper's replication factor).
  * ``fetch``         — per-step batched sample exchange: one capacity-bounded
    ``all_to_all`` replaces the paper's per-file MPI round trips.
  * ``codec``         — fixed-rate block quantization (the TPU-idiomatic
    stand-in for LZSS; decode is a Pallas kernel at HBM bandwidth).
"""
from repro.core.device_store import DeviceStore, DeviceStoreConfig
from repro.core.fetch import make_fetch_fn, tokens_from_payload
from repro.core.codec import block_quantize, block_dequantize_host

__all__ = ["DeviceStore", "DeviceStoreConfig", "make_fetch_fn",
           "tokens_from_payload", "block_quantize", "block_dequantize_host"]
