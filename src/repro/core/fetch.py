"""Batched sample fetch over the mesh — the FanStore data plane on ICI.

Semantics: the dataset is an (S, B) array of fixed-size sample records,
sharded (S over ``data``, B over ``model``); a step's global batch is a
vector of G sample indices sharded over (``pod``, ``data``). ``fetch``
returns the (G, B) payload batch with the same index order, sharded
(G over (pod, data), B over model).

Routing is MoE-style dispatch with storage shards as "experts":

  1. all_gather the request ids within the data axis (tiny: G ints),
  2. every shard gathers the records it owns for every requester and
     scatters them into a (D, capacity, B/M) send buffer,
  3. one all_to_all flips owner->requester,
  4. requesters scatter received records into batch-slot order.

Capacity: with uniform-random requests, each (owner, requester) pair gets
Binomial(G/D, 1/D) records; ``capacity_factor`` pads above the mean. The
overflow flag reports drops (training treats it like the paper treats a
failed read: deterministic, observable). The stratified sampler
(repro.data.sampler.StratifiedSampler) guarantees exactly G/D^2 per pair, so
capacity_factor=1.0 gives a zero-waste, zero-drop exchange — the beyond-paper
configuration measured in EXPERIMENTS.md.

Pods: by default the store is replicated per pod (paper's replication factor
R = n_pods) so the exchange never crosses the pod boundary; set
``shard_over_pods=True`` to split S over (pod, data) and let the all_to_all
span both axes (for datasets too large for one pod's HBM).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.compat import shard_map


def required_capacity(local_batch: int, num_shards: int,
                      capacity_factor: float) -> int:
    """Per-(owner,requester) record slots: ceil(cf * G_local / D)."""
    return max(1, math.ceil(capacity_factor * local_batch / num_shards))


def make_fetch_fn(mesh: Mesh, *, num_samples: int, sample_bytes: int,
                  data_axis: str = "data", model_axis: Optional[str] = "model",
                  pod_axis: Optional[str] = None,
                  capacity_factor: float = 2.0,
                  dtype=jnp.uint8):
    """Build a jit-able ``fetch(store, idx) -> (batch, overflow)``.

    store: (S, B) sharded P((pod?, data), model)  [pod only if shard_over_pods]
    idx:   (G,)  int32 sharded P((pod, data))
    batch: (G, B) sharded P((pod, data), model)
    overflow: (num_batch_shards,) bool, one flag per (pod, data) shard.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fetch_axes: Tuple[str, ...] = (data_axis,) if pod_axis is None \
        else (pod_axis, data_axis)
    D = 1
    for a in fetch_axes:
        D *= axis_sizes[a]
    if num_samples % D:
        raise ValueError(f"num_samples {num_samples} must divide {D} shards")
    s_local = num_samples // D

    batch_axes = tuple(a for a in (pod_axis, data_axis) if a is not None) \
        if pod_axis is not None else (data_axis,)
    # When the store is pod-replicated, requests are still pod-sharded: the
    # exchange happens independently inside each pod's replica.
    store_spec = P(fetch_axes if pod_axis is not None else data_axis, model_axis)
    idx_spec = P(batch_axes)
    out_spec = (P(batch_axes, model_axis), P(batch_axes))

    def local_fn(store_l, idx_l):
        # store_l: (s_local, B_local); idx_l: (g_local,)
        g_local = idx_l.shape[0]
        cap = required_capacity(g_local, D, capacity_factor)
        d = lax.axis_index(fetch_axes)           # linearized shard id
        all_req = lax.all_gather(idx_l, fetch_axes, tiled=False)  # (D, g_local)
        all_req = all_req.reshape(D, g_local)
        owner = all_req // s_local                # (D, g_local)
        mine = owner == d
        local_row = jnp.where(mine, all_req - d * s_local, 0)
        payload = jnp.take(store_l, local_row.reshape(-1), axis=0)
        payload = payload.reshape(D, g_local, -1)             # (D, g, B_l)
        pos = jnp.cumsum(mine.astype(jnp.int32), axis=1) - 1  # (D, g)
        slot = jnp.where(mine & (pos < cap), pos, cap)        # cap = drop
        send = jnp.zeros((D, cap) + payload.shape[2:], dtype=payload.dtype)
        send = jax.vmap(lambda b, s, p: b.at[s].set(p, mode="drop"))(
            send, slot, payload)
        col = jnp.broadcast_to(jnp.arange(g_local, dtype=jnp.int32)[None],
                               (D, g_local))
        send_slots = jnp.full((D, cap), -1, jnp.int32)
        send_slots = jax.vmap(lambda b, s, c: b.at[s].set(c, mode="drop"))(
            send_slots, slot, col)
        axis = fetch_axes[0] if len(fetch_axes) == 1 else fetch_axes
        recv = lax.all_to_all(send, axis, 0, 0, tiled=False)
        recv_slots = lax.all_to_all(send_slots, axis, 0, 0, tiled=False)
        out = jnp.zeros((g_local,) + payload.shape[2:], dtype=payload.dtype)
        tgt = jnp.where(recv_slots >= 0, recv_slots, g_local).reshape(-1)
        out = out.at[tgt].set(recv.reshape((-1,) + payload.shape[2:]),
                              mode="drop")
        overflow = (jnp.sum(mine, axis=1) > cap).any()
        return out, overflow[None]

    shmap = shard_map(local_fn, mesh=mesh,
                      in_specs=(store_spec, idx_spec),
                      out_specs=out_spec, check_vma=False)

    def fetch(store: jax.Array, idx: jax.Array):
        return shmap(store, idx)

    fetch.store_spec = store_spec          # type: ignore[attr-defined]
    fetch.idx_spec = idx_spec              # type: ignore[attr-defined]
    fetch.out_specs = out_spec             # type: ignore[attr-defined]
    fetch.num_shards = D                   # type: ignore[attr-defined]
    fetch.samples_per_shard = s_local      # type: ignore[attr-defined]
    return fetch


def tokens_from_payload(batch_u8: jax.Array, seq_len: int) -> jax.Array:
    """Bitcast fetched uint8 payload records to int32 token sequences."""
    b = batch_u8.shape[0]
    return lax.bitcast_convert_type(
        batch_u8.reshape(b, seq_len, 4), jnp.int32).reshape(b, seq_len)
