"""Fixed-rate block quantization — the TPU-idiomatic "compression" tier.

The paper compresses partitions with LZSS: variable-rate, branchy,
decompressed by the CPU at ~GB/s. On a TPU the decompressor must be a dense
vector kernel, so the device tier trades LZSS for per-block absmax int8 (or
packed int4) quantization: fixed 2x/4x ratio (vs the paper's 2.8x on SRGAN),
decode at HBM bandwidth via ``repro.kernels.dequant``.

Encode runs host-side (NumPy) at data-preparation time — exactly where the
paper pays its compression cost (§6.3). ``block_dequantize_host`` is the
NumPy mirror used by tests to cross-check the device kernel.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

BLOCK = 256   # elements per scale block


def block_quantize(x: np.ndarray, *, block: int = BLOCK, bits: int = 8
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Quantize float array -> (int8 payload, float16 per-block scales).

    ``x``: (N, F) float records, F divisible by ``block``.
    Returns payload (N, F) int8 in [-127,127] (or packed int4 (N, F//2)) and
    scales (N, F//block) float16.
    """
    if bits not in (4, 8):
        raise ValueError("bits must be 4 or 8")
    n, f = x.shape
    if f % block:
        raise ValueError(f"feature dim {f} must divide block {block}")
    xb = x.reshape(n, f // block, block).astype(np.float32)
    absmax = np.abs(xb).max(axis=2, keepdims=True)
    qmax = 127.0 if bits == 8 else 7.0
    # round the scale through f16 FIRST so quantization and (f16-scaled)
    # dequantization use the identical scale -> error stays <= scale/2
    scale = np.where(absmax > 0, absmax / qmax, 1.0).astype(np.float16)
    scale = np.maximum(scale, np.float16(6e-8)).astype(np.float32)
    q = np.clip(np.rint(xb / scale), -qmax, qmax).astype(np.int8)
    q = q.reshape(n, f)
    if bits == 4:
        lo = q[:, 0::2] & 0x0F
        hi = (q[:, 1::2] & 0x0F) << 4
        q = (lo | hi).astype(np.int8)
    return q, scale.reshape(n, f // block).astype(np.float16)


def block_dequantize_host(q: np.ndarray, scales: np.ndarray, *,
                          block: int = BLOCK, bits: int = 8) -> np.ndarray:
    """NumPy oracle for the device dequant kernel."""
    n = q.shape[0]
    if bits == 4:
        lo = (q.astype(np.int8) << 4).astype(np.int8) >> 4   # sign-extend
        hi = q.astype(np.int8) >> 4
        full = np.empty((n, q.shape[1] * 2), dtype=np.int8)
        full[:, 0::2] = lo
        full[:, 1::2] = hi
        q = full
    f = q.shape[1]
    xb = q.reshape(n, f // block, block).astype(np.float32)
    return (xb * scales.astype(np.float32)[..., None]).reshape(n, f)
