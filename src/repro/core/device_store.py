"""The HBM-resident dataset shard — FanStore's "local SSD" tier on TPU.

``DeviceStore`` packs a host dataset of fixed-size sample records into a
single (num_samples, sample_bytes) uint8 array and places it on the mesh:

  * samples sharded over the ``data`` axis (and optionally ``pod``),
  * bytes sharded over the ``model`` axis (so TP peers don't duplicate HBM —
    analogous to the paper splitting partitions across nodes),
  * pod-replicated by default = paper's replication factor R (pod count).

Records must be fixed-rate; variable-size files are padded at pack time
(``pad_to``) or block-quantized by :mod:`repro.core.codec` first. The
whole-record fetch mirrors the paper's whole-file sequential reads (§3.4).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fetch import make_fetch_fn


@dataclass(frozen=True)
class DeviceStoreConfig:
    num_samples: int
    sample_bytes: int
    data_axis: str = "data"
    model_axis: Optional[str] = "model"
    pod_axis: Optional[str] = None       # None => replicate store across pods
    capacity_factor: float = 2.0

    def __post_init__(self):
        if self.sample_bytes % 4:
            raise ValueError("sample_bytes must be a multiple of 4 "
                             "(records are bitcast to 4-byte words)")


class DeviceStore:
    """Owns the sharded dataset array + its fetch function."""

    def __init__(self, mesh: Mesh, config: DeviceStoreConfig):
        self.mesh = mesh
        self.config = config
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        m = axis_sizes.get(config.model_axis, 1) if config.model_axis else 1
        if config.sample_bytes % (4 * m):
            raise ValueError(
                f"sample_bytes {config.sample_bytes} must divide 4*model "
                f"axis ({m}) for byte sharding")
        self.fetch = make_fetch_fn(
            mesh, num_samples=config.num_samples,
            sample_bytes=config.sample_bytes,
            data_axis=config.data_axis, model_axis=config.model_axis,
            pod_axis=config.pod_axis,
            capacity_factor=config.capacity_factor)
        self.store_sharding = NamedSharding(mesh, self.fetch.store_spec)
        self.idx_sharding = NamedSharding(mesh, self.fetch.idx_spec)

    # -- placement -------------------------------------------------------------
    def place(self, records: np.ndarray) -> jax.Array:
        """Move (num_samples, sample_bytes) uint8 host records onto the mesh."""
        cfg = self.config
        if records.shape != (cfg.num_samples, cfg.sample_bytes):
            raise ValueError(f"records shape {records.shape} != "
                             f"{(cfg.num_samples, cfg.sample_bytes)}")
        return jax.device_put(np.ascontiguousarray(records, dtype=np.uint8),
                              self.store_sharding)

    def place_tokens(self, tokens: np.ndarray) -> jax.Array:
        """Place an int32 (num_samples, seq_len) token dataset as records."""
        recs = np.ascontiguousarray(tokens, dtype="<i4")
        recs = recs.view(np.uint8).reshape(tokens.shape[0], -1)
        return self.place(recs)

    def specs(self) -> Tuple[jax.ShapeDtypeStruct, jax.ShapeDtypeStruct]:
        """ShapeDtypeStructs for (store, idx) — dry-run stand-ins."""
        cfg = self.config
        store = jax.ShapeDtypeStruct(
            (cfg.num_samples, cfg.sample_bytes), jnp.uint8,
            sharding=self.store_sharding)
        # global batch length is the caller's choice; expose a builder
        return store

    def idx_spec(self, global_batch: int) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((global_batch,), jnp.int32,
                                    sharding=self.idx_sharding)

    @property
    def hbm_bytes_per_device(self) -> int:
        cfg = self.config
        axis_sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        d = axis_sizes[cfg.data_axis]
        if cfg.pod_axis:
            d *= axis_sizes[cfg.pod_axis]
        m = axis_sizes.get(cfg.model_axis, 1) if cfg.model_axis else 1
        return cfg.num_samples * cfg.sample_bytes // (d * m)
