"""Batched serving driver: prefill a prompt batch, decode N tokens.

CPU demo with smoke configs; the same step functions lower for the
production mesh in dryrun.py (decode_32k / long_500k cells).

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import build_model
from repro.serve.serve_step import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--sample", default="greedy", choices=["greedy", "temp"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke if args.preset == "smoke" else get_config)(args.arch)
    cfg = cfg.scaled(remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    if cfg.family == "audio":
        toks = rng.integers(0, cfg.vocab_size,
                            (args.batch, args.prompt_len, cfg.num_codebooks))
    else:
        toks = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
    prompt = {"tokens": jnp.asarray(toks.astype(np.int32))}
    if cfg.family == "vlm":
        prompt["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_patches, cfg.d_model)),
            jnp.bfloat16)

    t0 = time.perf_counter()
    out = generate(model, params, prompt, steps=args.steps,
                   sample=args.sample,
                   key=jax.random.key(args.seed + 1))
    out = jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    tps = args.batch * args.steps / dt
    print(f"{args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s incl. compile)")
    print("first sequence:", np.asarray(out)[0].tolist()[:16])


if __name__ == "__main__":
    main()
