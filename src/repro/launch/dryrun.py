import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax-touching import — jax
# locks the device count on first init (see the multi-pod dry-run contract).
_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the right step function (train_step for train_*,
prefill/decode for serve shapes) against ShapeDtypeStruct inputs — no
allocation anywhere — compiles it for the production mesh, and records:
  * memory_analysis()  (does it fit),
  * cost_analysis()    (FLOPs / bytes for the roofline),
  * the partitioned HLO's collective payloads (wire bytes),
  * the three roofline terms + dominant bottleneck (utils.roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape train_4k --mesh both --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""

import argparse
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import ShardingRules, make_rules
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.serve.kvcache import cache_shardings, cache_specs
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.optimizer import OptimizerConfig, adamw_init
from repro.train.train_step import TrainState, make_train_step
from repro.utils import roofline


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    mesh = rules.mesh
    g, t = shape.global_batch, shape.seq_len
    bspec = NamedSharding(mesh, rules.batch_spec(shape.kind, g, t))
    if shape.kind == "decode":
        tok_shape = (g, 1, cfg.num_codebooks) if cfg.family == "audio" \
            else (g, 1)
        return {"tokens": _sds(tok_shape, jnp.int32, bspec)}
    if cfg.family == "audio":
        return {"tokens": _sds((g, t, cfg.num_codebooks), jnp.int32, bspec)}
    if cfg.family == "vlm":
        t_text = t - cfg.num_patches
        pspec = NamedSharding(mesh, rules.batch_spec(shape.kind, g))
        return {"tokens": _sds((g, t_text), jnp.int32, bspec),
                "patches": _sds((g, cfg.num_patches, cfg.d_model),
                                jnp.bfloat16, pspec)}
    return {"tokens": _sds((g, t), jnp.int32, bspec)}


def _params_specs(model, rules: ShardingRules):
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    shardings = rules.params_shardings(shapes, model.cfg)
    return jax.tree.map(lambda s, sh: _sds(s.shape, s.dtype, sh),
                        shapes, shardings), shardings


def _model_flops(model, shape: ShapeConfig) -> float:
    """6*N_active*D (train), 2*N_active*D (prefill), 2*N_active*B (decode)."""
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    n_active = model.active_param_count(shapes)
    emb = shapes["embed"].size
    n_eff = n_active - emb if not model.cfg.tie_embeddings else n_active
    if shape.kind == "train":
        return 6.0 * n_eff * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_eff * shape.tokens
    return 2.0 * n_eff * shape.global_batch       # decode: 1 token / request


def depth_variant(cfg: ModelConfig, L: int) -> ModelConfig:
    """Same widths/segment structure, reduced depth (cost is affine in L).

    cost_analysis does not multiply while-loop bodies by trip count, so the
    scanned-layer cost of the full model is recovered by compiling two depth
    variants and extrapolating linearly — the fixed segments (first-dense,
    global-attention layers) are held constant so the slope is exactly the
    per-scanned-layer cost. The full-depth compile still provides
    memory_analysis (fit) and the collective schedule.
    """
    overrides: Dict[str, Any] = {"num_layers": L, "unroll": True}
    if cfg.global_layers:
        n = len(cfg.global_layers)
        pos = [0] + [((i * (L - 1)) // (n - 1)) for i in range(1, n - 1)] + [L - 1] \
            if n > 1 else [0]
        overrides["global_layers"] = tuple(sorted(set(pos)))
    return cfg.scaled(**overrides)


def variant_depths(cfg: ModelConfig) -> Tuple[int, int]:
    n_fixed = cfg.first_dense_layers + len(cfg.global_layers)
    la = max(4, n_fixed + 4)
    return la, la + 4


def lower_cell(arch: str, shape_name: str, mesh: Mesh, *,
               grad_sync: str = "auto",
               act_constraints: bool = True,
               cfg: Optional[ModelConfig] = None) -> Tuple[Any, Any, Dict]:
    """Returns (lowered, compiled, info) for one cell."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"cell skipped: {why}")
    seq_shard = shape.kind != "train" and shape.global_batch < 16
    rules = make_rules(mesh, seq_shard=seq_shard)
    # int8 grad sync runs the step inside shard_map over the dp axes: any
    # with_sharding_constraint inside may then only name the model axis.
    model_rules = rules
    if grad_sync == "int8":
        import dataclasses as _dc
        model_rules = _dc.replace(rules, dp_axes=())
    model = build_model(cfg, rules=model_rules if act_constraints else None)
    bspecs = batch_specs(cfg, shape, rules)
    pspecs, pshard = _params_specs(model, rules)

    with mesh:
        if shape.kind == "train":
            ocfg = OptimizerConfig()
            step = make_train_step(model, ocfg, mesh=mesh,
                                   dp_axes=rules.dp_axes,
                                   grad_sync=grad_sync)
            opt_specs = {
                "m": jax.tree.map(lambda s: s, pspecs),
                "v": jax.tree.map(lambda s: s, pspecs),
                "step": _sds((), jnp.int32, NamedSharding(mesh, P())),
            }
            ef = None
            if grad_sync == "int8":
                n = sum(int(p.size) for p in jax.tree.leaves(pspecs))
                ef = _sds((n,), jnp.float32, NamedSharding(mesh, P()))
            state = TrainState(params=pspecs, opt=opt_specs, ef=ef)
            lowered = jax.jit(step).lower(state, bspecs)
        elif shape.kind == "prefill":
            fn = make_prefill_step(model, shape.seq_len)
            lowered = jax.jit(fn).lower(pspecs, bspecs)
        else:  # decode
            fn = model.decode_step
            cshapes = cache_specs(model, shape.global_batch, shape.seq_len)
            cshard = cache_shardings(model, shape.global_batch, shape.seq_len,
                                     rules)
            cspecs = jax.tree.map(lambda s, sh: _sds(s.shape, s.dtype, sh),
                                  cshapes, cshard)
            clen = _sds((), jnp.int32, NamedSharding(mesh, P()))
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                pspecs, bspecs["tokens"], cspecs, clen)
        t0 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0

    info = {"arch": arch, "shape": shape_name, "compile_s": compile_s,
            "chips": mesh.devices.size,
            "mesh": "x".join(str(s) for s in mesh.devices.shape)}
    return lowered, compiled, info


def _mem_dict(compiled) -> Tuple[Dict, Optional[int]]:
    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    memdict: Dict[str, int] = {}
    peak = None
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                memdict[k] = int(v)
        peak = sum(memdict.get(k, 0) for k in ("argument_size_in_bytes",
                                               "output_size_in_bytes",
                                               "temp_size_in_bytes"))
        if "alias_size_in_bytes" in memdict:
            peak -= memdict["alias_size_in_bytes"]
    return memdict, peak


def _cell_costs(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # jax<=0.4 returns [dict]
        cost = cost[0] if cost else {}
    stats = roofline.parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "wire": float(stats.wire_bytes),
            "coll_by_kind": dict(stats.bytes_by_kind)}


def extrapolated_costs(arch: str, shape_name: str, mesh: Mesh, *,
                       grad_sync: str = "auto",
                       cfg_overrides: Optional[Dict] = None
                       ) -> Dict[str, float]:
    """Affine-in-depth extrapolation of cost_analysis to full depth."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.scaled(**cfg_overrides)
    la, lb = variant_depths(cfg)
    costs = {}
    for L in (la, lb):
        _, compiled, _ = lower_cell(arch, shape_name, mesh,
                                    grad_sync=grad_sync,
                                    cfg=depth_variant(cfg, L))
        costs[L] = _cell_costs(compiled)
        del compiled
    lf = cfg.num_layers
    out: Dict[str, Any] = {"variant_depths": [la, lb]}
    for key in ("flops", "bytes", "wire"):
        slope = (costs[lb][key] - costs[la][key]) / (lb - la)
        out[key] = costs[la][key] + (lf - la) * slope
        out[f"{key}_per_layer"] = slope
    kinds = set(costs[la]["coll_by_kind"]) | set(costs[lb]["coll_by_kind"])
    out["coll_by_kind"] = {}
    for k in kinds:
        a = costs[la]["coll_by_kind"].get(k, 0)
        b = costs[lb]["coll_by_kind"].get(k, 0)
        out["coll_by_kind"][k] = int(a + (lf - la) * (b - a) / (lb - la))
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: Optional[str] = None, grad_sync: str = "auto",
             tag: str = "", with_roofline: Optional[bool] = None,
             cfg_overrides: Optional[Dict] = None) -> Dict:
    """Full-depth compile (fit proof) + roofline terms (single-pod cells)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.scaled(**cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    if with_roofline is None:
        with_roofline = not multi_pod     # roofline table is single-pod only
    if not ok:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "skipped": True, "reason": why}
    else:
        model = build_model(cfg)
        mf = _model_flops(model, shape)
        _, compiled, info = lower_cell(arch, shape_name, mesh,
                                       grad_sync=grad_sync, cfg=cfg)
        memdict, peak = _mem_dict(compiled)
        raw = _cell_costs(compiled)
        del compiled
        if with_roofline:
            ext = extrapolated_costs(arch, shape_name, mesh,
                                     grad_sync=grad_sync,
                                     cfg_overrides=cfg_overrides)
            cost = {"flops": ext["flops"], "bytes accessed": ext["bytes"]}
            wire = ext["wire"]
            coll = ext["coll_by_kind"]
        else:
            cost = {"flops": raw["flops"], "bytes accessed": raw["bytes"]}
            wire = raw["wire"]
            coll = raw["coll_by_kind"]
        rep = roofline.RooflineReport(
            arch=arch, shape=shape_name, mesh=info["mesh"],
            chips=info["chips"],
            flops_per_device=cost["flops"],
            bytes_per_device=cost["bytes accessed"],
            wire_bytes_per_device=wire,
            compute_s=cost["flops"] / roofline.PEAK_FLOPS,
            memory_s=cost["bytes accessed"] / roofline.HBM_BW,
            collective_s=wire / roofline.LINK_BW,
            model_flops_global=mf,
            collectives=coll, peak_memory_bytes=peak)
        result = rep.to_dict()
        result["memory_analysis"] = memdict
        result["compile_s"] = info["compile_s"]
        result["grad_sync"] = grad_sync
        result["extrapolated"] = bool(with_roofline)
        result["raw_body_costs"] = raw
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--grad-sync", default="auto", choices=["auto", "int8"])
    ap.add_argument("--opt-attn", action="store_true",
                    help="enable attn_scale_in_q + attn_probs_bf16")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                cell = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                t0 = time.perf_counter()
                try:
                    overrides = ({"attn_scale_in_q": True,
                                  "attn_probs_bf16": True}
                                 if args.opt_attn else None)
                    r = run_cell(arch, shape_name, multi_pod=mp,
                                 out_dir=args.out, grad_sync=args.grad_sync,
                                 tag=args.tag, cfg_overrides=overrides)
                    if r.get("skipped"):
                        print(f"[SKIP] {cell}: {r['reason']}", flush=True)
                    else:
                        print(f"[OK]   {cell}: compile={r['compile_s']:.1f}s "
                              f"dominant={r['dominant']} "
                              f"comp={r['compute_s']*1e3:.2f}ms "
                              f"mem={r['memory_s']*1e3:.2f}ms "
                              f"coll={r['collective_s']*1e3:.2f}ms "
                              f"useful={r['useful_flops_ratio']:.2f}",
                              flush=True)
                except Exception as e:
                    print(f"[FAIL] {cell}: {e}", flush=True)
                    traceback.print_exc()
                print(f"       wall={time.perf_counter()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
