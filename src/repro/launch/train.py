"""End-to-end training driver: FanStore data plane + model + checkpoints.

Runs for real on this CPU container with the reduced (smoke) configs and on
TPU with the full ones — the driver code is identical; only --preset and the
mesh change. Demonstrates the whole system:

  dataset -> fanstore partitions -> ClusterSpec topology (simulated
  nodes x co-located workers, pluggable transport backend via --backend:
  modeled / socket / shm) -> one cluster.connect() FanStoreSession per
  (node, worker) sharing each node's cache tier ->
  PrefetchLoader (threads; --prefetch-schedule switches it to the
  clairvoyant schedule-driven mode: the epoch permutation materialized
  from the sampler's peek_epoch() rides ahead of compute in
  window-coalesced round trips, driven by one PrefetchScheduler per
  (node, worker) — every node keeps its own windows in flight; there is
  no node-0 pin) ->
  [optional device-store all_to_all fetch] ->
  train_step (auto or int8 grad sync) -> CheckpointManager -> resume

Checkpoints can additionally stream through the FanStore engine itself
(--ckpt-fanstore): shards chunk through the session's CheckpointWriter on
the concurrent write lane, so the modeled clocks show checkpoint I/O
overlapped with the data plane instead of serialized in front of it.

Usage (CPU example, ~1 minute):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b \
      --preset smoke --steps 30 --global-batch 16 --seq-len 64
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.data.pipeline import PrefetchLoader
from repro.data.sampler import GlobalUniformSampler, StratifiedSampler
from repro.data.synthetic import files_to_tokens, token_dataset, tokens_to_files
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.metrics import JsonlSink, Reduce
from repro.fanstore.prefetch import EpochSchedule, SchedulerGroup
from repro.fanstore.spec import ClusterSpec
from repro.fanstore.prepare import prepare_dataset
from repro.models import build_model
from repro.train.checkpoint import (CheckpointManager, restore_checkpoint,
                                    save_to_session)
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b", choices=ARCH_IDS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--num-samples", type=int, default=512)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--workers", type=int, default=1,
                    help="co-located training workers per node; each gets "
                         "its own cluster.connect() session (and, under "
                         "--prefetch-schedule, its own loader axis) while "
                         "sharing the node's cache tier")
    ap.add_argument("--replication", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-sync", default="auto", choices=["auto", "int8"])
    ap.add_argument("--sampler", default="uniform",
                    choices=["uniform", "stratified"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-fanstore", action="store_true",
                    help="also stream checkpoint shards through the "
                         "FanStore session write path (concurrent write "
                         "lane, placement-owned outputs)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="stream per-step training metrics (loss mean, "
                         "step-time p99, items/s rate, per-rank read "
                         "bytes) plus the full accounting-ledger bridge "
                         "through the cluster's MetricsCollector to this "
                         "JSONL sink (periodic ticks + a final explicit "
                         "flush)")
    ap.add_argument("--metrics-every", type=float, default=1.0,
                    help="minimum seconds between periodic JSONL "
                         "snapshots (0 = snapshot every step)")
    ap.add_argument("--io-threads", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="modeled",
                    choices=["modeled", "socket", "shm", "rdma"],
                    help="transport backend behind the cluster: the "
                         "modeled interconnect, real TCP serving loops "
                         "(striped/pipelined), the zero-copy shared-"
                         "memory fast path, or one-sided rdma-class "
                         "reads over registered segments")
    ap.add_argument("--prefetch-schedule", action="store_true",
                    help="clairvoyant data plane: materialize the epoch's "
                         "permutation from the sampler's peek_epoch() into "
                         "an EpochSchedule axed per (node, worker) and "
                         "drive PrefetchLoader(schedule=SchedulerGroup) — "
                         "every worker on every node keeps its own "
                         "lookahead windows of remote I/O riding ahead of "
                         "compute (steps past the first epoch fall back "
                         "to demand reads)")
    ap.add_argument("--prefetch-window", type=int, default=8,
                    help="lookahead window in training steps for "
                         "--prefetch-schedule")
    ap.add_argument("--epochs", type=int, default=0,
                    help="with --prefetch-schedule: stitch this many "
                         "consecutive epochs into ONE schedule "
                         "(EpochSchedule.from_sampler(epochs=K)) so "
                         "lookahead windows flow across epoch boundaries "
                         "with no drain-and-refill stall and the Belady "
                         "oracle stays exact at the seam; --steps is then "
                         "derived as epochs * steps_per_epoch "
                         "(0 = single-epoch schedule, --steps drives)")
    args = ap.parse_args()
    if args.epochs:
        if not args.prefetch_schedule:
            raise SystemExit("--epochs requires --prefetch-schedule "
                             "(it parameterizes the stitched schedule)")
        # derive the step budget up front so the optimizer schedule and
        # the stitched EpochSchedule agree on the horizon
        args.steps = args.epochs * (args.num_samples // args.global_batch)

    cfg = (get_smoke if args.preset == "smoke" else get_config)(args.arch)
    if cfg.family in ("audio", "vlm"):
        raise SystemExit("driver demo supports LM-batch families; "
                         "see examples/ for audio/vlm smoke paths")
    model = build_model(cfg)
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                           total_steps=args.steps)

    # ---- FanStore data plane -------------------------------------------------
    tokens = token_dataset(args.num_samples, args.seq_len, cfg.vocab_size,
                           seed=args.seed)
    files = tokens_to_files(tokens)
    blobs, rep = prepare_dataset(files, num_partitions=args.nodes * 2,
                                 compress=False)
    # the schedule-driven loaders stage windows through each node's shared
    # cache tier; budget every node to hold its epoch slice (bounded by the
    # whole dataset — co-located workers SHARE the tier, not split it)
    cache_bytes = 0
    if args.prefetch_schedule:
        cache_bytes = sum(len(b) for b in files.values()) + (1 << 20)
    workers = max(1, args.workers)
    spec = ClusterSpec(num_nodes=args.nodes, workers_per_node=workers,
                       backend=args.backend,
                       replication=args.replication,
                       cache_bytes=cache_bytes,
                       cache_policy="belady" if cache_bytes else "lru")
    num_loaders = spec.total_workers
    if args.prefetch_schedule and args.global_batch % num_loaders:
        raise SystemExit(
            f"--global-batch {args.global_batch} must divide across "
            f"{args.nodes} nodes x {workers} workers for "
            f"--prefetch-schedule")
    cluster = FanStoreCluster.from_spec(spec)
    cluster.load_partitions(blobs)
    paths = sorted(files)
    print(f"fanstore: {rep.num_files} files in {rep.num_partitions} "
          f"partitions on {args.nodes} nodes x {workers} workers "
          f"(R={args.replication}, backend={args.backend})")

    if args.sampler == "stratified":
        sampler = StratifiedSampler(args.num_samples, args.global_batch,
                                    num_shards=args.nodes, seed=args.seed)
    else:
        sampler = GlobalUniformSampler(args.num_samples, args.global_batch,
                                       seed=args.seed)

    # one descriptor-based session per (node, worker) in the declared
    # topology; every read and write below goes through this surface (no
    # raw cluster calls). Co-located sessions share their node's tier.
    order = [ctx.key for ctx in spec.workers()]   # node-major, the
    sessions = {key: cluster.connect(*key) for key in order}  # slice order
    step_counter = {"n": 0}

    # observability: per-step series stream through the cluster's
    # collector to a JSONL sink (periodic ticks in the loop below plus a
    # final explicit flush). Per-rank read bytes are recorded on each
    # issuing session, so the PER_RANK view ties each loader's traffic
    # to its (node, worker) coordinate.
    sink = (JsonlSink(args.metrics_jsonl,
                      every_s=args.metrics_every or None)
            if args.metrics_jsonl else None)

    def _read(key, chunk_paths) -> list:
        blobs_out = sessions[key].read_many(chunk_paths)
        if sink is not None:
            sessions[key].record_metric(
                "train.read_bytes", sum(len(b) for b in blobs_out))
        return blobs_out

    def fetch_many(idxs) -> list:
        # under --prefetch-schedule each step's batch is split into one
        # contiguous slice per (node, worker) — the same slicing the
        # materialized schedule uses — and every slice is ONE coalesced
        # read_many on its own session (no node-0 pin: all nodes read);
        # otherwise the whole batch rides the session whose turn it is
        step_counter["n"] += 1
        if not args.prefetch_schedule:
            key = order[(step_counter["n"] - 1) % len(order)]
            return _read(key, [paths[i] for i in idxs])
        per = len(idxs) // len(order)
        out = []
        for r, key in enumerate(order):
            chunk = idxs[r * per:(r + 1) * per]
            out.extend(_read(key, [paths[i] for i in chunk]))
        return out

    def decode(blobs_list):
        return {"tokens": jnp.asarray(files_to_tokens(blobs_list,
                                                      args.seq_len))}

    scheduler = None
    if args.prefetch_schedule:
        # the permutation of every epoch is fully determined by the
        # sampler seed: materialize it WITHOUT advancing the sampler,
        # axed per (node, worker), and run one clairvoyant driver per
        # coordinate so every node keeps its own lookahead windows in
        # flight. --epochs K stitches K epochs into one globally-stepped
        # horizon: windows flow across the epoch boundary instead of
        # draining at epoch end.
        stitch = max(1, args.epochs)
        schedule = EpochSchedule.from_sampler(sampler, paths,
                                              num_requesters=num_loaders,
                                              workers_per_node=workers,
                                              cluster=cluster,
                                              epochs=stitch)
        scheduler = SchedulerGroup.for_schedule(
            cluster, schedule, window_steps=args.prefetch_window)
        print(f"prefetch-schedule: {len(scheduler)} loaders "
              f"({args.nodes} nodes x {workers} workers), "
              f"{scheduler.num_windows} windows of "
              f"{args.prefetch_window} steps over "
              f"{schedule.num_steps} steps"
              + (f" ({stitch} stitched epochs x "
                 f"{schedule.steps_per_epoch} steps)"
                 if stitch > 1 else ""))

    loader = PrefetchLoader(sampler, fetch_many=fetch_many, decode=decode,
                            num_threads=args.io_threads, depth=2,
                            schedule=scheduler)

    # ---- train state / restore ------------------------------------------------
    state = init_state(model, jax.random.key(args.seed), ocfg,
                       grad_sync=args.grad_sync)
    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and mgr is not None and mgr.latest_step() is not None:
        state, manifest = restore_checkpoint(args.ckpt_dir, state)
        start_step = manifest["step"]
        sampler.state.step = manifest["extra"].get("sampler_step", 0)
        sampler.state.epoch = manifest["extra"].get("sampler_epoch", 0)
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(model, ocfg,
                                      microbatches=args.microbatches))
    t0 = time.perf_counter()
    n_done = start_step
    t_step = t0
    try:
        for batch in loader.batches(args.steps - start_step):
            state, metrics = step_fn(state, batch)
            n_done += 1
            if sink is not None:
                now = time.perf_counter()
                cm = cluster.metrics
                cm.record_metric("train.loss", float(metrics["loss"]),
                                 reduce=Reduce.MEAN)
                cm.record_metric("train.step_time_s", now - t_step,
                                 reduce=Reduce.P99)
                cm.record_metric("train.items", args.global_batch,
                                 rate=True)
                t_step = now
                sink.tick(cm)
            if n_done % 10 == 0 or n_done == args.steps:
                dt = time.perf_counter() - t0
                items = (n_done - start_step) * args.global_batch / dt
                print(f"step {n_done:5d} loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"throughput={items:.1f} items/s", flush=True)
            if n_done % args.ckpt_every == 0:
                extra = {"sampler_step": sampler.state.step,
                         "sampler_epoch": sampler.state.epoch}
                if mgr is not None:
                    mgr.save(n_done, state, extra=extra)
                if args.ckpt_fanstore:
                    save_to_session(sessions[order[0]], n_done, state,
                                    extra=extra)
        extra = {"sampler_step": sampler.state.step,
                 "sampler_epoch": sampler.state.epoch}
        if mgr is not None:
            mgr.save(n_done, state, blocking=True, extra=extra)
        if args.ckpt_fanstore and n_done % args.ckpt_every != 0:
            save_to_session(sessions[order[0]], n_done, state, extra=extra)
    finally:
        try:
            loader.close()   # may re-raise an in-flight window error
        finally:
            cluster.close()  # join the I/O pool + any serving loops
    print(f"done: {n_done} steps, local-hit-rate="
          f"{cluster.local_hit_rate():.3f}")
    if sink is not None:
        # final explicit flush: the last snapshot carries the complete
        # ledger bridge (the clocks outlive cluster.close())
        snap = sink.flush(cluster.metrics)
        sink.close()
        view = sessions[order[0]].metrics()
        st = snap["metrics"].get("train.step_time_s", {})
        print(f"metrics: jsonl={args.metrics_jsonl} "
              f"records={sink.records_written} "
              f"version={snap['version']} "
              f"series={len(snap['metrics'])} "
              f"step_p50={st.get('p50', 0.0):.4f}s "
              f"step_p99={st.get('p99', 0.0):.4f}s "
              f"rank0_read_bytes="
              f"{view['metrics'].get('train.read_bytes', {}).get('sum', 0):.0f}")
    if scheduler is not None:
        prefetch_s = max(c.prefetch_s for c in cluster.clocks.values())
        busy_s = max(c.busy_s for c in cluster.clocks.values())
        print(f"prefetch-schedule: loaders={len(scheduler)} "
              f"windows_issued={scheduler.windows_issued} "
              f"bytes_scheduled={scheduler.bytes_scheduled} "
              f"cache_hit_rate={cluster.cache_hit_rate():.3f} "
              f"max_prefetch_s={prefetch_s:.6f} "
              f"(prefetch lane overlaps demand; busy={busy_s:.6f})")
    if args.backend != "modeled":
        print(f"measured: makespan={cluster.measured_makespan_s():.6f}s "
              f"bytes={cluster.accounting.measured_bytes()} "
              f"requests={cluster.accounting.measured_requests()}")
    if args.ckpt_fanstore:
        clock = cluster.clocks[order[0][0]]
        print(f"fanstore-ckpt: write_bytes={clock.write_bytes} "
              f"write_s={clock.write_s:.6f} consume_s={clock.consume_s:.6f} "
              f"(write lane overlaps the data plane; busy={clock.busy_s:.6f})")


if __name__ == "__main__":
    main()
