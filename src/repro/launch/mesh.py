"""Production mesh builders.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (dry-runs must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips).

    When the process exposes more placeholder devices than the mesh needs
    (the dry-run forces 512), the single-pod mesh takes the first 256.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) != need:
        import numpy as np
        if len(devices) < need:
            raise RuntimeError(f"mesh needs {need} devices, have {len(devices)}")
        from jax.sharding import Mesh
        return Mesh(np.array(devices[:need]).reshape(shape), axes)
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 4, model: int = 2, *, pods: int = 0):
    """Small mesh for subprocess tests (needs matching fake device count)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
