"""Host-side prefetching data pipeline (paper §3.4: async I/O / prefetch).

``PrefetchLoader`` runs a pool of I/O threads (Keras uses 4 per process; same
default) that pull sample indices from a sampler, fetch the bytes through a
FanStore read function, decode, and stage finished batches in a bounded
queue — so the I/O of batch t+1..t+depth overlaps the compute of batch t.
The loader is checkpointable: its cursor is the sampler state.

Beyond depth-batches lookahead, the loader can drive a *clairvoyant*
schedule (``schedule=`` a :class:`repro.fanstore.prefetch.PrefetchScheduler`):
before fetching step t it tells the scheduler to keep windows issued through
step t + ``prefetch_window``, so whole-epoch remote I/O rides ahead of
compute in window-coalesced round trips and the per-step ``fetch_many`` is
served from the client cache without blocking on the fabric.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np


class EpochShuffler:
    """Deterministic per-epoch permutation utility (shared by samplers/tests)."""

    def __init__(self, n: int, seed: int = 0):
        self.n = n
        self.seed = seed

    def perm(self, epoch: int) -> np.ndarray:
        return np.random.default_rng((self.seed, epoch)).permutation(self.n)


class PrefetchLoader:
    """Bounded-depth async batch loader.

    Args:
      sampler: object with ``next_batch() -> np.ndarray[int32]`` and ``state``.
      fetch: maps one sample index -> bytes (e.g. a FanStore read).
      decode: maps list-of-bytes for a batch -> model-ready arrays.
      fetch_many: optional batched fetch mapping a list of sample indices ->
        list of bytes in order (e.g. ``FanStoreCluster.read_many``). When
        given, each batch is ONE coalesced call — the engine groups requests
        per owner node and pays one round trip per owner instead of one per
        sample — and the per-sample thread fan-out is skipped.
      num_threads: I/O threads *per batch* fetching samples concurrently
        (per-sample path only).
      depth: batches staged ahead of compute.
      schedule: optional clairvoyant prefetch driver (an object with
        ``ensure(step)``/``wait_ready(step)``/``close()``, i.e. a
        ``repro.fanstore.prefetch.PrefetchScheduler``). The producer keeps
        lookahead windows issued ahead of consumption and gates each step
        on its own window, so ``fetch_many`` hits the client cache instead
        of paying per-step round trips.
      prefetch_window: how many steps ahead of the consuming step the
        schedule is kept issued (default: the scheduler's own window size).

    Errors raised inside the producer thread are never swallowed: they
    surface on the next ``__next__`` (in place of further batches) or on
    ``close()`` if the consumer stopped early.
    """

    def __init__(self, sampler, fetch: Callable[[int], bytes] = None,
                 decode: Callable[[List[bytes]], object] = None, *,
                 fetch_many: Optional[
                     Callable[[List[int]], List[bytes]]] = None,
                 num_threads: int = 4, depth: int = 2,
                 schedule=None, prefetch_window: Optional[int] = None):
        if fetch is None and fetch_many is None:
            raise ValueError("need fetch or fetch_many")
        if decode is None:
            raise ValueError("decode is required")
        self.sampler = sampler
        self.fetch = fetch
        self.fetch_many = fetch_many
        self.decode = decode
        self.num_threads = num_threads
        self.depth = depth
        self.schedule = schedule
        if prefetch_window is None:
            prefetch_window = getattr(schedule, "window_steps", None) or depth
        self.prefetch_window = prefetch_window
        self._sched_step = getattr(getattr(sampler, "state", None), "step", 0)
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._producer: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None
        self._err_raised = False
        self._done = False

    # -- batch assembly ------------------------------------------------------
    def _fetch_batch(self, indices: np.ndarray) -> object:
        if self.fetch_many is not None:
            return self.decode(self.fetch_many([int(i) for i in indices]))
        out: List[Optional[bytes]] = [None] * len(indices)
        if self.num_threads <= 1:
            for i, idx in enumerate(indices):
                out[i] = self.fetch(int(idx))
        else:
            cursor = iter(range(len(indices)))
            lock = threading.Lock()
            errors: List[BaseException] = []

            def worker():
                while True:
                    with lock:
                        if errors:
                            return
                        i = next(cursor, None)
                    if i is None:
                        return
                    try:
                        out[i] = self.fetch(int(indices[i]))
                    except BaseException as e:
                        with lock:
                            errors.append(e)
                        return

            threads = [threading.Thread(target=worker)
                       for _ in range(self.num_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            if errors:
                raise errors[0]
        return self.decode(out)  # type: ignore[arg-type]

    def _produce(self, num_batches: int) -> None:
        try:
            for _ in range(num_batches):
                if self._stop.is_set():
                    return
                if self.schedule is not None:
                    # keep lookahead windows in flight, then gate on the
                    # current step's window so the fetch hits the cache
                    self.schedule.ensure(
                        self._sched_step + self.prefetch_window)
                    self.schedule.wait_ready(self._sched_step)
                batch = self._fetch_batch(self.sampler.next_batch())
                self._sched_step += 1
                while not self._stop.is_set():
                    try:
                        self._q.put(batch, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:   # surfaced on the consumer side
            self._err = e
        finally:
            self._q.put(None)

    # -- public API ------------------------------------------------------------
    def start(self, num_batches: int) -> "PrefetchLoader":
        """Spawn the producer for ``num_batches``; consume via ``__next__``."""
        if self._producer is not None and self._producer.is_alive():
            raise RuntimeError("loader is already running")
        self._drain()               # stale sentinel from an earlier run
        self._stop.clear()
        self._err = None
        self._err_raised = False
        self._done = False
        self._producer = threading.Thread(
            target=self._produce, args=(num_batches,), daemon=True)
        self._producer.start()
        return self

    def __iter__(self) -> Iterator[object]:
        return self

    def __next__(self) -> object:
        if self._producer is None:
            raise RuntimeError("call start()/batches() before iterating")
        if self._done:
            self._raise_pending()
            raise StopIteration
        item = self._q.get()
        if item is None:
            self._done = True
            self._producer.join()
            if self.schedule is not None:
                self.schedule.close()    # surfaces in-flight window errors
            self._raise_pending()
            raise StopIteration
        return item

    def batches(self, num_batches: int) -> Iterator[object]:
        """Yield ``num_batches`` decoded batches with prefetch overlap."""
        self.start(num_batches)
        return iter(self)

    def _raise_pending(self) -> None:
        if self._err is not None and not self._err_raised:
            self._err_raised = True
            raise self._err

    def close(self) -> None:
        """Stop the producer, drain staged batches, and re-raise any
        producer-side error that has not been surfaced yet — an exception
        raised after the consumer walked away must not be swallowed."""
        self._stop.set()
        t = self._producer
        if t is not None:
            while t.is_alive():
                self._drain()
                t.join(timeout=0.05)
            t.join()
        self._drain()
        self._done = True
        if self.schedule is not None:
            self.schedule.close()
        self._raise_pending()

    def _drain(self) -> None:
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    def stop(self) -> None:
        """Legacy alias for :meth:`close` (same error-surfacing contract)."""
        self.close()

    @property
    def cursor(self):
        return self.sampler.state
