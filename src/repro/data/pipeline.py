"""Host-side prefetching data pipeline (paper §3.4: async I/O / prefetch).

``PrefetchLoader`` runs a pool of I/O threads (Keras uses 4 per process; same
default) that pull sample indices from a sampler, fetch the bytes through a
FanStore read function, decode, and stage finished batches in a bounded
queue — so the I/O of batch t+1..t+depth overlaps the compute of batch t.
The loader is checkpointable: its cursor is the sampler state.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np


class EpochShuffler:
    """Deterministic per-epoch permutation utility (shared by samplers/tests)."""

    def __init__(self, n: int, seed: int = 0):
        self.n = n
        self.seed = seed

    def perm(self, epoch: int) -> np.ndarray:
        return np.random.default_rng((self.seed, epoch)).permutation(self.n)


class PrefetchLoader:
    """Bounded-depth async batch loader.

    Args:
      sampler: object with ``next_batch() -> np.ndarray[int32]`` and ``state``.
      fetch: maps one sample index -> bytes (e.g. a FanStore read).
      decode: maps list-of-bytes for a batch -> model-ready arrays.
      fetch_many: optional batched fetch mapping a list of sample indices ->
        list of bytes in order (e.g. ``FanStoreCluster.read_many``). When
        given, each batch is ONE coalesced call — the engine groups requests
        per owner node and pays one round trip per owner instead of one per
        sample — and the per-sample thread fan-out is skipped.
      num_threads: I/O threads *per batch* fetching samples concurrently
        (per-sample path only).
      depth: batches staged ahead of compute.
    """

    def __init__(self, sampler, fetch: Callable[[int], bytes] = None,
                 decode: Callable[[List[bytes]], object] = None, *,
                 fetch_many: Optional[
                     Callable[[List[int]], List[bytes]]] = None,
                 num_threads: int = 4, depth: int = 2):
        if fetch is None and fetch_many is None:
            raise ValueError("need fetch or fetch_many")
        if decode is None:
            raise ValueError("decode is required")
        self.sampler = sampler
        self.fetch = fetch
        self.fetch_many = fetch_many
        self.decode = decode
        self.num_threads = num_threads
        self.depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._producer: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    # -- batch assembly ------------------------------------------------------
    def _fetch_batch(self, indices: np.ndarray) -> object:
        if self.fetch_many is not None:
            return self.decode(self.fetch_many([int(i) for i in indices]))
        out: List[Optional[bytes]] = [None] * len(indices)
        if self.num_threads <= 1:
            for i, idx in enumerate(indices):
                out[i] = self.fetch(int(idx))
        else:
            cursor = iter(range(len(indices)))
            lock = threading.Lock()
            errors: List[BaseException] = []

            def worker():
                while True:
                    with lock:
                        if errors:
                            return
                        i = next(cursor, None)
                    if i is None:
                        return
                    try:
                        out[i] = self.fetch(int(indices[i]))
                    except BaseException as e:
                        with lock:
                            errors.append(e)
                        return

            threads = [threading.Thread(target=worker)
                       for _ in range(self.num_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            if errors:
                raise errors[0]
        return self.decode(out)  # type: ignore[arg-type]

    def _produce(self, num_batches: int) -> None:
        try:
            for _ in range(num_batches):
                if self._stop.is_set():
                    return
                batch = self._fetch_batch(self.sampler.next_batch())
                while not self._stop.is_set():
                    try:
                        self._q.put(batch, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:   # surfaced on the consumer side
            self._err = e
        finally:
            self._q.put(None)

    # -- public API ------------------------------------------------------------
    def batches(self, num_batches: int) -> Iterator[object]:
        """Yield ``num_batches`` decoded batches with prefetch overlap."""
        self._stop.clear()
        self._producer = threading.Thread(
            target=self._produce, args=(num_batches,), daemon=True)
        self._producer.start()
        served = 0
        while served < num_batches:
            item = self._q.get()
            if item is None:
                break
            yield item
            served += 1
        self._producer.join()
        if self._err is not None:
            raise self._err

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    @property
    def cursor(self):
        return self.sampler.state
