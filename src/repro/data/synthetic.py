"""Synthetic datasets for benchmarks, tests, and examples.

Shapes mirror the paper's workloads: many small files (ImageNet-like blobs),
medium image pairs (SRGAN-like), and shot files (FRNN-like), plus LM token
sequences for the assigned-architecture training path.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def small_file_dataset(num_files: int, size_range: Tuple[int, int] = (1_000, 200_000),
                       *, num_dirs: int = 10, seed: int = 0,
                       entropy_bits: float = 4.0) -> Dict[str, bytes]:
    """ImageNet-1k-like: many small files across class directories."""
    rng = np.random.default_rng(seed)
    hi = int(2 ** entropy_bits)
    out: Dict[str, bytes] = {}
    for i in range(num_files):
        n = int(rng.integers(size_range[0], size_range[1] + 1))
        out[f"train/cls_{i % num_dirs:04d}/img_{i:07d}.bin"] = \
            bytes(rng.integers(0, hi, n, dtype=np.uint8))
    return out


def fixed_size_files(file_size: int, count: int, *, seed: int = 0,
                     entropy_bits: float = 8.0, prefix: str = "bench"
                     ) -> Dict[str, bytes]:
    """The paper's §6.2 benchmark layout: uniform file size, one directory."""
    rng = np.random.default_rng(seed)
    hi = int(2 ** entropy_bits)
    return {f"{prefix}/f_{i:06d}.bin":
            bytes(rng.integers(0, hi, file_size, dtype=np.uint8).tobytes())
            for i in range(count)}


def token_dataset(num_samples: int, seq_len: int, vocab: int, *, seed: int = 0
                  ) -> np.ndarray:
    """LM training corpus: (num_samples, seq_len) int32 token ids.

    Generated from a tiny order-1 Markov chain so a model can actually learn
    structure (loss decreases) in the end-to-end example.
    """
    rng = np.random.default_rng(seed)
    k = min(vocab, 64)
    trans = rng.dirichlet(np.ones(k) * 0.2, size=k)
    out = np.empty((num_samples, seq_len), dtype=np.int32)
    state = rng.integers(0, k, num_samples)
    for t in range(seq_len):
        out[:, t] = state
        u = rng.random(num_samples)
        cdf = np.cumsum(trans[state], axis=1)
        state = (u[:, None] < cdf).argmax(axis=1)
    return out % vocab


def tokens_to_files(tokens: np.ndarray, *, prefix: str = "lm") -> Dict[str, bytes]:
    """Serialize each sequence as one little-endian int32 'file'."""
    return {f"{prefix}/seq_{i:07d}.bin": tokens[i].astype("<i4").tobytes()
            for i in range(tokens.shape[0])}


def files_to_tokens(blobs, seq_len: int) -> np.ndarray:
    """Decode a list of int32-token files into a (B, seq_len) batch."""
    return np.stack([np.frombuffer(b, dtype="<i4", count=seq_len) for b in blobs])
