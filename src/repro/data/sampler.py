"""Batch samplers over the global dataset index space.

The paper's Fig 1 shows why sampling must stay *global*: restricting each
worker to its locally-stored subset ("partitioned view") costs ~4% accuracy.
Samplers here therefore draw indices over the full dataset; placement (who
stores the sample) is a transport detail handled by the store.

  * GlobalUniformSampler  — the paper's access pattern: iid uniform without
    replacement within an epoch (per-epoch global shuffle).
  * StratifiedSampler     — beyond-paper: per step, each of the D workers
    draws an equal number of samples from every storage shard. Still uniform
    over the global dataset, but makes the device-tier all_to_all perfectly
    balanced (zero overflow/padding). §Perf quantifies the win.
  * PartitionedViewSampler — the ablation arm of Fig 1 (each worker sees only
    its local shard).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterator, List, Optional

import numpy as np


@dataclass
class SamplerState:
    """Checkpointable cursor: (epoch, step-within-epoch) + base seed."""
    seed: int
    epoch: int = 0
    step: int = 0


class _Base:
    def __init__(self, num_samples: int, global_batch: int, *, seed: int = 0):
        if global_batch > num_samples:
            raise ValueError("global batch exceeds dataset size")
        self.num_samples = num_samples
        self.global_batch = global_batch
        self.state = SamplerState(seed=seed)

    @property
    def steps_per_epoch(self) -> int:
        return self.num_samples // self.global_batch

    def _advance(self) -> None:
        self.state.step += 1
        if self.state.step >= self.steps_per_epoch:
            self.state.step = 0
            self.state.epoch += 1

    def restore(self, state: SamplerState) -> None:
        self.state = state

    def peek_epoch(self, epoch: Optional[int] = None) -> List[np.ndarray]:
        """Materialize every batch of ``epoch`` (default: the current one)
        WITHOUT advancing the sampler — the permutation is fully determined
        by (seed, epoch), which is what makes clairvoyant prefetch
        scheduling possible (see repro.fanstore.prefetch.EpochSchedule).
        """
        saved = dataclasses.replace(self.state)
        if epoch is None:
            epoch = saved.epoch
        self.state = SamplerState(seed=saved.seed, epoch=epoch, step=0)
        try:
            return [self.next_batch() for _ in range(self.steps_per_epoch)]
        finally:
            self.restore(saved)


class GlobalUniformSampler(_Base):
    """Per-epoch global shuffle, sliced into global batches (paper §3.1)."""

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.state.seed, epoch))
        return rng.permutation(self.num_samples)

    def next_batch(self) -> np.ndarray:
        perm = self._perm(self.state.epoch)
        lo = self.state.step * self.global_batch
        batch = perm[lo: lo + self.global_batch].astype(np.int32)
        self._advance()
        return batch


class StratifiedSampler(_Base):
    """Owner-balanced global sampling for D storage shards.

    Each batch draws exactly ``global_batch / num_shards`` indices from every
    shard's index range (shard s owns [s*S, (s+1)*S)), *and* arranges the
    batch so that every requester's contiguous slice (worker w owns batch
    positions [w*G/D, (w+1)*G/D)) contains exactly G/D^2 samples from every
    owner — that per-requester balance is what lets the device fetch run at
    capacity_factor 1.0 with zero drops. Within a shard the draw is a
    per-epoch shuffle, so over an epoch every sample is seen once — the
    global-view guarantee holds.
    """

    def __init__(self, num_samples: int, global_batch: int, num_shards: int,
                 *, seed: int = 0):
        super().__init__(num_samples, global_batch, seed=seed)
        if num_samples % num_shards or global_batch % (num_shards * num_shards):
            raise ValueError("need num_shards | num_samples and "
                             "num_shards^2 | global_batch")
        self.num_shards = num_shards
        self.per_shard = num_samples // num_shards
        self.batch_per_shard = global_batch // num_shards       # per owner
        self.per_pair = self.batch_per_shard // num_shards      # per (owner, requester)

    @property
    def steps_per_epoch(self) -> int:
        return self.per_shard // self.batch_per_shard

    def next_batch(self) -> np.ndarray:
        D = self.num_shards
        draws = []
        for s in range(D):
            rng = np.random.default_rng((self.state.seed, self.state.epoch, s))
            perm = rng.permutation(self.per_shard)
            lo = self.state.step * self.batch_per_shard
            draws.append(s * self.per_shard + perm[lo: lo + self.batch_per_shard])
        # draws[o] has G/D ids owned by o; requester r takes draws[o][r*p:(r+1)*p]
        mat = np.stack(draws)                        # (owners D, G/D)
        mat = mat.reshape(D, D, self.per_pair)       # (owner, requester, per_pair)
        mat = mat.transpose(1, 0, 2)                 # (requester, owner, per_pair)
        rows = mat.reshape(D, -1)
        # shuffle within each requester slice (owner counts preserved)
        rng = np.random.default_rng((self.state.seed, self.state.epoch,
                                     self.state.step, 0xBA7C4))
        for r in range(D):
            rows[r] = rows[r][rng.permutation(rows.shape[1])]
        self._advance()
        return rows.reshape(-1).astype(np.int32)


class PartitionedViewSampler(_Base):
    """Fig-1 ablation: worker w samples only from its own shard."""

    def __init__(self, num_samples: int, global_batch: int, num_workers: int,
                 *, seed: int = 0):
        super().__init__(num_samples, global_batch, seed=seed)
        if num_samples % num_workers or global_batch % num_workers:
            raise ValueError("sizes must divide num_workers")
        self.num_workers = num_workers
        self.per_worker = num_samples // num_workers
        self.batch_per_worker = global_batch // num_workers

    def next_batch(self) -> np.ndarray:
        cols = []
        for w in range(self.num_workers):
            rng = np.random.default_rng((self.state.seed, self.state.epoch, w))
            perm = rng.permutation(self.per_worker)
            lo = (self.state.step * self.batch_per_worker) % self.per_worker
            cols.append(w * self.per_worker + perm[lo: lo + self.batch_per_worker])
        self._advance()
        return np.concatenate(cols).astype(np.int32)
