from repro.data.sampler import (GlobalUniformSampler, StratifiedSampler,
                                PartitionedViewSampler)
from repro.data.pipeline import PrefetchLoader, EpochShuffler
from repro.data import synthetic

__all__ = ["GlobalUniformSampler", "StratifiedSampler", "PartitionedViewSampler",
           "PrefetchLoader", "EpochShuffler", "synthetic"]
