"""POSIX-style interface over a FanStore cluster (paper §5.5).

The real FanStore detours glibc ``open/read/close/stat/...`` with binary
interception; there is no Python analogue of patching compiled libc calls, so
this layer exposes the same surface as a file-object API rooted at a mount
prefix (default ``/fanstore``), and :mod:`repro.fanstore.intercept` optionally
monkeypatches ``builtins.open`` / ``os.stat`` / ``os.listdir`` so unmodified
user code that touches ``/fanstore/...`` paths transparently hits the store —
the closest user-space equivalent of the paper's detours.

Consistency surface (paper §3.5): multi-read / single-write. Reads are
whole-file-sequential but ``seek``/partial ``read`` work (the cache holds the
full decompressed payload). Writes go to new paths only and become visible
on ``close()``.
"""
from __future__ import annotations

import io
import os
from typing import List, Optional

from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.metadata import StatRecord

MOUNT = "/fanstore"


class FanStoreFile(io.RawIOBase):
    """A read- or write-mode descriptor against the store."""

    def __init__(self, fs: "FanStoreFS", path: str, mode: str):
        super().__init__()
        self._fs = fs
        self._path = path
        self._mode = mode
        self._pos = 0
        if "r" in mode:
            self._data: Optional[bytes] = fs.cluster.read(fs.node_id, path)
            self._writing = False
        elif "w" in mode or "x" in mode:
            self._data = None
            self._writing = True        # bytes live in the NodeStore buffer
            fs.cluster.nodes[fs.node_id].write_begin(path)
        else:
            raise ValueError(f"unsupported mode {mode!r}")

    # -- reads --
    def readable(self) -> bool:
        return self._data is not None

    def read(self, size: int = -1) -> bytes:
        if self._data is None:
            raise io.UnsupportedOperation("not open for reading")
        if size is None or size < 0:
            out = self._data[self._pos:]
            self._pos = len(self._data)
        else:
            out = self._data[self._pos: self._pos + size]
            self._pos += len(out)
        return out

    def seekable(self) -> bool:
        return self._data is not None

    def seek(self, offset: int, whence: int = os.SEEK_SET) -> int:
        base = {os.SEEK_SET: 0, os.SEEK_CUR: self._pos,
                os.SEEK_END: len(self._data or b"")}[whence]
        self._pos = max(0, base + offset)
        return self._pos

    # -- writes --
    def writable(self) -> bool:
        return self._writing

    def write(self, data) -> int:
        if not self._writing:
            raise io.UnsupportedOperation("not open for writing")
        b = bytes(data)
        self._fs.cluster.nodes[self._fs.node_id].write_append(self._path, b)
        return len(b)

    def close(self) -> None:
        if self.closed:
            return
        writing, self._writing = self._writing, False
        try:
            if writing:
                # route through the cluster's commit helper so the FS layer
                # gets the same single-write enforcement + metadata-forward
                # accounting as cluster.write_file
                self._fs.cluster.commit_write(self._fs.node_id, self._path)
        finally:
            super().close()


class FanStoreFS:
    """The per-process client: node-local view of the global namespace."""

    def __init__(self, cluster: FanStoreCluster, node_id: int, *,
                 mount: str = MOUNT):
        self.cluster = cluster
        self.node_id = node_id
        self.mount = mount.rstrip("/")

    def resolve(self, path: str) -> str:
        """Strip the mount prefix; reject paths outside the mount."""
        if not path.startswith(self.mount + "/") and path != self.mount:
            raise FileNotFoundError(f"{path}: outside FanStore mount {self.mount}")
        return path[len(self.mount):].strip("/")

    def owns(self, path: str) -> bool:
        return path == self.mount or path.startswith(self.mount + "/")

    def open(self, path: str, mode: str = "rb") -> FanStoreFile:
        if "b" not in mode:
            raise ValueError("FanStore is a binary store; use 'rb'/'wb'")
        return FanStoreFile(self, self.resolve(path), mode.replace("b", ""))

    def read_many(self, paths: List[str]) -> List[bytes]:
        """Batched whole-file reads through the engine: one modeled round
        trip per (this node, owner) pair instead of one per file."""
        return self.cluster.read_many(self.node_id,
                                      [self.resolve(p) for p in paths])

    def stat(self, path: str) -> StatRecord:
        return self.cluster.stat(self.resolve(path))

    def listdir(self, path: str) -> List[str]:
        return self.cluster.readdir(self.resolve(path))

    def exists(self, path: str) -> bool:
        try:
            self.stat(path)
            return True
        except FileNotFoundError:
            return False

    def walk_count(self, path: str = "") -> int:
        """The start-of-training metadata traversal (paper §3.3): count files."""
        rel = self.resolve(path) if path else ""
        todo = [rel]
        n = 0
        while todo:
            d = todo.pop()
            for name in self.cluster.readdir(d):
                child = f"{d}/{name}" if d else name
                if self.cluster.metadata.is_dir(child):
                    todo.append(child)
                else:
                    n += 1
        return n
