"""POSIX-style file-object interface over a FanStore cluster (paper §5.5).

DEPRECATED surface: ``FanStoreFS``/``FanStoreFile`` are kept as thin
adapters over the descriptor-based :class:`repro.fanstore.api.FanStoreSession`
so pre-session call sites keep working unchanged. New code should use the
session directly — it exposes the same namespace plus the fd-level verbs
(``pread``/``pwrite``/``fsync``/``opendir``) and the batched write path.

Semantics are unchanged: multi-read / single-write (§3.5), whole-payload
materialization at open so ``seek``/partial ``read`` are RAM operations,
writes visible on ``close()``. The FS adapter commits on the legacy
serialized ``consume`` lane, byte-for-byte the ``cluster.write_file``
accounting (regression-pinned).
"""
from __future__ import annotations

import io
import os
from typing import List

from repro.fanstore.api import MOUNT, FanStoreSession
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.metadata import StatRecord

__all__ = ["MOUNT", "FanStoreFile", "FanStoreFS"]


class FanStoreFile(io.RawIOBase):
    """A read- or write-mode file object wrapping one session descriptor."""

    def __init__(self, session: FanStoreSession, path: str, mode: str):
        super().__init__()
        self._session = session
        self._path = path
        self._writing = session._writing_from(mode)
        self._fd = session.open(path, mode)

    @property
    def fd(self) -> int:
        return self._fd

    # -- reads --
    def readable(self) -> bool:
        return not self._writing

    def read(self, size: int = -1) -> bytes:
        if self._writing:
            raise io.UnsupportedOperation("not open for reading")
        return self._session.read(self._fd, -1 if size is None else size)

    def seekable(self) -> bool:
        return not self._writing

    def seek(self, offset: int, whence: int = os.SEEK_SET) -> int:
        # nonstandard whence -> ValueError; SEEK_END during a write ->
        # io.UnsupportedOperation (size is undefined until close) — the
        # session's lseek enforces both
        return self._session.lseek(self._fd, offset, whence)

    # -- writes --
    def writable(self) -> bool:
        return self._writing

    def write(self, data) -> int:
        if not self._writing:
            raise io.UnsupportedOperation("not open for writing")
        return self._session.write(self._fd, bytes(data))

    def flush(self) -> None:
        # file-object flush is a buffer no-op (bytes ship on close, the
        # legacy visible-on-close contract); use session.fsync for the
        # streaming write lane
        super().flush()

    def close(self) -> None:
        if self.closed:
            return
        try:
            if self._session.owns_fd(self._fd):
                self._session.close(self._fd)
        finally:
            super().close()


class FanStoreFS:
    """Deprecated per-process client adapter; see ``FanStoreSession``.

    The FS adapter pins the legacy behaviors: paths must be mount-prefixed,
    modes must be binary, and write commits account on the serialized
    demand lane exactly like ``cluster.write_file``.
    """

    def __init__(self, cluster: FanStoreCluster, node_id: int, *,
                 mount: str = MOUNT):
        self.session = FanStoreSession(cluster, node_id, mount=mount,
                                       lane="consume")
        self.cluster = cluster
        self.node_id = node_id
        self.mount = self.session.mount

    def resolve(self, path: str) -> str:
        """Strip the mount prefix; reject paths outside the mount."""
        path = os.fspath(path)
        if not path.startswith(self.mount + "/") and path != self.mount:
            raise FileNotFoundError(
                f"{path}: outside FanStore mount {self.mount}")
        return self.session.resolve(path)

    def owns(self, path: str) -> bool:
        return self.session.owns(path)

    def open(self, path: str, mode: str = "rb") -> FanStoreFile:
        if "b" not in mode:
            raise ValueError("FanStore is a binary store; use 'rb'/'wb'")
        self.resolve(path)                     # enforce mount-prefixed paths
        return FanStoreFile(self.session, path, mode)

    def read_many(self, paths: List[str]) -> List[bytes]:
        """Batched whole-file reads through the engine: one modeled round
        trip per (this node, owner) pair instead of one per file."""
        return self.session.read_many([self.resolve(p) for p in paths])

    def stat(self, path: str) -> StatRecord:
        return self.session.stat(self.resolve(path))

    def listdir(self, path: str) -> List[str]:
        self.resolve(path)                     # reject paths outside the mount
        return self.session.listdir(path)

    def scandir(self, path: str):
        self.resolve(path)
        return self.session.scandir(path)

    def exists(self, path: str) -> bool:
        try:
            self.stat(path)
            return True
        except FileNotFoundError:
            return False

    def unlink(self, path: str) -> None:
        """Delete a committed output file (output GC; inputs are
        immutable). Mount-prefixed path, like every FS-adapter call."""
        self.resolve(path)
        self.session.unlink(path)

    def walk_count(self, path: str = "") -> int:
        """The start-of-training metadata traversal (paper §3.3): count files."""
        return self.session.walk_count(path)
