"""Per-node FanStore store (paper §5.4).

Each compute node runs one ``NodeStore`` holding:
  * the partitions assigned to it ("local SSD" tier — kept in RAM here, with
    an optional spill directory to model the on-disk layout),
  * an index path -> (partition_id, record) for its local files,
  * the refcount file cache: a file's decompressed bytes stay cached while any
    open descriptor refers to it and are evicted when the count reaches zero
    (paper: uniform random access defeats LRU; evict-on-last-close instead),
  * write buffers for output files: bytes are concatenated in RAM and the
    metadata becomes visible only when ``close()`` forwards it to the node
    chosen by the placement hash (visible-until-finish consistency). The
    write lane may stream chunks ahead of close (``write_take``); the
    placement owner stages them per (writer, path) and joins them at commit,
  * the output tier: committed payloads for files this node owns as the
    placement target — outputs are served like any other local file
    (``open_local``/``serve_remote`` fall back to it).
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.fanstore.layout import FileRecord, iter_partition
from repro.fanstore.metadata import StatRecord


@dataclass
class _CacheEntry:
    data: bytes
    refcount: int = 0


@dataclass
class _WriteBuffer:
    chunks: List[bytes] = field(default_factory=list)
    flushed: int = 0        # bytes already streamed to the placement owner
    buffered: int = 0       # bytes in chunks (kept so size checks are O(1))

    def append(self, data: bytes) -> int:
        self.chunks.append(bytes(data))
        self.buffered += len(data)
        return len(data)

    def take(self) -> bytes:
        """Drain buffered-but-unflushed bytes (streaming fsync)."""
        data = b"".join(self.chunks)
        self.chunks.clear()
        self.flushed += len(data)
        self.buffered = 0
        return data

    def getvalue(self) -> bytes:
        return b"".join(self.chunks)


class NodeStore:
    """One node's slice of the transient store."""

    def __init__(self, node_id: int, *, codec: str = "lzss",
                 spill_dir: Optional[str] = None) -> None:
        self.node_id = node_id
        self.codec = codec
        self.spill_dir = spill_dir
        self._partitions: Dict[int, bytes] = {}
        self._index: Dict[str, Tuple[int, FileRecord]] = {}
        self._cache: Dict[str, _CacheEntry] = {}
        # the refcount cache is mutated by every thread that serves this
        # node — transport pool workers AND (socket backend) per-connection
        # handler threads — so open/release are locked: an unlocked
        # refcount ++/-- pair can double-delete an entry (spurious
        # KeyError to an innocent client) or strand it forever
        self._cache_lock = threading.Lock()
        self._writes: Dict[str, _WriteBuffer] = {}
        # output tier (this node as the placement owner of written files):
        # committed payloads plus per-(writer, path) staging for chunks
        # streamed ahead of close() by the write lane
        self._outputs: Dict[str, bytes] = {}
        self._staging: Dict[Tuple[int, str], List[bytes]] = {}
        # counters for benchmarks / tests
        self.stats = {"local_opens": 0, "cache_hits": 0, "evictions": 0,
                      "bytes_read": 0, "bytes_served": 0, "decompressed": 0}

    # ---- partition loading -------------------------------------------------
    def load_partition(self, partition_id: int, blob: bytes) -> List[str]:
        """Install a partition; returns the paths it contributes."""
        if self.spill_dir is not None:
            os.makedirs(self.spill_dir, exist_ok=True)
            fn = os.path.join(self.spill_dir, f"part_{partition_id:06d}.fst")
            with open(fn, "wb") as f:
                f.write(blob)
        self._partitions[partition_id] = blob
        paths = []
        for rec in iter_partition(blob, codec=self.codec):
            self._index[rec.path] = (partition_id, rec)
            paths.append(rec.path)
        return paths

    def drop_partition(self, partition_id: int) -> None:
        self._partitions.pop(partition_id, None)
        self._index = {p: (pid, r) for p, (pid, r) in self._index.items()
                       if pid != partition_id}

    @property
    def partition_ids(self) -> Tuple[int, ...]:
        return tuple(sorted(self._partitions))

    def has(self, path: str) -> bool:
        return path in self._index

    def local_paths(self) -> List[str]:
        return list(self._index)

    def record_for(self, path: str) -> Optional[FileRecord]:
        hit = self._index.get(path)
        return hit[1] if hit else None

    def locate(self, path: str) -> Optional[Tuple[int, FileRecord]]:
        """(partition_id, record) for a local input file — the coordinates
        a registration-based wire (RDMA) needs to pin the file's stored
        bytes at their offset inside the partition blob."""
        return self._index.get(path)

    def partition_blob(self, partition_id: int) -> bytes:
        """The raw partition image (registration targets map it whole:
        one pinned segment serves every record in the partition)."""
        return self._partitions[partition_id]

    # ---- reads (local tier) ------------------------------------------------
    def open_local(self, path: str) -> bytes:
        """Open+read a local file: refcount++ and return (cached) bytes.

        Falls back to the output tier (files this node owns as the
        placement target of committed writes); outputs are RAM-resident
        already, so they bypass the refcount cache.
        """
        with self._cache_lock:
            entry = self._cache.get(path)
            if entry is not None:
                entry.refcount += 1
                self.stats["cache_hits"] += 1
                return entry.data
            hit = self._index.get(path)
            if hit is None:
                out = self._outputs.get(path)
                if out is not None:
                    self.stats["local_opens"] += 1
                    self.stats["bytes_read"] += len(out)
                    return out
                raise FileNotFoundError(path)
            pid, rec = hit
            blob = self._partitions[pid]
            raw = blob[rec.data_offset: rec.data_offset + rec.stored_size]
            if rec.compressed_size:
                from repro.fanstore.layout import _decompress
                data = _decompress(self.codec, bytes(raw), rec.stat.st_size)
                self.stats["decompressed"] += 1
            else:
                data = bytes(raw)
            self._cache[path] = _CacheEntry(data=data, refcount=1)
            self.stats["local_opens"] += 1
            self.stats["bytes_read"] += len(data)
            return data

    def release(self, path: str) -> None:
        """close(): refcount--; evict at zero (paper's counter table)."""
        with self._cache_lock:
            entry = self._cache.get(path)
            if entry is None:
                return
            entry.refcount -= 1
            if entry.refcount <= 0:
                del self._cache[path]
                self.stats["evictions"] += 1

    def serve_remote(self, path: str) -> bytes:
        """Handle a peer's round-trip read request (no cache interaction)."""
        data = self.open_local(path)
        # the serving side does not hold a descriptor; release immediately
        self.release(path)
        self.stats["bytes_served"] += len(data)
        return data

    def serve_remote_view(self, path: str) -> memoryview:
        """Zero-copy serve for co-located requesters (the shared-memory
        backend): a borrowed ``memoryview`` over this store's own buffers.

        Uncompressed partition records are served as a view straight into
        the partition blob — the payload never exists twice; committed
        outputs are viewed in place. Compressed records must decompress
        (every backend pays that) and the view covers the fresh buffer.
        The view is read-only borrowed memory: valid until the partition
        (or output) is dropped, never to be mutated.
        """
        out = self._outputs.get(path)
        if out is not None:
            self.stats["bytes_served"] += len(out)
            return memoryview(out)
        hit = self._index.get(path)
        if hit is None:
            raise FileNotFoundError(path)
        pid, rec = hit
        blob = self._partitions[pid]
        raw = memoryview(blob)[rec.data_offset:
                               rec.data_offset + rec.stored_size]
        if rec.compressed_size:
            from repro.fanstore.layout import _decompress
            data = _decompress(self.codec, bytes(raw), rec.stat.st_size)
            self.stats["decompressed"] += 1
            self.stats["bytes_served"] += len(data)
            return memoryview(data)
        self.stats["bytes_served"] += rec.stored_size
        return raw

    @property
    def cached_bytes(self) -> int:
        return sum(len(e.data) for e in self._cache.values())

    @property
    def open_files(self) -> int:
        return sum(e.refcount for e in self._cache.values())

    # ---- writes (output tier) ----------------------------------------------
    def write_begin(self, path: str) -> None:
        if path in self._index:
            raise PermissionError(f"{path}: input files are immutable (single-write)")
        self._writes.setdefault(path, _WriteBuffer())

    def write_append(self, path: str, data: bytes) -> int:
        buf = self._writes.get(path)
        if buf is None:
            raise IOError(f"{path}: not open for write")
        return buf.append(data)

    def write_take(self, path: str) -> bytes:
        """Drain the open write's unflushed bytes (streaming fsync); the
        write stays open and the drained bytes count toward the final stat."""
        buf = self._writes.get(path)
        if buf is None:
            raise IOError(f"{path}: not open for write")
        return buf.take()

    def write_size(self, path: str) -> int:
        """Bytes written so far (flushed + buffered) on an open write."""
        buf = self._writes.get(path)
        if buf is None:
            raise IOError(f"{path}: not open for write")
        return buf.flushed + buf.buffered

    def write_abort(self, path: str) -> None:
        self._writes.pop(path, None)

    def write_finish(self, path: str) -> Tuple[StatRecord, bytes]:
        """close() on a written file: final stat (all bytes, including any
        already streamed to the owner) + the remaining unflushed payload.

        The caller (cluster) ships the remainder to the placement owner and
        publishes the metadata; only then does the file become visible.
        """
        buf = self._writes.pop(path, None)
        if buf is None:
            raise IOError(f"{path}: not open for write")
        data = buf.getvalue()
        return StatRecord.for_data(buf.flushed + len(data)), data

    @property
    def pending_writes(self) -> int:
        return len(self._writes)

    # ---- output tier (this node as placement owner) ------------------------
    def stage_output(self, writer: int, path: str, chunk: bytes) -> None:
        """Receive one streamed chunk of an in-flight write. Staging is
        keyed by (writer, path) so two racing writers never interleave."""
        self._staging.setdefault((writer, path), []).append(chunk)

    def drop_staging(self, writer: int, path: str) -> None:
        self._staging.pop((writer, path), None)

    def commit_output(self, writer: int, path: str) -> bytes:
        """Join the writer's staged chunks into the committed payload."""
        data = b"".join(self._staging.pop((writer, path), []))
        self._outputs[path] = data
        return data

    def has_output(self, path: str) -> bool:
        return path in self._outputs

    def output_size(self, path: str) -> Optional[int]:
        """Size of a committed output payload WITHOUT booking a read
        (metadata-only callers, e.g. the wire STAT verb); None when this
        node does not own the path."""
        data = self._outputs.get(path)
        return len(data) if data is not None else None

    def drop_output(self, path: str) -> int:
        """Output GC: free a committed payload this node owns (unlink).
        Returns the bytes reclaimed (0 when the path was not held)."""
        data = self._outputs.pop(path, None)
        return len(data) if data is not None else 0

    @property
    def output_bytes(self) -> int:
        return sum(len(v) for v in self._outputs.values())
