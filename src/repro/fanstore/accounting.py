"""Accounting layer: modeled AND measured per-node timelines.

Two kinds of clocks, one per node each:

* ``NodeClock`` — the *modeled* ledger. The simulated cluster never
  sleeps; every I/O operation accrues modeled time onto the node that
  paid it: consume time (reads the node issued), serve time (reads it
  answered), byte counters, and the client-side read-cache counters the
  cache layer reports through it. Every backend accrues these
  identically, so modeled quantities stay comparable (and
  regression-pinnable) whichever backend moved the bytes.
* ``WallClock`` — the *measured* ledger. The real-wire backends
  (:mod:`repro.fanstore.backends.socket` / ``.shm``) additionally record
  wall-clock nanoseconds around every actual transfer: requester-side
  time per lane, server-side handling time (shipped back inside the
  response frame), and real bytes moved. The modeled backend leaves it
  at zero. Measured lanes are *activity totals* — concurrent transfers
  on one node sum, so ``busy_s`` is an upper bound on that node's
  measured wall time, not an exact makespan.

``ClusterAccounting`` owns one clock of each kind per node and reports
either view: ``makespan_s()`` (modeled) vs ``measured_makespan_s()``
(hardware truth), plus the aggregates the benchmarks plot.

Concurrency contract: ``ClusterAccounting.lock`` is THE clock lock.
The transport backend accrues every modeled/measured quantity under it
(the cluster hands it to ``make_backend``), and ``reset()`` /
``snapshot()`` / every dict-iterating aggregate here takes the same
lock — so a flush racing in-flight accrual sees a CONSISTENT per-node
state (never a half-applied tenant row, never ``dict changed size
during iteration``, never an accrual stranded on a clock object that
``reset()`` just swapped out). The observability plane
(:mod:`repro.fanstore.metrics`) builds its ledger bridge exclusively
from :meth:`ClusterAccounting.snapshot` for exactly this reason.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List


@dataclass(frozen=True)
class WindowAccount:
    """One prefetch window's ledger entry: a single coalesced round trip
    covering every file fetched from one owner for one lookahead window."""
    owner: int
    files: int
    bytes: int
    cost_s: float


@dataclass
class NodeClock:
    """Per-node accounted timeline: what the node spent consuming vs serving."""
    consume_s: float = 0.0
    serve_s: float = 0.0
    bytes_in: int = 0
    bytes_out: int = 0
    local_bytes: int = 0
    # prefetch lane: scheduled (clairvoyant) I/O issued ahead of consumption
    # on the transport pool. It runs concurrently with the demand path, so it
    # gets its own timeline and per-window ledger instead of serializing onto
    # consume_s — that is what lets makespan model I/O hidden behind compute.
    prefetch_s: float = 0.0
    prefetch_bytes: int = 0
    prefetch_windows: int = 0
    prefetch_log: List[WindowAccount] = field(default_factory=list)
    # write lane: output-file / checkpoint writes issued through the batched
    # engine path (cluster.write_many, CheckpointWriter). Like prefetch it
    # runs on the transport pool concurrently with the demand path, so it
    # gets its own timeline — a checkpoint flush overlapped with an active
    # prefetch window costs max(write, prefetch), not the sum. The legacy
    # per-file write_file/commit_write path stays on consume_s (the seed's
    # serialized demand write).
    write_s: float = 0.0
    write_bytes: int = 0
    write_rpcs: int = 0
    # retry ledger: failover read attempts this node paid for after a
    # replica failed (injected or real). retry_s is the modeled backoff
    # time, ALSO accrued onto consume_s (a demand retry blocks the
    # consumer), so it is a visible subset of the consume lane rather
    # than a fifth concurrent lane — degraded-mode cost stays inside the
    # same makespan the healthy run is measured by.
    retries: int = 0
    retry_s: float = 0.0
    # serve-app lane: read-mostly SERVING tenants (inference replicas,
    # param/KV streaming — repro.fanstore.serving) issuing reads through
    # this node. Like prefetch and write it is a concurrent timeline: a
    # node co-hosting a trainer and N serving tenants models
    # max(consume, serve_app, ...), not the sum. Every accrual carries a
    # tenant id, so the per-tenant breakdown below sums to these totals
    # by construction (same contract as the worker cache attribution).
    serve_app_s: float = 0.0
    serve_app_bytes: int = 0
    serve_app_requests: int = 0
    # per-tenant attribution of the serve-app lane: bytes / requests /
    # modeled seconds per tenant id. Sums equal the lane totals above by
    # construction (every accrual goes through attribute_tenant under
    # the transport lock; pinned in tests and the BENCH serving guard).
    tenant_bytes: Dict[str, int] = field(default_factory=dict)
    tenant_requests: Dict[str, int] = field(default_factory=dict)
    tenant_serve_s: Dict[str, float] = field(default_factory=dict)
    # client-side read cache (repro.fanstore.cache), surfaced here so one
    # object answers "what did this node's I/O look like"
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    cache_hit_bytes: int = 0
    # per-worker attribution of the cache counters above: co-located
    # workers share one NodeCacheTier, so the node totals stay the tier
    # truth and this breakdown answers "whose reads hit". Sums equal the
    # totals by construction (every accrual updates both under the
    # transport lock; pinned in tests).
    worker_cache_hits: Dict[int, int] = field(default_factory=dict)
    worker_cache_misses: Dict[int, int] = field(default_factory=dict)
    worker_cache_hit_bytes: Dict[int, int] = field(default_factory=dict)
    # per-JOB attribution of the same counters: several jobs (train +
    # eval) can attach to one namespace and share a node's cache tier, so
    # each cache event also lands on the issuing job's row. Reads that
    # never named a job book under "default", keeping the job sums equal
    # to the node totals by construction (tenant-ledger discipline).
    job_cache_hits: Dict[str, int] = field(default_factory=dict)
    job_cache_misses: Dict[str, int] = field(default_factory=dict)
    job_cache_hit_bytes: Dict[str, int] = field(default_factory=dict)

    def attribute_cache(self, worker_id: int, *, hit: bool,
                        nbytes: int = 0,
                        job: "str | None" = None) -> None:
        """Book one cache event onto the node totals, the worker's
        attribution row, AND the issuing job's row (call under the
        transport lock)."""
        jkey = job if job is not None else "default"
        if hit:
            self.cache_hits += 1
            self.cache_hit_bytes += nbytes
            self.worker_cache_hits[worker_id] = \
                self.worker_cache_hits.get(worker_id, 0) + 1
            self.worker_cache_hit_bytes[worker_id] = \
                self.worker_cache_hit_bytes.get(worker_id, 0) + nbytes
            self.job_cache_hits[jkey] = \
                self.job_cache_hits.get(jkey, 0) + 1
            self.job_cache_hit_bytes[jkey] = \
                self.job_cache_hit_bytes.get(jkey, 0) + nbytes
        else:
            self.cache_misses += 1
            self.worker_cache_misses[worker_id] = \
                self.worker_cache_misses.get(worker_id, 0) + 1
            self.job_cache_misses[jkey] = \
                self.job_cache_misses.get(jkey, 0) + 1

    def attribute_tenant(self, tenant: str, *, nbytes: int = 0,
                         cost_s: float = 0.0, requests: int = 0) -> None:
        """Book one serve-app accrual onto BOTH the lane totals and the
        tenant's attribution row (call under the transport lock). This is
        the only writer of the serve-app lane, so per-tenant sums equal
        the totals by construction."""
        self.serve_app_s += cost_s
        self.serve_app_bytes += nbytes
        self.serve_app_requests += requests
        self.tenant_bytes[tenant] = \
            self.tenant_bytes.get(tenant, 0) + nbytes
        self.tenant_requests[tenant] = \
            self.tenant_requests.get(tenant, 0) + requests
        self.tenant_serve_s[tenant] = \
            self.tenant_serve_s.get(tenant, 0.0) + cost_s

    @property
    def busy_s(self) -> float:
        # consumption, service, scheduled prefetch, batched writes, and
        # serving-tenant reads contend for the same NIC/cores but run on
        # separate threads; a node's makespan is at least each and at
        # most the sum — use max (full overlap) as the optimistic bound
        # the paper's threaded workers approach.
        return max(self.consume_s, self.serve_s, self.prefetch_s,
                   self.write_s, self.serve_app_s)

    @property
    def cache_hit_rate(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 0.0


@dataclass
class WallClock:
    """Per-node MEASURED timeline: real nanoseconds spent moving bytes.

    Lanes mirror ``NodeClock`` (consume / serve / prefetch / write /
    serve_app) so the two ledgers line up column-for-column; values are
    wall-clock activity totals recorded by the real-wire backends around
    every transfer.
    """
    consume_ns: int = 0
    serve_ns: int = 0
    prefetch_ns: int = 0
    write_ns: int = 0
    serve_app_ns: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    requests: int = 0
    # per-stripe attribution: the striped socket backend books each
    # stripe leg's wall time and bytes under its stripe id (stripe 0 is
    # the classic single-connection lane), so a skewed stripe — one slow
    # connection starving the reassembly barrier — is visible in the
    # ledger instead of smeared into the lane total.
    stripe_ns: Dict[int, int] = field(default_factory=dict)
    stripe_bytes: Dict[int, int] = field(default_factory=dict)
    # wire-codec ledger: payload bytes before (raw) and after (sent) the
    # on-the-wire codec, receiver-side truth. raw == sent when the cost
    # model kept every payload raw.
    wire_raw_bytes: int = 0
    wire_sent_bytes: int = 0
    # measured mirror of NodeClock's retry ledger: real backoff
    # nanoseconds slept by the failover read path on this node
    retries: int = 0
    retry_ns: int = 0

    def attribute_stripe(self, stripe_id: int, dt_ns: int,
                         nbytes: int) -> None:
        """Book one stripe leg (call under the transport lock)."""
        self.stripe_ns[stripe_id] = \
            self.stripe_ns.get(stripe_id, 0) + dt_ns
        self.stripe_bytes[stripe_id] = \
            self.stripe_bytes.get(stripe_id, 0) + nbytes

    def accrue(self, lane: str, dt_ns: int) -> None:
        if lane == "prefetch":
            self.prefetch_ns += dt_ns
        elif lane == "write":
            self.write_ns += dt_ns
        elif lane == "serve":
            self.serve_ns += dt_ns
        elif lane == "serve_app":
            self.serve_app_ns += dt_ns
        else:
            self.consume_ns += dt_ns

    @property
    def busy_s(self) -> float:
        # same optimistic-overlap bound as NodeClock.busy_s: the lanes run
        # on separate threads, so a node is busy at least max() of them
        return max(self.consume_ns, self.serve_ns, self.prefetch_ns,
                   self.write_ns, self.serve_app_ns) / 1e9

    @property
    def total_s(self) -> float:
        """Serialized (no-overlap) bound: the sum of every lane."""
        return (self.consume_ns + self.serve_ns + self.prefetch_ns
                + self.write_ns + self.serve_app_ns) / 1e9


class ClusterAccounting:
    """One modeled + one measured clock per node, plus the cluster-level
    aggregates benchmarks read. Modeled quantities are deterministic;
    measured ones exist only after a real-wire backend moved bytes."""

    def __init__(self, node_ids: Iterable[int]):
        ids = list(node_ids)
        # THE clock lock. Reentrant because aggregate readers here may be
        # called from code already holding it (the transport backend
        # accrues under this same object when the cluster wires it in).
        self.lock = threading.RLock()
        self.clocks: Dict[int, NodeClock] = {i: NodeClock() for i in ids}
        self.wall: Dict[int, WallClock] = {i: WallClock() for i in ids}

    def __getitem__(self, node_id: int) -> NodeClock:
        return self.clocks[node_id]

    def add_node(self, node_id: int) -> None:
        with self.lock:
            self.clocks.setdefault(node_id, NodeClock())
            self.wall.setdefault(node_id, WallClock())

    def reset(self) -> None:
        # in place, so every holder of the clocks dict (e.g. the transport
        # backend) observes the reset without re-pointing. Under the clock
        # lock: an in-flight accrual either lands fully before the swap
        # (and is dropped with the old clock) or fully after (and survives
        # on the fresh clock) — never half-applied across the two.
        with self.lock:
            for i in list(self.clocks):
                self.clocks[i] = NodeClock()
            for i in list(self.wall):
                self.wall[i] = WallClock()

    # ---- consistent snapshot (observability-plane bridge) ------------------
    def snapshot(self) -> Dict[str, dict]:
        """One CONSISTENT copy of every ledger, taken under the clock
        lock so no accrual is half-applied across related counters (e.g.
        a tenant row bumped but the lane total not yet).

        Returns plain builtins only (JSON-serializable): ``{"nodes":
        {node_id: {"modeled": {...}, "measured": {...}}}, "cluster":
        {aggregates}}``. This is the ONLY ledger-read path the
        observability plane uses; aggregates are computed from the
        copies, never from the live dicts.
        """
        with self.lock:
            nodes = {
                i: {"modeled": self._clock_dict(self.clocks[i]),
                    "measured": self._wall_dict(self.wall[i])}
                for i in self.clocks
            }
        # aggregates from the copies — outside the lock on purpose
        modeled = [n["modeled"] for n in nodes.values()]
        measured = [n["measured"] for n in nodes.values()]

        def _merge(rows: List[dict], key: str) -> dict:
            out: dict = {}
            for r in rows:
                for k, v in r[key].items():
                    out[k] = out.get(k, 0 if isinstance(v, int) else 0.0) + v
            return out

        local = sum(m["local_bytes"] + m["cache_hit_bytes"] for m in modeled)
        total_in = sum(m["bytes_in"] for m in modeled)
        hits = sum(m["cache_hits"] for m in modeled)
        lookups = hits + sum(m["cache_misses"] for m in modeled)
        makespan = max((m["busy_s"] for m in modeled), default=0.0)
        moved = local + total_in
        cluster = {
            "makespan_s": makespan,
            "measured_makespan_s":
                max((w["busy_s"] for w in measured), default=0.0),
            "measured_total_s": sum(w["total_s"] for w in measured),
            "measured_bytes": sum(w["bytes_in"] for w in measured),
            "measured_requests": sum(w["requests"] for w in measured),
            "aggregate_bandwidth_Bps":
                (moved / makespan) if makespan > 0 else 0.0,
            "local_hit_rate": (local / moved) if moved else 1.0,
            "cache_hit_rate": (hits / lookups) if lookups else 0.0,
            "prefetch_windows": sum(m["prefetch_windows"] for m in modeled),
            "prefetch_bytes": sum(m["prefetch_bytes"] for m in modeled),
            "write_bytes": sum(m["write_bytes"] for m in modeled),
            "write_rpcs": sum(m["write_rpcs"] for m in modeled),
            "serve_app_bytes": sum(m["serve_app_bytes"] for m in modeled),
            "serve_app_requests":
                sum(m["serve_app_requests"] for m in modeled),
            "retries": sum(m["retries"] for m in modeled),
            "retry_s": sum(m["retry_s"] for m in modeled),
            "measured_retries": sum(w["retries"] for w in measured),
            "tenant_bytes": _merge(modeled, "tenant_bytes"),
            "tenant_requests": _merge(modeled, "tenant_requests"),
            "tenant_serve_s": _merge(modeled, "tenant_serve_s"),
            "job_cache_hits": _merge(modeled, "job_cache_hits"),
            "job_cache_misses": _merge(modeled, "job_cache_misses"),
            "job_cache_hit_bytes": _merge(modeled, "job_cache_hit_bytes"),
            "stripe_bytes": _merge(measured, "stripe_bytes"),
            "wire_raw_bytes": sum(w["wire_raw_bytes"] for w in measured),
            "wire_sent_bytes": sum(w["wire_sent_bytes"] for w in measured),
            "wire_saved_bytes":
                sum(w["wire_raw_bytes"] - w["wire_sent_bytes"]
                    for w in measured),
        }
        return {"nodes": nodes, "cluster": cluster}

    @staticmethod
    def _clock_dict(c: NodeClock) -> dict:
        """Copy one modeled clock to plain builtins (prefetch_log is
        summarized by its window/byte counters, not copied entry by
        entry). Call under the clock lock."""
        return {
            "consume_s": c.consume_s, "serve_s": c.serve_s,
            "prefetch_s": c.prefetch_s, "write_s": c.write_s,
            "serve_app_s": c.serve_app_s, "busy_s": c.busy_s,
            "bytes_in": c.bytes_in, "bytes_out": c.bytes_out,
            "local_bytes": c.local_bytes,
            "prefetch_bytes": c.prefetch_bytes,
            "prefetch_windows": c.prefetch_windows,
            "write_bytes": c.write_bytes, "write_rpcs": c.write_rpcs,
            "retries": c.retries, "retry_s": c.retry_s,
            "serve_app_bytes": c.serve_app_bytes,
            "serve_app_requests": c.serve_app_requests,
            "tenant_bytes": dict(c.tenant_bytes),
            "tenant_requests": dict(c.tenant_requests),
            "tenant_serve_s": dict(c.tenant_serve_s),
            "cache_hits": c.cache_hits, "cache_misses": c.cache_misses,
            "cache_evictions": c.cache_evictions,
            "cache_hit_bytes": c.cache_hit_bytes,
            "cache_hit_rate": c.cache_hit_rate,
            "worker_cache_hits": dict(c.worker_cache_hits),
            "worker_cache_misses": dict(c.worker_cache_misses),
            "worker_cache_hit_bytes": dict(c.worker_cache_hit_bytes),
            "job_cache_hits": dict(c.job_cache_hits),
            "job_cache_misses": dict(c.job_cache_misses),
            "job_cache_hit_bytes": dict(c.job_cache_hit_bytes),
        }

    @staticmethod
    def _wall_dict(w: WallClock) -> dict:
        """Copy one measured clock to plain builtins (call under the
        clock lock)."""
        return {
            "consume_ns": w.consume_ns, "serve_ns": w.serve_ns,
            "prefetch_ns": w.prefetch_ns, "write_ns": w.write_ns,
            "serve_app_ns": w.serve_app_ns,
            "busy_s": w.busy_s, "total_s": w.total_s,
            "bytes_in": w.bytes_in, "bytes_out": w.bytes_out,
            "requests": w.requests,
            "stripe_ns": dict(w.stripe_ns),
            "stripe_bytes": dict(w.stripe_bytes),
            "wire_raw_bytes": w.wire_raw_bytes,
            "wire_sent_bytes": w.wire_sent_bytes,
            "retries": w.retries, "retry_ns": w.retry_ns,
        }

    def makespan_s(self) -> float:
        with self.lock:
            return max((c.busy_s for c in self.clocks.values()), default=0.0)

    # ---- measured (wall-clock) view ----------------------------------------
    def measured_makespan_s(self) -> float:
        """Max per-node measured busy time (optimistic-overlap bound)."""
        with self.lock:
            return max((w.busy_s for w in self.wall.values()), default=0.0)

    def measured_total_s(self) -> float:
        """Whole-cluster measured activity (sum of every node's lanes)."""
        with self.lock:
            return sum(w.total_s for w in self.wall.values())

    def measured_bytes(self) -> int:
        with self.lock:
            return sum(w.bytes_in for w in self.wall.values())

    def measured_requests(self) -> int:
        with self.lock:
            return sum(w.requests for w in self.wall.values())

    def measured_stripe_bytes(self) -> Dict[int, int]:
        """Cluster-wide bytes moved per stripe id (striped socket wires)."""
        out: Dict[int, int] = {}
        with self.lock:
            for w in self.wall.values():
                for sid, nbytes in w.stripe_bytes.items():
                    out[sid] = out.get(sid, 0) + nbytes
        return out

    def measured_wire_saved(self) -> int:
        """Bytes the on-the-wire codec kept OFF the wire (0 when the cost
        model never engaged it)."""
        with self.lock:
            return sum(w.wire_raw_bytes - w.wire_sent_bytes
                       for w in self.wall.values())

    def aggregate_bandwidth(self) -> float:
        with self.lock:
            total = sum(c.local_bytes + c.bytes_in + c.cache_hit_bytes
                        for c in self.clocks.values())
            t = max((c.busy_s for c in self.clocks.values()), default=0.0)
        return total / t if t > 0 else 0.0

    def prefetch_windows(self) -> int:
        with self.lock:
            return sum(c.prefetch_windows for c in self.clocks.values())

    def prefetch_bytes(self) -> int:
        with self.lock:
            return sum(c.prefetch_bytes for c in self.clocks.values())

    def write_bytes(self) -> int:
        with self.lock:
            return sum(c.write_bytes for c in self.clocks.values())

    def write_rpcs(self) -> int:
        with self.lock:
            return sum(c.write_rpcs for c in self.clocks.values())

    # ---- serving plane (repro.fanstore.serving) ----------------------------
    def serve_app_bytes(self) -> int:
        """Cluster-wide bytes read on the serve-app lane."""
        with self.lock:
            return sum(c.serve_app_bytes for c in self.clocks.values())

    def serve_app_requests(self) -> int:
        with self.lock:
            return sum(c.serve_app_requests for c in self.clocks.values())

    def _merge_rows(self, attr: str) -> dict:
        """Merge one per-key attribution dict across nodes, under the
        clock lock (the live dicts grow during accrual)."""
        out: dict = {}
        with self.lock:
            for c in self.clocks.values():
                for k, v in getattr(c, attr).items():
                    out[k] = out.get(k, type(v)()) + v
        return out

    def tenant_bytes(self) -> Dict[str, int]:
        """Per-tenant bytes merged across nodes; values sum to
        :meth:`serve_app_bytes` by construction."""
        return self._merge_rows("tenant_bytes")

    def tenant_requests(self) -> Dict[str, int]:
        return self._merge_rows("tenant_requests")

    def tenant_serve_s(self) -> Dict[str, float]:
        """Per-tenant modeled serve-app seconds merged across nodes —
        the fairness metric the serving BENCH block bounds."""
        return self._merge_rows("tenant_serve_s")

    def retries(self) -> int:
        """Cluster-wide failover retry count (modeled ledger)."""
        with self.lock:
            return sum(c.retries for c in self.clocks.values())

    def retry_s(self) -> float:
        """Cluster-wide modeled backoff time paid by failover retries."""
        with self.lock:
            return sum(c.retry_s for c in self.clocks.values())

    def local_hit_rate(self) -> float:
        # client-cache hits are served from node-local RAM: they count as
        # local (no fabric crossing), same as partition-store reads
        with self.lock:
            local = sum(c.local_bytes + c.cache_hit_bytes
                        for c in self.clocks.values())
            total = local + sum(c.bytes_in for c in self.clocks.values())
        return local / total if total else 1.0

    def cache_hit_rate(self) -> float:
        with self.lock:
            hits = sum(c.cache_hits for c in self.clocks.values())
            total = hits + sum(c.cache_misses for c in self.clocks.values())
        return hits / total if total else 0.0

    # ---- per-job cache attribution (multi-job seam) ------------------------
    def job_cache_hits(self) -> Dict[str, int]:
        """Per-job cache hits merged across nodes; values sum to the
        node totals by construction (every accrual books both)."""
        return self._merge_rows("job_cache_hits")

    def job_cache_misses(self) -> Dict[str, int]:
        return self._merge_rows("job_cache_misses")

    def job_cache_hit_bytes(self) -> Dict[str, int]:
        return self._merge_rows("job_cache_hit_bytes")
