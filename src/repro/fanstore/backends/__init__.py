"""Pluggable transport backends behind the one verb seam.

Four interchangeable wires (pick one with
``FanStoreCluster(backend=...)``):

=========  ========================  =====================================
name       moves bytes via           accounts
=========  ========================  =====================================
modeled    in-process references     modeled clocks only (deterministic)
socket     framed TCP: striped       modeled clocks + measured wall time
           connections, pipelined    (requester + per-stripe lanes,
           requests, optional        server serve_ns, wire codec ledger)
           on-the-wire LZSS
shm        zero-copy memoryviews /   modeled clocks + measured wall time
           shared-memory segments
rdma       one-sided reads over      modeled one-sided cost (lookup +
           registered ShmArena       line rate, ZERO owner serve lane)
           segments (rkey tables)    + measured wall time
=========  ========================  =====================================

All wires speak the same verbs; the two-sided ones (modeled / socket /
shm) accrue identical modeled costs, so the engine above the seam
(cluster, session, prefetch scheduler, write path) is backend-agnostic.
The rdma backend's fabric genuinely differs — one-sided reads involve no
owner CPU — so it overrides the documented accounting seams. Further
UCX-style backends slot in by subclassing
:class:`~repro.fanstore.backends.base.TransportBackend` and registering
here.
"""
from __future__ import annotations

from typing import Dict, Type

from repro.fanstore.backends.base import TransportBackend
from repro.fanstore.backends.modeled import InterconnectModel, ModeledBackend
from repro.fanstore.backends.rdma import RdmaBackend
from repro.fanstore.backends.shm import SharedMemoryBackend, ShmArena
from repro.fanstore.backends.socket import SocketBackend

__all__ = ["TransportBackend", "ModeledBackend", "SocketBackend",
           "SharedMemoryBackend", "ShmArena", "RdmaBackend",
           "InterconnectModel", "BACKENDS", "make_backend"]

BACKENDS: Dict[str, Type[TransportBackend]] = {
    "modeled": ModeledBackend,
    "socket": SocketBackend,
    "shm": SharedMemoryBackend,
    "rdma": RdmaBackend,
}


def make_backend(name: str, net, nodes, clocks, *, wall=None,
                 num_threads: int = 8, **options) -> TransportBackend:
    """Construct a registered backend by name (``backend_options`` from
    the cluster land in ``options``, e.g. ``host=`` for sockets; the
    cluster also passes ``lock=ClusterAccounting.lock`` here so clock
    accrual and snapshot/reset serialize on one lock)."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown transport backend {name!r}; "
            f"choose from {sorted(BACKENDS)}") from None
    return cls(net, nodes, clocks, wall=wall, num_threads=num_threads,
               **options)
