"""The socket backend: a real TCP wire path behind the transport seam.

Every node runs one serving loop (paper §5.2's "I/O thread"): a
``_NodeServer`` binds a loopback TCP socket, accepts connections, and
answers framed :mod:`repro.fanstore.wire` requests by scatter-gathering
from its own ``NodeStore`` — ``FETCH_BATCH``/``FETCH_WINDOW`` frames come
back as one ``DATA`` frame carrying every payload in the group (the wire
twin of the modeled one-round-trip-per-owner coalescing), ``PUT_BATCH``
frames land in the owner's per-(writer, path) staging, and handler
exceptions travel back as ``ERR`` frames that re-raise client-side as the
same exception class.

The client half keeps ONE persistent connection per (requester, owner)
pair — connections are dialed lazily, serialized by a per-pair lock
(one request frame, one response frame), and closed on backend
``close()``. Serving loops are named ``fanstore-serve-*`` /
``fanstore-conn-*`` so tests can assert deterministic teardown.

Accounting is dual: the modeled clocks accrue exactly as on every other
backend (so modeled quantities stay backend-independent), while measured
wall time accrues onto the ``WallClock`` lanes — the requester pays the
observed round-trip duration, and the owner's serve lane is credited with
the handling time the server reports inside each response frame. These
are the repo's first hardware-truth numbers (``BENCH_io.json``'s
``measured`` block).
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.fanstore import wire
from repro.fanstore.backends.base import TransportBackend
from repro.fanstore.metadata import StatRecord
from repro.fanstore.store import NodeStore
from repro.fanstore.wire import FetchItem, MsgType

__all__ = ["SocketBackend"]

_FETCH_TYPES = {"fetch": MsgType.FETCH, "fetch_batch": MsgType.FETCH_BATCH,
                "fetch_window": MsgType.FETCH_WINDOW}


class _NodeServer:
    """One node's serving loop: accept thread + per-connection handlers."""

    def __init__(self, node_id: int, store: NodeStore, host: str):
        self.node_id = node_id
        self.store = store
        self._listener = socket.create_server((host, 0))
        self.address: Tuple[str, int] = self._listener.getsockname()
        self._stop = threading.Event()
        self._conn_lock = threading.Lock()
        self._conns: List[socket.socket] = []
        self._threads: List[threading.Thread] = []
        self._accept_thread = threading.Thread(
            target=self._accept_loop,
            name=f"fanstore-serve-{node_id}", daemon=True)
        self._accept_thread.start()

    # ---- serving loop ------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:           # listener closed: clean shutdown
                return
            if self._stop.is_set():   # the wake-up dial from close()
                conn.close()
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            t = threading.Thread(
                target=self._handle, args=(conn,),
                name=f"fanstore-conn-{self.node_id}", daemon=True)
            with self._conn_lock:
                self._conns.append(conn)
                self._threads.append(t)
            t.start()

    def _handle(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                mtype, body = wire.read_frame(conn)
                self._dispatch(conn, mtype, body)
        except (ConnectionError, OSError):
            pass                       # peer hung up / shutdown race
        finally:
            conn.close()

    def _dispatch(self, conn: socket.socket, mtype: MsgType,
                  body: bytes) -> None:
        """Answer one request with exactly one response frame — a handler
        exception (FileNotFoundError from a bad path, PermissionError,
        anything the store raises) becomes an ERR frame and the connection
        stays usable; only a failure to WRITE the response (peer gone)
        propagates and closes the connection. The response is built before
        any byte is sent, so request/response framing can never
        desynchronize."""
        rtype, rbody = self._answer(mtype, body)
        wire.write_frame(conn, rtype, rbody)

    def _answer(self, mtype: MsgType, body: bytes) -> Tuple[MsgType, bytes]:
        t0 = time.perf_counter_ns()
        try:
            if mtype in (MsgType.FETCH, MsgType.FETCH_BATCH,
                         MsgType.FETCH_WINDOW):
                paths, materialize = wire.decode_fetch(body)
                if materialize:        # ONE scatter-gather over local blobs
                    payloads = [self.store.serve_remote(p) for p in paths]
                else:
                    payloads = [b"" for _ in paths]
                return MsgType.DATA, wire.encode_data(
                    payloads, serve_ns=time.perf_counter_ns() - t0)
            if mtype == MsgType.PUT_BATCH:
                writer, entries = wire.decode_put(body)
                for path, data in entries:
                    self.store.stage_output(writer, path, data)
                return MsgType.OK, wire.encode_ok(
                    serve_ns=time.perf_counter_ns() - t0)
            if mtype == MsgType.STAT:
                path = wire.decode_stat(body)
                return MsgType.STAT_OK, wire.encode_stat_ok(
                    self._stat(path), serve_ns=time.perf_counter_ns() - t0)
            raise wire.WireError(f"unexpected request type {mtype!r}")
        except BaseException as exc:   # noqa: BLE001 — becomes an ERR frame
            return MsgType.ERR, wire.encode_error(exc)

    def _stat(self, path: str) -> StatRecord:
        rec = self.store.record_for(path)
        if rec is not None:
            return rec.stat
        size = self.store.output_size(path)   # metadata-only: no read booked
        if size is not None:
            return StatRecord.for_data(size)
        raise FileNotFoundError(path)

    def close(self) -> None:
        self._stop.set()
        # a blocking accept() is not reliably interrupted by closing the
        # listener from another thread; dial it once so it wakes, sees the
        # stop flag, and exits deterministically
        try:
            socket.create_connection(self.address, timeout=1.0).close()
        except OSError:
            pass
        self._listener.close()
        with self._conn_lock:
            conns, threads = list(self._conns), list(self._threads)
            self._conns.clear()
            self._threads.clear()
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()                  # unblocks recv()
        self._accept_thread.join(timeout=5.0)
        for t in threads:
            t.join(timeout=5.0)


class SocketBackend(TransportBackend):
    """Framed TCP transfers between per-node serving loops (loopback)."""

    name = "socket"
    measured = True

    def __init__(self, net, nodes, clocks, *, wall=None, num_threads: int = 8,
                 host: str = "127.0.0.1"):
        super().__init__(net, nodes, clocks, wall=wall,
                         num_threads=num_threads)
        self.host = host
        self._servers: Dict[int, _NodeServer] = {}
        # one persistent connection (+ request lock) per (requester, owner)
        self._conns: Dict[Tuple[int, int],
                          Tuple[socket.socket, threading.Lock]] = {}
        self._dial_lock = threading.Lock()

    # ---- lifecycle ---------------------------------------------------------
    def _start_serving(self) -> None:
        for nid, store in self.nodes.items():
            if nid not in self._servers:
                self._servers[nid] = _NodeServer(nid, store, self.host)

    def _stop_serving(self) -> None:
        with self._dial_lock:
            conns = list(self._conns.values())
            self._conns.clear()
        for sock, _ in conns:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            sock.close()
        for srv in self._servers.values():
            srv.close()
        self._servers.clear()

    def server_address(self, node_id: int) -> Tuple[str, int]:
        """The (host, port) a node's serving loop listens on."""
        self.start()
        return self._servers[node_id].address

    def _conn(self, requester: int,
              owner: int) -> Tuple[socket.socket, threading.Lock]:
        key = (requester, owner)
        hit = self._conns.get(key)      # GIL-atomic fast path
        if hit is not None:
            return hit
        # _lazy_start takes the lifecycle lock, so run it BEFORE taking
        # the dial lock (close() holds lifecycle while tearing down); it
        # raises rather than respawning servers on a closed backend
        self._lazy_start()
        with self._dial_lock:
            hit = self._conns.get(key)
            if hit is None:
                sock = socket.create_connection(
                    self._servers[owner].address)
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                hit = (sock, threading.Lock())
                self._conns[key] = hit
        return hit

    # ---- one round trip ----------------------------------------------------
    def _request(self, requester: int, owner: int, mtype: MsgType,
                 body: bytes) -> Tuple[MsgType, bytes]:
        sock, lock = self._conn(requester, owner)
        with lock:                     # one frame out, one frame back
            wire.write_frame(sock, mtype, body)
            rtype, rbody = wire.read_frame(sock)
        if rtype == MsgType.ERR:
            raise wire.decode_error(rbody)
        return rtype, rbody

    # ---- movement primitives -----------------------------------------------
    def _move_fetch(self, requester: int, owner: int,
                    items: Sequence[FetchItem], materialize: bool,
                    verb: str) -> Tuple[List[bytes], int]:
        _, rbody = self._request(
            requester, owner, _FETCH_TYPES[verb],
            wire.encode_fetch([it.path for it in items],
                              materialize=materialize))
        return wire.decode_data(rbody)

    def _move_put(self, writer: int, owner: int,
                  pairs: Sequence[Tuple[FetchItem, bytes]]) -> int:
        _, rbody = self._request(
            writer, owner, MsgType.PUT_BATCH,
            wire.encode_put(writer, [(it.path, d) for it, d in pairs]))
        return wire.decode_ok(rbody)

    # ---- extra wire verb ---------------------------------------------------
    def stat_remote(self, requester: int, owner: int,
                    path: str) -> StatRecord:
        """Ask an owner's serving loop for a file's stat over the wire."""
        _, rbody = self._request(requester, owner, MsgType.STAT,
                                 wire.encode_stat(path))
        st, _ = wire.decode_stat_ok(rbody)
        return st
