"""The socket backend: a real TCP wire path behind the transport seam.

Every node runs one serving loop (paper §5.2's "I/O thread"): a
``_NodeServer`` binds a loopback TCP socket, accepts connections, and
answers framed :mod:`repro.fanstore.wire` requests by scatter-gathering
from its own ``NodeStore`` — ``FETCH_BATCH``/``FETCH_WINDOW`` frames come
back as one ``DATA`` frame carrying every payload in the group (the wire
twin of the modeled one-round-trip-per-owner coalescing), ``PUT_BATCH``
frames land in the owner's per-(writer, path) staging, and handler
exceptions travel back as ERR frames that re-raise client-side as the
same exception class.

The data plane is built for throughput:

* **Connection striping** — up to ``stripes`` persistent connections per
  (requester, owner) pair. A large batch is split into contiguous
  sub-batches balanced by stored bytes (``wire.split_stripes``), each
  sub-batch rides its own connection concurrently (its own server-side
  handler thread, its own TCP stream), and the payload runs are slotted
  back into item order whatever order the stripes finish
  (``wire.reassemble``). Stripe legs are wall-timed individually
  (``WallClock.attribute_stripe``).
* **Request pipelining** — within one connection a sub-batch is cut into
  up to ``pipeline_depth`` request frames sent back-to-back before the
  first response is read, so the server builds response *k+1* while the
  client drains response *k*; TCP FIFO plus the server's strict
  one-response-per-request discipline keeps framing aligned with no
  sequence numbers on the wire.
* **Vectored I/O** — responses are scatter-gathered with ``sendmsg``
  straight from the store's zero-copy ``serve_remote_view`` buffers
  (``wire.write_frame_parts``), and both sides ``recv_into`` reusable
  per-connection receive buffers, so each payload crosses Python exactly
  once per side (kernel->buffer on receive; buffer->kernel on send).
* **Tuned sockets** — TCP_NODELAY plus sized SO_SNDBUF/SO_RCVBUF
  (``sock_buf_bytes``, default 4 MiB) on every connection, both sides.
* **LZSS-on-the-wire** — the per-payload codec flag from
  :class:`~repro.fanstore.wire.WireCodecPolicy`: each DATA/PUT payload is
  compressed only when the cost model predicts the codec CPU beats the
  wire time saved, and ships raw (flag clear) when the attempt does not
  shrink it. The receiver ledgers raw-vs-sent bytes onto its
  ``WallClock``.

Connections are dialed lazily, each serialized by a per-stripe lock, and
closed on backend ``close()`` — teardown joins every stripe's connection
handler deterministically (the PR-4 wake-up dial covers the accept loop;
shutdown+close unblocks each per-connection recv). Serving loops are
named ``fanstore-serve-*`` / ``fanstore-conn-*`` and the stripe fan-out
pool ``fanstore-stripe-*`` so the leak-check fixture sees them all.

Accounting is dual: the modeled clocks accrue exactly as on every other
two-sided backend (so modeled quantities stay backend-independent), while
measured wall time accrues onto the ``WallClock`` lanes — the requester
pays the observed round-trip duration, and the owner's serve lane is
credited with the handling time the server reports inside each response
frame. These are the repo's hardware-truth numbers (``BENCH_io.json``'s
``measured`` block; the ``measured.wire`` block pins striped-vs-single
throughput on the standard trace).
"""
from __future__ import annotations

import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

from repro.fanstore import wire
from repro.fanstore.backends.base import TransportBackend
from repro.fanstore.metadata import StatRecord
from repro.fanstore.store import NodeStore
from repro.fanstore.wire import FetchItem, MsgType

__all__ = ["SocketBackend"]

_FETCH_TYPES = {"fetch": MsgType.FETCH, "fetch_batch": MsgType.FETCH_BATCH,
                "fetch_window": MsgType.FETCH_WINDOW}

#: default socket buffer size (SO_SNDBUF/SO_RCVBUF), both sides
_SOCK_BUF = 4 << 20

#: a batch smaller than this ships on one stripe: splitting it would pay
#: extra dials and thread hops for bytes a single stream moves instantly
_STRIPE_MIN_BYTES = 128 << 10


def _tune(sock: socket.socket, buf_bytes: int) -> None:
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    try:
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, buf_bytes)
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, buf_bytes)
    except OSError:        # pragma: no cover - kernel may clamp, never fatal
        pass


class _NodeServer:
    """One node's serving loop: accept thread + per-connection handlers."""

    def __init__(self, node_id: int, store: NodeStore, host: str,
                 policy: Optional[wire.WireCodecPolicy] = None,
                 buf_bytes: int = _SOCK_BUF, join_timeout_s: float = 5.0):
        self.node_id = node_id
        self.store = store
        self.policy = policy if policy is not None and policy.codec != "none" \
            else None
        self.buf_bytes = buf_bytes
        self.join_timeout_s = join_timeout_s
        self._listener = socket.create_server((host, 0))
        self.address: Tuple[str, int] = self._listener.getsockname()
        self._stop = threading.Event()
        self._conn_lock = threading.Lock()
        self._conns: List[socket.socket] = []
        self._threads: List[threading.Thread] = []
        self._accept_thread = threading.Thread(
            target=self._accept_loop,
            name=f"fanstore-serve-{node_id}", daemon=True)
        self._accept_thread.start()

    # ---- serving loop ------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:           # listener closed: clean shutdown
                return
            if self._stop.is_set():   # the wake-up dial from close()
                conn.close()
                return
            _tune(conn, self.buf_bytes)
            t = threading.Thread(
                target=self._handle, args=(conn,),
                name=f"fanstore-conn-{self.node_id}", daemon=True)
            with self._conn_lock:
                self._conns.append(conn)
                self._threads.append(t)
            t.start()

    def _handle(self, conn: socket.socket) -> None:
        # reusable receive buffer: the connection is persistent, so one
        # geometrically-grown buffer serves every request frame with zero
        # per-frame allocation (the decoders copy payloads out before the
        # next read overwrites it)
        rbuf = bytearray(1 << 16)
        try:
            while not self._stop.is_set():
                mtype, body = wire.read_frame(conn, rbuf)
                self._dispatch(conn, mtype, body)
        except (ConnectionError, OSError):
            pass                       # peer hung up / shutdown race
        finally:
            conn.close()

    def _dispatch(self, conn: socket.socket, mtype: MsgType,
                  body) -> None:
        """Answer one request with exactly one response frame — a handler
        exception (FileNotFoundError from a bad path, PermissionError,
        anything the store raises) becomes an ERR frame and the connection
        stays usable; only a failure to WRITE the response (peer gone)
        propagates and closes the connection. The response scatter list is
        built before any byte is sent, so request/response framing can
        never desynchronize — the discipline pipelined clients rely on."""
        rtype, parts = self._answer(mtype, body)
        wire.write_frame_parts(conn, rtype, parts)

    def _answer(self, mtype: MsgType, body) -> Tuple[MsgType, List[bytes]]:
        t0 = time.perf_counter_ns()
        try:
            if mtype in (MsgType.FETCH, MsgType.FETCH_BATCH,
                         MsgType.FETCH_WINDOW):
                paths, materialize = wire.decode_fetch(body)
                if materialize:        # ONE scatter-gather over local blobs:
                    # zero-copy views — sendmsg gathers them straight from
                    # the partition blobs / output tier, payloads are
                    # never joined into a response body
                    payloads = [self.store.serve_remote_view(p)
                                for p in paths]
                else:
                    payloads = [b"" for _ in paths]
                return MsgType.DATA, wire.encode_data_parts(
                    payloads, serve_ns=time.perf_counter_ns() - t0,
                    policy=self.policy)
            if mtype == MsgType.PUT_BATCH:
                writer, entries = wire.decode_put(body)
                for path, data in entries:
                    self.store.stage_output(writer, path, data)
                return MsgType.OK, [wire.encode_ok(
                    serve_ns=time.perf_counter_ns() - t0)]
            if mtype == MsgType.STAT:
                path = wire.decode_stat(body)
                return MsgType.STAT_OK, [wire.encode_stat_ok(
                    self._stat(path), serve_ns=time.perf_counter_ns() - t0)]
            raise wire.WireError(f"unexpected request type {mtype!r}")
        except BaseException as exc:   # noqa: BLE001 — becomes an ERR frame
            return MsgType.ERR, [wire.encode_error(exc)]

    def _stat(self, path: str) -> StatRecord:
        rec = self.store.record_for(path)
        if rec is not None:
            return rec.stat
        size = self.store.output_size(path)   # metadata-only: no read booked
        if size is not None:
            return StatRecord.for_data(size)
        raise FileNotFoundError(path)

    def close(self) -> None:
        self._stop.set()
        # a blocking accept() is not reliably interrupted by closing the
        # listener from another thread; dial it once so it wakes, sees the
        # stop flag, and exits deterministically
        try:
            socket.create_connection(self.address, timeout=1.0).close()
        except OSError:
            pass
        self._listener.close()
        with self._conn_lock:
            conns, threads = list(self._conns), list(self._threads)
            self._conns.clear()
            self._threads.clear()
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()                  # unblocks recv()
        self._accept_thread.join(timeout=self.join_timeout_s)
        for t in threads:
            t.join(timeout=self.join_timeout_s)
        # a join that timed out used to succeed SILENTLY, leaking the
        # thread past this close and into the conftest leak fixture (or a
        # CI hang) with no pointer back here — name the stuck threads now
        stuck = [t.name for t in [self._accept_thread, *threads]
                 if t.is_alive()]
        if stuck:
            raise RuntimeError(
                f"fanstore socket teardown: node {self.node_id} serving "
                f"threads failed to join within {self.join_timeout_s}s: "
                f"{stuck}")


class _Conn:
    """One client-side stripe connection: socket + request lock + reusable
    receive buffer (pipelined responses decode before the next read)."""

    __slots__ = ("sock", "lock", "rbuf")

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.lock = threading.Lock()
        self.rbuf = bytearray(1 << 16)


class SocketBackend(TransportBackend):
    """Framed TCP transfers between per-node serving loops (loopback)."""

    name = "socket"
    measured = True

    def __init__(self, net, nodes, clocks, *, wall=None, num_threads: int = 8,
                 host: str = "127.0.0.1", sock_buf_bytes: int = _SOCK_BUF,
                 stripe_min_bytes: int = _STRIPE_MIN_BYTES,
                 dial_retries: int = 3, dial_backoff_s: float = 0.05,
                 join_timeout_s: float = 5.0, **wire_opts):
        super().__init__(net, nodes, clocks, wall=wall,
                         num_threads=num_threads, **wire_opts)
        self.host = host
        self.sock_buf_bytes = int(sock_buf_bytes)
        self.stripe_min_bytes = int(stripe_min_bytes)
        self.dial_retries = int(dial_retries)
        self.dial_backoff_s = float(dial_backoff_s)
        self.join_timeout_s = float(join_timeout_s)
        self._servers: Dict[int, _NodeServer] = {}
        # one persistent connection per (requester, owner, stripe) — the
        # single-connection wire of PR 4 is exactly the stripes=1 case
        self._conns: Dict[Tuple[int, int, int], _Conn] = {}
        self._dial_lock = threading.Lock()
        self._stripe_pool: Optional[ThreadPoolExecutor] = None

    # ---- lifecycle ---------------------------------------------------------
    def _start_serving(self) -> None:
        for nid, store in self.nodes.items():
            if nid not in self._servers:
                self._servers[nid] = _NodeServer(
                    nid, store, self.host, policy=self.wire_policy,
                    buf_bytes=self.sock_buf_bytes,
                    join_timeout_s=self.join_timeout_s)
        if self.stripes > 1 and self._stripe_pool is None:
            # fan-out workers for concurrent stripe legs; sized past the
            # stripe count so two overlapping striped batches (demand +
            # prefetch) both make progress. Workers spawn on demand.
            self._stripe_pool = ThreadPoolExecutor(
                max_workers=2 * self.stripes,
                thread_name_prefix="fanstore-stripe")

    def _stop_serving(self) -> None:
        with self._dial_lock:
            conns = list(self._conns.values())
            self._conns.clear()
        for c in conns:                # EVERY stripe's connection, each join
            try:                       # deterministic: shutdown unblocks the
                c.sock.shutdown(socket.SHUT_RDWR)  # server-side recv, close
            except OSError:            # releases the fd
                pass
            c.sock.close()
        pool, self._stripe_pool = self._stripe_pool, None
        if pool is not None:
            pool.shutdown(wait=True)   # joins every fanstore-stripe worker
        # close EVERY server even if one reports stuck threads, then
        # surface the first failure (a partial teardown would strand the
        # remaining serving loops with no further close coming)
        stuck: List[BaseException] = []
        for srv in self._servers.values():
            try:
                srv.close()
            except RuntimeError as exc:
                stuck.append(exc)
        self._servers.clear()
        if stuck:
            raise stuck[0]

    def server_address(self, node_id: int) -> Tuple[str, int]:
        """The (host, port) a node's serving loop listens on."""
        self.start()
        return self._servers[node_id].address

    def _connect(self, owner: int) -> socket.socket:
        """Dial one connection to ``owner``'s serving loop, retrying a
        refused/reset dial with exponential backoff (``dial_retries``
        attempts) — a serving loop still binding during a startup race
        used to fail the first fetch permanently. A dropped or unknown
        owner raises ``ConnectionError`` (the classified failure the
        failover read path retries on another replica), never ``KeyError``.
        Call with the dial lock held."""
        srv = self._servers.get(owner)
        if srv is None:
            raise ConnectionError(
                f"node {owner} has no serving loop (dead or never joined)")
        last: Optional[OSError] = None
        for attempt in range(self.dial_retries + 1):
            if attempt:
                time.sleep(self.dial_backoff_s * (2 ** (attempt - 1)))
            try:
                sock = socket.create_connection(srv.address)
                _tune(sock, self.sock_buf_bytes)
                return sock
            except (ConnectionRefusedError, ConnectionResetError,
                    ConnectionAbortedError) as exc:
                last = exc
        raise ConnectionError(
            f"dial to node {owner} at {srv.address} failed after "
            f"{self.dial_retries + 1} attempts") from last

    def _conn(self, requester: int, owner: int, stripe: int = 0) -> _Conn:
        key = (requester, owner, stripe)
        hit = self._conns.get(key)      # GIL-atomic fast path
        if hit is not None:
            return hit
        # _lazy_start takes the lifecycle lock, so run it BEFORE taking
        # the dial lock (close() holds lifecycle while tearing down); it
        # raises rather than respawning servers on a closed backend
        self._lazy_start()
        with self._dial_lock:
            hit = self._conns.get(key)
            if hit is None:
                hit = _Conn(self._connect(owner))
                self._conns[key] = hit
        return hit

    # ---- membership --------------------------------------------------------
    def drop_node(self, node_id: int) -> None:
        """A peer died: close every stripe dialed to OR from it and tear
        down its serving loop, so stale connections fail fast with a
        ``ConnectionError`` (classified, retried on a replica) instead of
        hanging on a half-open socket."""
        with self._dial_lock:
            doomed = [k for k in self._conns
                      if node_id in (k[0], k[1])]
            conns = [self._conns.pop(k) for k in doomed]
            srv = self._servers.pop(node_id, None)
        for c in conns:
            try:
                c.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.sock.close()
        if srv is not None:
            srv.close()

    def ensure_node(self, node_id: int) -> None:
        """A peer (re)joined: spawn its serving loop if the wire is up
        (lazy start covers the not-yet-started case)."""
        with self._lifecycle:
            started = self._started
        if started and node_id not in self._servers:
            self._servers[node_id] = _NodeServer(
                node_id, self.nodes[node_id], self.host,
                policy=self.wire_policy, buf_bytes=self.sock_buf_bytes,
                join_timeout_s=self.join_timeout_s)

    # ---- one round trip ----------------------------------------------------
    def _request(self, requester: int, owner: int, mtype: MsgType,
                 body: bytes, *, parts: Optional[List[bytes]] = None
                 ) -> Tuple[MsgType, memoryview]:
        conn = self._conn(requester, owner)
        with conn.lock:                # one frame out, one frame back
            if parts is not None:
                wire.write_frame_parts(conn.sock, mtype, parts)
            else:
                wire.write_frame(conn.sock, mtype, body)
            rtype, rbody = wire.read_frame(conn.sock, conn.rbuf)
            if rtype == MsgType.ERR:
                raise wire.decode_error(rbody)
            # copy before dropping the lock: rbody aliases the reusable
            # receive buffer, which the next request overwrites (OK/STAT
            # responses are tiny; DATA responses decode under the lock in
            # _fetch_on_stripe instead)
            return rtype, memoryview(bytes(rbody))

    # ---- striped + pipelined fetch -----------------------------------------
    def _fetch_on_stripe(self, requester: int, owner: int, stripe: int,
                         items: Sequence[FetchItem], materialize: bool,
                         verb: str) -> Tuple[List[bytes], int, int, int]:
        """One stripe leg: up to ``pipeline_depth`` request frames in
        flight on this stripe's connection. Every request frame goes out
        before the first response is read — the server answers strictly
        in order per connection, so the pipeline can never mismatch.
        Returns (payloads, serve_ns, raw_bytes, wire_bytes)."""
        mtype = _FETCH_TYPES[verb]
        depth = self.pipeline_depth if len(items) > 1 else 1
        chunks = wire.split_stripes(items, depth)
        conn = self._conn(requester, owner, stripe)
        payloads: List[bytes] = []
        serve_ns = raw_b = wire_b = 0
        err: Optional[BaseException] = None
        with conn.lock:
            wire.sendmsg_all(conn.sock, [
                wire.frame(mtype, wire.encode_fetch(
                    [it.path for it in items[s:e]], materialize=materialize))
                for s, e in chunks])
            for _ in chunks:           # drain EVERY response (keep framing
                rtype, rbody = wire.read_frame(conn.sock, conn.rbuf)
                if rtype == MsgType.ERR:   # aligned even past an error)
                    err = err or wire.decode_error(rbody)
                    continue
                p, s_ns, raw, sent = wire.decode_data_ex(rbody)
                payloads.extend(p)
                serve_ns += s_ns
                raw_b += raw
                wire_b += sent
        if err is not None:
            raise err
        return payloads, serve_ns, raw_b, wire_b

    def _timed_stripe(self, requester: int, owner: int, stripe: int,
                      items: Sequence[FetchItem], materialize: bool,
                      verb: str) -> Tuple[List[bytes], int]:
        """Run one stripe leg and book its wall time, bytes, and codec
        ledger under the stripe's id."""
        t0 = time.perf_counter_ns()
        payloads, serve_ns, raw_b, wire_b = self._fetch_on_stripe(
            requester, owner, stripe, items, materialize, verb)
        dt = time.perf_counter_ns() - t0
        with self._lock:
            w = self.wall[requester]
            w.attribute_stripe(stripe, dt, sum(len(p) for p in payloads))
            w.wire_raw_bytes += raw_b
            w.wire_sent_bytes += wire_b
        return payloads, serve_ns

    # ---- movement primitives -----------------------------------------------
    def _move_fetch(self, requester: int, owner: int,
                    items: Sequence[FetchItem], materialize: bool,
                    verb: str) -> Tuple[List[bytes], int]:
        pool = self._stripe_pool
        n_stripes = min(self.stripes, len(items)) if materialize else 1
        if (n_stripes > 1 and pool is not None
                and sum(it.stored for it in items) >= self.stripe_min_bytes):
            bounds = wire.split_stripes(items, n_stripes)
            futs = [pool.submit(self._timed_stripe, requester, owner, sid,
                                items[s:e], materialize, verb)
                    for sid, (s, e) in enumerate(bounds)]
            results = [f.result() for f in futs]
            payloads = wire.reassemble(
                len(items),
                [(bounds[i], results[i][0]) for i in range(len(bounds))])
            # serve legs run on concurrent handler threads server-side;
            # lanes are activity totals, so they sum (same convention as
            # every measured lane)
            return payloads, sum(r[1] for r in results)
        return self._timed_stripe(requester, owner, 0, items, materialize,
                                  verb)

    def _move_put(self, writer: int, owner: int,
                  pairs: Sequence[Tuple[FetchItem, bytes]]) -> int:
        policy = self.wire_policy if self.wire_policy.codec != "none" else None
        _, rbody = self._request(
            writer, owner, MsgType.PUT_BATCH, b"",
            parts=wire.encode_put_parts(
                writer, [(it.path, d) for it, d in pairs], policy=policy))
        return wire.decode_ok(rbody)

    # ---- extra wire verb ---------------------------------------------------
    def stat_remote(self, requester: int, owner: int,
                    path: str) -> StatRecord:
        """Ask an owner's serving loop for a file's stat over the wire."""
        _, rbody = self._request(requester, owner, MsgType.STAT,
                                 wire.encode_stat(path))
        st, _ = wire.decode_stat_ok(rbody)
        return st
