"""The RDMA-class backend: one-sided reads over registered memory.

The paper's remote path is two-sided — every fetch costs the owner a
request handling plus a scatter-gather on its serving thread. An
RDMA-style fabric removes the owner's CPU from the data path entirely:
the owner *registers* (pins) memory regions up front and publishes a
registration table; a requester that holds a region's (segment, offset,
rkey) coordinates reads the bytes with a one-sided verb the owner never
sees. This backend models that contract faithfully for co-located
processes:

* **Registration table** — per owner, ``path -> _Region``: the shared
  segment holding the bytes, the offset/length inside it, an rkey-style
  protection token, and the codec coordinates (stored bytes may be
  LZSS-compressed in the partition image; the REQUESTER decompresses,
  exactly as a real one-sided read hands back raw registered bytes).
  Input partitions are registered whole — one pinned segment per
  partition blob serves every record in it at its ``data_offset`` —
  and committed outputs are registered per path on first read.
  Registration happens lazily on first touch (the control path, amortized
  once per partition/output); :meth:`registration_table` exposes an
  owner's published table.
* **One-sided read** — :meth:`_move_fetch` looks up the region, verifies
  the token (a mismatched rkey raises ``PermissionError``, the fabric's
  protection-domain check), and copies the bytes out of the registered
  segment. It reports ``serve_ns = 0`` ALWAYS: the owner's measured serve
  lane never accrues, because its CPU never ran — the no-serve-lane
  contract the cross-backend tests pin.
* **Measured arm** — registered segments are real
  ``multiprocessing.shared_memory`` segments (:class:`ShmArena`), so
  co-located worker processes can attach and read with zero owner
  involvement; where ``/dev/shm`` is unavailable the regions degrade to
  in-process buffer views with identical semantics.
* **Modeled accounting** — this is the one backend whose fabric genuinely
  differs, so it overrides the two accounting seams: a remote read costs
  the requester ``trips * rdma_lookup_s + stored / rdma_bandwidth_Bps``
  (+ the universal requester-side decompress) and the owner NOTHING on
  its serve lane (``bytes_out`` still ledgers the bytes that left its
  memory); one-sided writes mirror it. All other modeled bookkeeping
  (lanes, prefetch ledger, cache accounting) is inherited unchanged.

Unlinked outputs are evicted from every table via
:meth:`invalidate_path` (wired through ``cluster.unlink``), so a freed
name can never serve stale registered bytes after a rewrite.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.fanstore.accounting import WindowAccount
from repro.fanstore.backends.base import TransportBackend
from repro.fanstore.backends.shm import ShmArena
from repro.fanstore.layout import _decompress
from repro.fanstore.wire import FetchItem

__all__ = ["RdmaBackend"]


def _rkey(owner: int, path: str) -> int:
    """Deterministic rkey-style token for a registration (stable across
    the region's lifetime; NOT a secret — it models the fabric's
    protection-domain check, not authentication)."""
    h = 2166136261
    for b in f"{owner}:{path}".encode():
        h = ((h ^ b) * 16777619) & 0xFFFFFFFF
    return h


@dataclass
class _Region:
    """One registered (pinned) byte range a requester may read one-sided."""
    segment: Optional[str]      # shm segment name (None: in-process buffer)
    seg_size: int               # registered segment length
    offset: int                 # byte offset of this path inside the segment
    length: int                 # stored bytes at that offset
    token: int                  # rkey-style protection token
    compressed: bool            # requester must decompress after the read
    raw_size: int               # decompressed size (== length when raw)
    own_segment: bool           # True: this region's segment is private to
    #                             the path (outputs) and dies with it
    buffer: Optional[memoryview] = None   # the no-arena fallback mapping


class RdmaBackend(TransportBackend):
    """One-sided reads over registered ``ShmArena`` segments."""

    name = "rdma"
    measured = True

    def __init__(self, net, nodes, clocks, *, wall=None,
                 num_threads: int = 8, use_arena: Optional[bool] = None,
                 **wire_opts):
        super().__init__(net, nodes, clocks, wall=wall,
                         num_threads=num_threads, **wire_opts)
        self._use_arena = ShmArena.available if use_arena is None \
            else bool(use_arena)
        self._arena: Optional[ShmArena] = None
        # owner -> {path -> region}; partition segments are shared by every
        # region of their partition, so they are tracked separately
        self._tables: Dict[int, Dict[str, _Region]] = {}
        self._part_segs: Dict[Tuple[int, int], Tuple[str, int]] = {}
        self._reg_lock = threading.Lock()

    # ---- lifecycle ---------------------------------------------------------
    def _start_serving(self) -> None:
        if self._use_arena and self._arena is None:
            self._arena = ShmArena()

    def _stop_serving(self) -> None:
        with self._reg_lock:
            self._tables.clear()
            self._part_segs.clear()
            arena, self._arena = self._arena, None
        if arena is not None:
            arena.close()              # unlinks every registered segment

    # ---- registration (the control path) -----------------------------------
    def registration_table(self, owner: int) -> Mapping[str, _Region]:
        """The owner's published table (a snapshot copy)."""
        with self._reg_lock:
            return dict(self._tables.get(owner, {}))

    def _region(self, owner: int, path: str) -> _Region:
        tab = self._tables.get(owner)   # GIL-atomic fast path
        if tab is not None:
            hit = tab.get(path)
            if hit is not None:
                return hit
        self._lazy_start()              # raises on a closed backend
        with self._reg_lock:
            tab = self._tables.setdefault(owner, {})
            hit = tab.get(path)
            if hit is None:
                hit = self._pin(owner, path)
                tab[path] = hit
        return hit

    def _pin(self, owner: int, path: str) -> _Region:
        """Register the bytes backing ``path`` (call under _reg_lock).

        Inputs pin the WHOLE partition blob once (the region is an
        offset into the shared segment); outputs get a private segment."""
        store = self.nodes[owner]
        loc = store.locate(path)
        if loc is not None:
            pid, rec = loc
            blob = store.partition_blob(pid)
            seg = self._part_segs.get((owner, pid))
            buffer = None
            if seg is None:
                if self._arena is not None:
                    seg = self._arena.export(blob)
                    self._part_segs[(owner, pid)] = seg
                else:
                    buffer = memoryview(blob)
            return _Region(
                segment=seg[0] if seg else None,
                seg_size=seg[1] if seg else len(blob),
                offset=rec.data_offset, length=rec.stored_size,
                token=_rkey(owner, path),
                compressed=bool(rec.compressed_size),
                raw_size=rec.stat.st_size, own_segment=False,
                buffer=buffer if buffer is not None
                else (memoryview(blob) if seg is None else None))
        size = store.output_size(path)
        if size is None:
            raise FileNotFoundError(path)
        data = bytes(store.serve_remote_view(path))
        if self._arena is not None:
            name, seg_size = self._arena.export(data)
            return _Region(segment=name, seg_size=seg_size, offset=0,
                           length=len(data), token=_rkey(owner, path),
                           compressed=False, raw_size=len(data),
                           own_segment=True)
        return _Region(segment=None, seg_size=len(data), offset=0,
                       length=len(data), token=_rkey(owner, path),
                       compressed=False, raw_size=len(data),
                       own_segment=True, buffer=memoryview(data))

    def invalidate_path(self, path: str) -> None:
        """Unlink notification: evict every registration of ``path`` and
        release output-private segments (a rewrite of the freed name must
        re-register, never serve the dead bytes)."""
        with self._reg_lock:
            for tab in self._tables.values():
                region = tab.pop(path, None)
                if (region is not None and region.own_segment
                        and region.segment is not None
                        and self._arena is not None):
                    self._arena.drop(region.segment)

    def drop_node(self, node_id: int) -> None:
        """Membership: tear down a dead owner's registration table and
        release its pinned partition segments. Requesters that still hold
        a pre-drop region keep a valid mapping until the arena closes —
        exactly the fabric's behaviour, where deregistration invalidates
        NEW lookups, not in-flight reads."""
        with self._reg_lock:
            tab = self._tables.pop(node_id, None)
            segs = [name for (own, _pid), (name, _sz)
                    in list(self._part_segs.items()) if own == node_id]
            for key in [k for k in self._part_segs if k[0] == node_id]:
                del self._part_segs[key]
            if tab is not None and self._arena is not None:
                segs.extend(r.segment for r in tab.values()
                            if r.own_segment and r.segment is not None)
            if self._arena is not None:
                for name in segs:
                    self._arena.drop(name)

    # ---- the one-sided verbs -----------------------------------------------
    def read_region(self, region: _Region, token: int) -> bytes:
        """One-sided read: copy the registered bytes out of the segment.
        The owner's CPU is not involved; a wrong rkey is the fabric's
        protection fault."""
        if token != region.token:
            raise PermissionError(
                f"rdma: rkey {token:#x} does not match registration")
        if region.buffer is not None:
            view = region.buffer
        else:
            assert self._arena is not None
            view = self._arena.view(region.segment, region.seg_size)
        return bytes(view[region.offset:region.offset + region.length])

    def _move_fetch(self, requester: int, owner: int,
                    items: Sequence[FetchItem], materialize: bool,
                    verb: str) -> Tuple[List[bytes], int]:
        if not materialize:
            return [b"" for _ in items], 0
        store = self.nodes[owner]
        out: List[bytes] = []
        for it in items:
            region = self._region(owner, it.path)
            raw = self.read_region(region, region.token)
            if region.compressed:      # requester-side decode: one-sided
                raw = _decompress(store.codec, raw, region.raw_size)
            out.append(raw)
        return out, 0   # the no-serve-lane contract: owner CPU never ran

    def _move_put(self, writer: int, owner: int,
                  pairs: Sequence[Tuple[FetchItem, bytes]]) -> int:
        # one-sided write into the owner's pre-negotiated staging region;
        # commit (joining the chunks) remains the cluster's publish step
        store = self.nodes[owner]
        for item, data in pairs:
            store.stage_output(writer, item.path, data)
        return 0

    # ---- the one-sided cost model (the accounting seams) -------------------
    def _account_remote(self, requester: int, owner: int,
                        items: Sequence[FetchItem], *,
                        round_trips: Optional[int] = None,
                        lane: str = "consume",
                        tenant: Optional[str] = None) -> None:
        """One-sided modeled cost: the requester pays a registration-table
        lookup per trip plus line-rate bytes (plus the universal
        requester-side decompress); the owner's serve lane accrues ZERO —
        only its ``bytes_out`` ledgers the bytes that left its memory.
        Lane bookkeeping mirrors the base exactly (including the
        serve-app lane's per-tenant attribution)."""
        trips = len(items) if round_trips is None else round_trips
        stored = sum(it.stored for it in items)
        clock = self.clocks[requester]
        cost = (trips * self.net.rdma_lookup_s
                + stored / self.net.rdma_bandwidth_Bps)
        for it in items:
            if it.compressed:
                cost += it.size / self.net.decompress_Bps
        if lane == "prefetch":
            clock.prefetch_s += cost
            clock.prefetch_bytes += stored
            clock.prefetch_windows += trips
            clock.prefetch_log.append(WindowAccount(
                owner=owner, files=len(items), bytes=stored, cost_s=cost))
        elif lane == "serve_app":
            clock.attribute_tenant(tenant or "anon", nbytes=stored,
                                   cost_s=cost, requests=trips)
        else:
            clock.consume_s += cost
            clock.bytes_in += stored
        self.clocks[owner].bytes_out += stored

    def _account_put(self, writer: int, owner: int, stored: int,
                     trips: int, lane: str) -> None:
        """One-sided write: writer pays lookup + line-rate bytes on its
        lane; the owner's serve lane accrues ZERO (the bytes land in its
        registered staging without its CPU)."""
        cost = (trips * self.net.rdma_lookup_s
                + stored / self.net.rdma_bandwidth_Bps)
        self._accrue_write(writer, cost, stored, trips, lane)
