"""The shared-memory backend: the Hoard-style co-located fast path.

When requester and owner share an address space (workers co-located on
one physical node — this container, by construction, holds the whole
simulated cluster), shipping payloads through a socket pays framing,
syscalls, and two copies for bytes that are already reachable. This
backend takes the node-local tier's shortcut instead:

* ``_move_fetch`` asks the owner's ``NodeStore`` for **zero-copy
  ``memoryview``s** over its partition blobs (``serve_remote_view``) and
  materializes each payload with a single ``bytes()`` copy — no frames,
  no syscalls, no intermediate buffer. Uncompressed files never exist
  twice; compressed ones pay exactly the one decompression every backend
  pays.
* ``fetch_views`` exposes the views themselves for callers that can
  consume borrowed buffers (the benchmark's true zero-copy arm).
* ``_move_put`` stages output chunks directly into the owner's staging
  table (co-located writers share the store).

For co-located worker *processes* (separate interpreters on one node),
:class:`ShmArena` provides the same trick over
``multiprocessing.shared_memory``: ``export`` copies a payload once into
a named segment; any process that knows the (name, size) pair maps it
read-only with zero further copies. The backend exports committed
payloads on demand via :meth:`export_output`, or a whole manifest of
paths via :meth:`export_paths`. The spawn-side counterpart is
:func:`attach_and_digest`: a worker process rebuilds the owner's
topology from a ``ClusterSpec`` JSON string, attaches the exported
segments by (name, size) handle, and reads the payloads byte-identical
— the cross-process seam, closed by a real ``multiprocessing`` spawn
test. Arena support degrades gracefully (``ShmArena.available``) where
``/dev/shm`` is absent.

Measured wall time accrues exactly as on the socket backend (requester
lane + owner serve lane), so ``BENCH_io.json``'s ``measured`` block can
show the co-located path beating the socket path on the same trace —
the modeled clocks accrue identically to every other backend.
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.fanstore.backends.base import TransportBackend
from repro.fanstore.wire import FetchItem

__all__ = ["SharedMemoryBackend", "ShmArena", "attach_and_digest"]

try:
    from multiprocessing import shared_memory as _shm
except ImportError:                     # pragma: no cover - stdlib on 3.8+
    _shm = None


class ShmArena:
    """Named ``multiprocessing.shared_memory`` segments for cross-process
    zero-copy: one export = one copy into the segment; every mapping
    after that is free. Owns its segments — ``close()`` unlinks them."""

    #: False when the platform offers no POSIX shared memory
    available = _shm is not None

    def __init__(self) -> None:
        # name -> (segment, owns): only segments THIS arena created get
        # unlinked at close; attached peer exports are merely unmapped
        self._segments: Dict[str, Tuple["_shm.SharedMemory", bool]] = {}
        self._lock = threading.Lock()

    def export(self, data: bytes) -> Tuple[str, int]:
        """Copy ``data`` into a fresh segment; returns (name, size) — the
        handle another process needs to map it."""
        if _shm is None:
            raise RuntimeError("multiprocessing.shared_memory unavailable")
        seg = _shm.SharedMemory(create=True, size=max(len(data), 1))
        seg.buf[:len(data)] = data
        with self._lock:
            self._segments[seg.name] = (seg, True)
        return seg.name, len(data)

    def view(self, name: str, size: int) -> memoryview:
        """Map a segment (local or exported by a peer) as a read view."""
        if _shm is None:
            raise RuntimeError("multiprocessing.shared_memory unavailable")
        with self._lock:
            hit = self._segments.get(name)
        if hit is None:                # exported by another arena: attach
            seg = _shm.SharedMemory(name=name)
            with self._lock:
                hit = self._segments.setdefault(name, (seg, False))
            if hit[0] is not seg:      # lost the insert race: drop ours
                seg.close()
        return hit[0].buf[:size]

    def drop(self, name: str) -> None:
        """Release ONE segment early (e.g. an unlinked output's
        registration): unmap, and unlink if this arena created it."""
        with self._lock:
            hit = self._segments.pop(name, None)
        if hit is None:
            return
        seg, owns = hit
        try:
            seg.close()
        except BufferError:            # a borrowed view is still live
            pass
        if owns:
            try:
                seg.unlink()
            except FileNotFoundError:
                pass

    def close(self) -> None:
        with self._lock:
            segments = list(self._segments.values())
            self._segments.clear()
        for seg, owns in segments:
            try:
                seg.close()
            except BufferError:
                # a consumer still holds a borrowed view; carry on — the
                # memory is freed when the last map drops
                pass
            if owns:                   # never unlink a peer's live export
                try:
                    seg.unlink()
                except FileNotFoundError:  # owner gone and name reclaimed
                    pass

    def __len__(self) -> int:
        return len(self._segments)


class SharedMemoryBackend(TransportBackend):
    """Zero-copy co-located transfers over the owner's own buffers."""

    name = "shm"
    measured = True

    def __init__(self, net, nodes, clocks, *, wall=None,
                 num_threads: int = 8, arena: Optional[ShmArena] = None,
                 **wire_opts):
        super().__init__(net, nodes, clocks, wall=wall,
                         num_threads=num_threads, **wire_opts)
        self.arena = arena

    def _stop_serving(self) -> None:
        if self.arena is not None:
            self.arena.close()

    # ---- movement primitives -----------------------------------------------
    @staticmethod
    def _materialize(view: memoryview) -> bytes:
        """Owning bytes for a served view with the fewest copies: a view
        spanning a whole bytes object (freshly decompressed payloads,
        committed outputs) is that object — hand it back uncopied; only
        borrowed partition-blob slices pay the one materializing copy."""
        obj = view.obj
        if type(obj) is bytes and view.nbytes == len(obj):
            return obj
        return bytes(view)

    def _move_fetch(self, requester: int, owner: int,
                    items: Sequence[FetchItem], materialize: bool,
                    verb: str) -> Tuple[List[bytes], int]:
        if not materialize:
            return [b"" for _ in items], 0
        store = self.nodes[owner]
        t0 = time.perf_counter_ns()
        out = [self._materialize(store.serve_remote_view(it.path))
               for it in items]
        # co-located: the owner's "serving" IS the view construction; the
        # copy happens on the requester's side of the same duration
        return out, time.perf_counter_ns() - t0

    def _move_put(self, writer: int, owner: int,
                  pairs: Sequence[Tuple[FetchItem, bytes]]) -> int:
        store = self.nodes[owner]
        t0 = time.perf_counter_ns()
        for item, data in pairs:
            store.stage_output(writer, item.path, data)
        return time.perf_counter_ns() - t0

    # ---- zero-copy extras --------------------------------------------------
    def fetch_views(self, requester: int, owner: int,
                    items: Sequence[FetchItem]) -> List[memoryview]:
        """Borrowed zero-copy views of the owner's payloads (no modeled
        accounting: this is the raw fast path for callers that manage
        their own lifetimes, e.g. the measured benchmark)."""
        store = self.nodes[owner]
        t0 = time.perf_counter_ns()
        views = [store.serve_remote_view(it.path) for it in items]
        dt = time.perf_counter_ns() - t0
        self._wall_accrue(requester, "consume", dt,
                          bytes_in=sum(v.nbytes for v in views), requests=1,
                          owner=owner, serve_ns=dt,
                          bytes_out=sum(v.nbytes for v in views))
        return views

    def export_output(self, owner: int, path: str) -> Tuple[str, int]:
        """Copy a committed output payload into a shared-memory segment so
        a co-located worker *process* can map it zero-copy; returns the
        (segment name, size) handle. Requires an :class:`ShmArena`."""
        if self.arena is None:
            raise RuntimeError("SharedMemoryBackend built without an arena")
        data = self._materialize(self.nodes[owner].serve_remote_view(path))
        return self.arena.export(data)

    def export_paths(self, owner: int, paths: Sequence[str]
                     ) -> Dict[str, Tuple[str, int]]:
        """Export a manifest of payloads (inputs OR committed outputs the
        ``owner`` node holds) into shared-memory segments: the
        ``{path: (segment name, size)}`` handle table a spawned worker
        process needs — ship it beside ``cluster.spec.to_json()`` and the
        worker reconstructs the topology and maps every payload with
        :func:`attach_and_digest` (or :meth:`ShmArena.view` directly)."""
        if self.arena is None:
            raise RuntimeError("SharedMemoryBackend built without an arena")
        store = self.nodes[owner]
        return {p: self.arena.export(
                    self._materialize(store.serve_remote_view(p)))
                for p in paths}


def attach_and_digest(spec_json: str,
                      handles: Mapping[str, Tuple[str, int]]
                      ) -> Dict[str, object]:
    """Worker-process entry point for the cross-process shm seam.

    Runs in a SPAWNED interpreter (module-level so ``multiprocessing``'s
    spawn context can import it): rebuilds the owner's topology from the
    serialized :class:`~repro.fanstore.spec.ClusterSpec`, attaches every
    exported segment by its (name, size) handle, and returns
    ``{"spec_json": <re-serialized spec>, "digests": {path: sha256hex},
    "sizes": {path: nbytes}}`` — the parent asserts the spec round-trip
    is identity and the digests match its own payloads byte-for-byte.
    The attached segments are unmapped (never unlinked: this arena did
    not create them) before returning.
    """
    # local import: repro.fanstore.spec imports this module's package
    from repro.fanstore.spec import ClusterSpec
    spec = ClusterSpec.from_json(spec_json)     # validates the topology
    arena = ShmArena()
    digests: Dict[str, str] = {}
    sizes: Dict[str, int] = {}
    try:
        for path, (name, size) in handles.items():
            view = arena.view(name, size)
            try:
                digests[path] = hashlib.sha256(view).hexdigest()
                sizes[path] = view.nbytes
            finally:
                view.release()          # drop the borrow before unmapping
    finally:
        arena.close()                   # attached-only: unmaps, no unlink
    return {"spec_json": spec.to_json(), "digests": digests,
            "sizes": sizes,
            "workers_per_node": spec.workers_per_node}
