"""The transport backend seam: one verb surface, interchangeable wires.

Every byte in the cluster crosses a :class:`TransportBackend`. The base
class owns everything the backends must agree on — the verb surface the
engine calls (``fetch_local`` / ``fetch_remote`` / ``fetch_remote_batch``
/ ``fetch_window`` / ``prefetch_local`` / ``put_local`` /
``put_remote_batch``), the *modeled* cost accounting those verbs accrue
onto the per-node ``NodeClock`` timelines (identical for every backend,
so modeled quantities never depend on which wire moved the bytes), the
shared thread pool behind the async ``submit`` API, and the lifecycle
(``start``/``close``, context manager).

Subclasses override only the two payload-movement primitives:

* :meth:`_move_fetch` — how bytes travel from an owner's ``NodeStore`` to
  the requester;
* :meth:`_move_put` — how output chunks travel to the placement owner's
  staging area.

Two-sided wires (modeled / socket / shm) share the base cost model
verbatim, so their modeled quantities never depend on which wire moved
the bytes. A backend whose FABRIC genuinely differs (the RDMA backend's
one-sided reads involve no owner CPU) additionally overrides the two
accounting seams — :meth:`_account_remote` / :meth:`_account_put` — and
documents the deviation; the lane bookkeeping (prefetch ledger, write
lane split) stays the base's job either way.

A backend that sets ``measured = True`` additionally gets wall-clock
accounting for free: the base times every movement with
``time.perf_counter_ns`` and accrues the duration onto the requester's
measured :class:`~repro.fanstore.accounting.WallClock` lane, plus the
server-side handling time (returned by ``_move_fetch``/``_move_put``)
onto the owner's measured serve lane. The modeled backend leaves the
wall clocks untouched — ``ClusterAccounting`` then reports whichever
view exists.

Callers hand the verbs resolved :class:`~repro.fanstore.wire.FetchItem`
tuples (path + sizes); the backend knows nothing about placement or
metadata.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.fanstore.accounting import NodeClock, WallClock, WindowAccount
from repro.fanstore.store import NodeStore
from repro.fanstore.wire import FetchItem, WireCodecPolicy

__all__ = ["TransportBackend"]


class TransportBackend:
    """Moves payloads between node stores; accounts modeled (and, for real
    wires, measured) cost. Abstract over the movement mechanism only."""

    #: registry name ("modeled" / "socket" / "shm" / "rdma")
    name = "base"
    #: True when the backend performs real transfers worth wall-clock timing
    measured = False

    def __init__(self, net, nodes: Dict[int, NodeStore],
                 clocks: Dict[int, NodeClock], *,
                 wall: Optional[Dict[int, WallClock]] = None,
                 num_threads: int = 8, stripes: int = 1,
                 pipeline_depth: int = 4, wire_codec: str = "none",
                 wire_policy: Optional[Dict[str, float]] = None,
                 lock: Optional[threading.RLock] = None):
        self.net = net
        self.nodes = nodes
        self.clocks = clocks
        self.wall = wall if wall is not None else {
            i: WallClock() for i in nodes}
        # wire tuning lives on the base so ClusterSpec can plumb it to ANY
        # backend uniformly; wires without connections (modeled/shm/rdma)
        # simply never consult stripes/pipeline, and the codec policy is
        # validated here either way (a bad wire_codec fails at build time)
        self.stripes = max(1, int(stripes))
        self.pipeline_depth = max(1, int(pipeline_depth))
        self.wire_policy = WireCodecPolicy(codec=wire_codec,
                                           **dict(wire_policy or {}))
        # clock accrual from pool threads. When the cluster wires in
        # ClusterAccounting.lock here, accrual and snapshot/reset/flush
        # serialize on ONE lock — the consistency contract accounting.py
        # documents. Standalone construction keeps a private lock.
        self._lock = lock if lock is not None else threading.Lock()
        self._lifecycle = threading.Lock()  # start/close state transitions
        self._pool: Optional[ThreadPoolExecutor] = None
        self._num_threads = num_threads
        self._started = False
        self._closed = False
        # fault-injection seam: a FaultInjector installed by the cluster;
        # every movement consults it BEFORE bytes move, so an injected
        # fault is indistinguishable from a real dead peer downstream
        self._faults = None

    def set_faults(self, injector) -> None:
        """Install a :class:`repro.fanstore.faults.FaultInjector` (or None
        to disable). All verbs consult it before moving bytes."""
        self._faults = injector

    def _maybe_inject(self, requester: int, owner: int, verb: str) -> None:
        """Ask the injector about one operation; raises the injected
        exception, and books any injected straggler delay as retry-free
        latency on the requester's modeled consume lane."""
        if self._faults is None:
            return
        delay = self._faults.check(requester, owner, verb)
        if delay > 0.0:
            if self.measured:
                time.sleep(delay)
            with self._lock:
                self.clocks[requester].consume_s += delay

    # ---- lifecycle ---------------------------------------------------------
    def start(self) -> "TransportBackend":
        """Bring the wire up (idempotent). The modeled backend has nothing
        to start; the socket backend spawns its per-node serving loops.
        Explicit ``start()`` also REOPENS a closed backend; the lazy path
        remote verbs use (:meth:`_lazy_start`) refuses to, so an
        undrained pool task racing ``close()`` errors instead of silently
        respawning serving loops the teardown will never see."""
        with self._lifecycle:
            if not self._started:
                self._started = True
                self._closed = False
                self._start_serving()
        return self

    def _lazy_start(self) -> None:
        """Bring the wire up from a verb (exactly once, locked). Unlike
        :meth:`start` this raises on a closed backend: the only way to get
        here after ``close()`` is an in-flight task the caller failed to
        drain, and respawning serving loops for it would leak them."""
        with self._lifecycle:
            if self._closed:
                raise RuntimeError(
                    "transport backend is closed (drain futures before "
                    "close(), or call start() to reopen)")
            if not self._started:
                self._started = True
                self._start_serving()

    def close(self) -> None:
        """Deterministic teardown: stop serving loops, drop connections,
        and join the shared I/O pool. Idempotent; the backend may be
        restarted with :meth:`start` afterwards. The state flip is locked
        against :meth:`start`; the joins run outside the lock so an
        in-flight pool task that lazily calls ``start()`` cannot deadlock
        the shutdown (callers drain their futures before closing)."""
        with self._lifecycle:
            if self._closed:
                return
            self._closed = True
            self._started = False
            pool, self._pool = self._pool, None
        self._stop_serving()
        if pool is not None:
            pool.shutdown(wait=True)

    # legacy name from the PR-1 Transport; same full teardown
    shutdown = close

    def __enter__(self) -> "TransportBackend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _start_serving(self) -> None:
        """Subclass hook: spawn serving loops / map segments."""

    def _stop_serving(self) -> None:
        """Subclass hook: join serving loops, close connections."""

    def invalidate_path(self, path: str) -> None:
        """A committed output was unlinked: drop any transport-held state
        for the name (the RDMA backend's registration table caches
        path -> segment mappings that must never serve a deleted payload).
        No-op for wires that hold no per-path state."""

    def drop_node(self, node_id: int) -> None:
        """Membership hook: ``node_id`` is dead — tear down any per-peer
        transport state (the socket backend closes the dead peer's serving
        loop and every stripe dialed to/from it; rdma drops its registered
        segments). No-op for wires that hold no per-peer state. Must be
        safe to call for a node that was never started, and must make
        future fetches against the node fail fast with a ConnectionError
        rather than hang."""

    def ensure_node(self, node_id: int) -> None:
        """Membership hook: ``node_id`` (re)joined — bring up any per-peer
        transport state a started wire needs to serve it (the socket
        backend spawns its serving loop). No-op before ``start()`` and for
        wires without per-peer state."""

    def account_retry(self, requester: int, delay_s: float, *,
                      count: int = 1) -> None:
        """Book ``count`` failover retries and their backoff on the
        requester's retry ledger. Modeled wires only accrue; measured
        wires really sleep the backoff first (the retried fetch is
        wall-timed like any other movement)."""
        slept_ns = 0
        if self.measured and delay_s > 0.0:
            t0 = time.perf_counter_ns()
            time.sleep(delay_s)
            slept_ns = time.perf_counter_ns() - t0
        with self._lock:
            clock = self.clocks[requester]
            clock.retries += count
            clock.retry_s += delay_s
            clock.consume_s += delay_s   # a demand retry blocks the consumer
            if self.measured:
                w = self.wall[requester]
                w.retries += count
                w.retry_ns += slept_ns

    # ---- movement primitives (the only parts a wire must provide) ----------
    def _move_fetch(self, requester: int, owner: int,
                    items: Sequence[FetchItem], materialize: bool,
                    verb: str) -> Tuple[List[bytes], int]:
        """Move ``items``'s payloads from ``owner`` to ``requester``.

        ``verb`` is ``"fetch"`` / ``"fetch_batch"`` / ``"fetch_window"`` so
        a framed wire can keep the transport's intent visible. Returns
        (payloads in item order, server-side handling nanoseconds — 0 when
        the wire cannot observe it)."""
        raise NotImplementedError

    def _move_put(self, writer: int, owner: int,
                  pairs: Sequence[Tuple[FetchItem, bytes]]) -> int:
        """Ship output chunks into ``owner``'s per-(writer, path) staging.
        Returns server-side handling nanoseconds."""
        raise NotImplementedError

    # ---- measured (wall-clock) accrual -------------------------------------
    def _wall_accrue(self, node_id: int, lane: str, dt_ns: int, *,
                     bytes_in: int = 0, bytes_out: int = 0,
                     requests: int = 0, owner: Optional[int] = None,
                     serve_ns: int = 0) -> None:
        with self._lock:
            w = self.wall[node_id]
            w.accrue(lane, dt_ns)
            w.bytes_in += bytes_in
            w.requests += requests
            if owner is not None:
                ow = self.wall[owner]
                ow.accrue("serve", serve_ns)
                ow.bytes_out += bytes_out

    # ---- local tier --------------------------------------------------------
    def fetch_local(self, node_id: int, item: FetchItem, *,
                    materialize: bool = True, lane: str = "consume",
                    tenant: Optional[str] = None) -> bytes:
        """Read a file the requesting node already holds (SSD tier).

        ``lane="serve_app"`` books the cost onto the concurrent serving
        lane (attributed to ``tenant``) instead of ``consume_s`` — a
        serving tenant's local read must not serialize into the trainer's
        demand timeline."""
        node = self.nodes[node_id]
        if materialize:
            t0 = time.perf_counter_ns() if self.measured else 0
            data = node.open_local(item.path)
            node.release(item.path)
            if self.measured:
                self._wall_accrue(node_id, lane,
                                  time.perf_counter_ns() - t0,
                                  bytes_in=len(data), requests=1)
        else:
            data = b""
        with self._lock:
            clock = self.clocks[node_id]
            cost = self.net.local_cost(item.size,
                                       compressed=item.compressed)
            if lane == "serve_app":
                clock.attribute_tenant(tenant or "anon", nbytes=item.size,
                                       cost_s=cost, requests=1)
            else:
                clock.consume_s += cost
            clock.local_bytes += item.size
        return data

    # ---- remote tier -------------------------------------------------------
    def fetch_remote(self, requester: int, owner: int, item: FetchItem, *,
                     materialize: bool = True, lane: str = "consume",
                     tenant: Optional[str] = None) -> bytes:
        """One synchronous round trip: one ``latency_s`` for one file."""
        data = self._timed_fetch(requester, owner, [item], materialize,
                                 "fetch", lane)[0]
        with self._lock:
            self._account_remote(requester, owner, [item], lane=lane,
                                 tenant=tenant)
        return data

    def fetch_remote_batch(self, requester: int, owner: int,
                           items: Sequence[FetchItem], *,
                           materialize: bool = True, lane: str = "consume",
                           tenant: Optional[str] = None) -> List[bytes]:
        """Coalesced fetch: K files from one owner, ONE round-trip latency.

        The requester pays ``latency_s`` once for the whole group and the
        owner pays one request-handling ``open_overhead_s`` (one message,
        one scatter-gather over its already-open partition blobs); per-byte
        costs are unchanged. See ``_account_remote`` for the exact model.
        ``lane="serve_app"`` routes the requester-side cost onto the
        concurrent serving lane with per-``tenant`` attribution.
        """
        if not items:
            return []
        out = self._timed_fetch(requester, owner, items, materialize,
                                "fetch_batch", lane)
        with self._lock:
            self._account_remote(requester, owner, items, round_trips=1,
                                 lane=lane, tenant=tenant)
        return out

    def fetch_window(self, requester: int, owner: int,
                     items: Sequence[FetchItem], *,
                     materialize: bool = True) -> List[bytes]:
        """Scheduled-prefetch fetch: one round trip for a whole lookahead
        WINDOW of files from one owner — the window may span many training
        batches, so the per-owner latency is amortized far beyond per-batch
        coalescing.

        Cost accrues on the requester's *prefetch lane*
        (``NodeClock.prefetch_s``), not ``consume_s``: the scheduler runs on
        the transport pool concurrently with demand reads, so makespan
        (``busy_s = max(consume, serve, prefetch)``) models the overlap
        instead of serializing prefetch behind consumption. Each call appends
        a :class:`WindowAccount` entry to the requester's per-window ledger.
        The owner's serve side is accounted identically to
        ``fetch_remote_batch`` (it answers one message either way).
        """
        if not items:
            return []
        out = self._timed_fetch(requester, owner, items, materialize,
                                "fetch_window", "prefetch")
        with self._lock:
            self._account_remote(requester, owner, items, round_trips=1,
                                 lane="prefetch")
        return out

    def _timed_fetch(self, requester: int, owner: int,
                     items: Sequence[FetchItem], materialize: bool,
                     verb: str, lane: str) -> List[bytes]:
        """Run the movement primitive, wall-timing it on measured wires."""
        self._maybe_inject(requester, owner, verb)
        if not self.measured:
            out, _ = self._move_fetch(requester, owner, items, materialize,
                                      verb)
            return out
        t0 = time.perf_counter_ns()
        out, serve_ns = self._move_fetch(requester, owner, items,
                                         materialize, verb)
        moved = sum(len(d) for d in out)
        self._wall_accrue(requester, lane, time.perf_counter_ns() - t0,
                          bytes_in=moved, requests=1, owner=owner,
                          bytes_out=moved, serve_ns=serve_ns)
        return out

    def prefetch_local(self, node_id: int, items: Sequence[FetchItem], *,
                       materialize: bool = True) -> List[bytes]:
        """Stage node-local files (SSD tier) into the client cache ahead of
        demand; costs accrue on the prefetch lane so the disk reads overlap
        the consume timeline."""
        node = self.nodes[node_id]
        out: List[bytes] = []
        total = 0
        cost = 0.0
        t0 = time.perf_counter_ns() if self.measured else 0
        for it in items:
            if materialize:
                data = node.open_local(it.path)
                node.release(it.path)
            else:
                data = b""
            out.append(data)
            total += it.size
            cost += self.net.local_cost(it.size, compressed=it.compressed)
        if self.measured and materialize:
            self._wall_accrue(node_id, "prefetch",
                              time.perf_counter_ns() - t0,
                              bytes_in=sum(len(d) for d in out),
                              requests=1)
        with self._lock:
            clock = self.clocks[node_id]
            clock.prefetch_s += cost
            clock.prefetch_bytes += total    # sole ledger for staged bytes
        return out

    def _account_remote(self, requester: int, owner: int,
                        items: Sequence[FetchItem], *,
                        round_trips: Optional[int] = None,
                        lane: str = "consume",
                        tenant: Optional[str] = None) -> None:
        """Accrue modeled cost; ``round_trips`` defaults to one per item.

        With ``round_trips=1`` (batched) the requester pays one ``latency_s``
        for the whole group and the owner pays one request-handling
        ``open_overhead_s``: the server answers a single message with one
        scatter-gather over its already-open partition blobs instead of K
        per-request handlings. Byte costs (NIC both sides, server storage
        read, client decompress) are per-byte and unchanged.

        ``lane="prefetch"`` books the requester side onto the concurrent
        prefetch timeline (``prefetch_s`` + per-window ledger) instead of
        ``consume_s``; ``lane="serve_app"`` books it onto the concurrent
        serving lane with per-``tenant`` attribution
        (:meth:`NodeClock.attribute_tenant`). The owner's serve side is
        lane-independent.
        """
        trips = len(items) if round_trips is None else round_trips
        stored = sum(it.stored for it in items)
        clock = self.clocks[requester]
        cost = trips * self.net.latency_s + stored / self.net.bandwidth_Bps
        for it in items:
            if it.compressed:
                cost += it.size / self.net.decompress_Bps
        if lane == "prefetch":
            clock.prefetch_s += cost
            clock.prefetch_bytes += stored
            clock.prefetch_windows += trips
            clock.prefetch_log.append(WindowAccount(
                owner=owner, files=len(items), bytes=stored, cost_s=cost))
        elif lane == "serve_app":
            clock.attribute_tenant(tenant or "anon", nbytes=stored,
                                   cost_s=cost, requests=trips)
        else:
            clock.consume_s += cost
            clock.bytes_in += stored
        oc = self.clocks[owner]
        oc.serve_s += trips * self.net.open_overhead_s
        oc.serve_s += stored / self.net.disk_bw_Bps
        oc.serve_s += stored / self.net.bandwidth_Bps
        oc.bytes_out += stored

    # ---- write path (output payloads ship TO the placement owner) ----------
    def put_local(self, node_id: int, pairs: Sequence[Tuple[FetchItem, bytes]],
                  *, lane: str = "write") -> None:
        """Persist output chunks on the writer's own store (writer == owner):
        per-chunk SSD-tier flush cost on the writer's chosen lane."""
        node = self.nodes[node_id]
        total = 0
        cost = 0.0
        t0 = time.perf_counter_ns() if self.measured else 0
        for item, data in pairs:
            node.stage_output(node_id, item.path, data)
            total += item.size
            cost += self.net.open_overhead_s + item.size / self.net.disk_bw_Bps
        if self.measured:
            self._wall_accrue(node_id, lane, time.perf_counter_ns() - t0,
                              requests=1)
        with self._lock:
            self._accrue_write(node_id, cost, total, len(pairs), lane)

    def put_remote_batch(self, writer: int, owner: int,
                         pairs: Sequence[Tuple[FetchItem, bytes]], *,
                         lane: str = "write",
                         round_trips: Optional[int] = None) -> None:
        """Ship output chunks to the placement owner. With ``round_trips=1``
        (the batched ``write_many`` fan-in) K chunks for one owner ride ONE
        message: the writer pays ``latency_s`` once on its lane and the
        owner handles one request (one ``open_overhead_s``) before the
        per-byte NIC + SSD-flush costs — the exact mirror of
        ``fetch_remote_batch`` on the read side. The carried metadata
        publish rides the same message (no separate forward)."""
        if not pairs:
            return
        self._maybe_inject(writer, owner, "put")
        if self.measured:
            t0 = time.perf_counter_ns()
            serve_ns = self._move_put(writer, owner, pairs)
            shipped = sum(len(d) for _, d in pairs)
            self._wall_accrue(writer, lane, time.perf_counter_ns() - t0,
                              requests=1, owner=owner, bytes_out=shipped,
                              serve_ns=serve_ns)
        else:
            self._move_put(writer, owner, pairs)
        trips = len(pairs) if round_trips is None else round_trips
        stored = sum(item.size for item, _ in pairs)
        with self._lock:
            self._account_put(writer, owner, stored, trips, lane)

    def _account_put(self, writer: int, owner: int, stored: int,
                     trips: int, lane: str) -> None:
        """Modeled cost of shipping ``stored`` output bytes in ``trips``
        messages: writer-side latency + NIC on its lane, owner-side
        request handling + NIC + SSD flush on its serve lane. The one
        overridable seam for fabrics with different write semantics
        (RDMA's one-sided writes skip the owner serve accrual entirely).
        Call under the transport lock."""
        cost = trips * self.net.latency_s + stored / self.net.bandwidth_Bps
        self._accrue_write(writer, cost, stored, trips, lane)
        oc = self.clocks[owner]
        oc.serve_s += trips * self.net.open_overhead_s
        oc.serve_s += stored / self.net.bandwidth_Bps
        oc.serve_s += stored / self.net.disk_bw_Bps

    def _accrue_write(self, node_id: int, cost: float, nbytes: int,
                      rpcs: int, lane: str) -> None:
        """Book writer-side cost: ``lane="write"`` is the concurrent write
        timeline (overlaps consume/prefetch in ``busy_s``); ``"consume"``
        is the legacy serialized path ``write_file``/``commit_write`` keeps."""
        clock = self.clocks[node_id]
        if lane == "write":
            clock.write_s += cost
            clock.write_bytes += nbytes
            clock.write_rpcs += rpcs
        else:
            clock.consume_s += cost

    # ---- cache tier (accounting only; payload comes from the cache) --------
    def account_cache_hit(self, node_id: int, item: FetchItem, *,
                          worker_id: int = 0, lane: str = "consume",
                          tenant: Optional[str] = None,
                          job: Optional[str] = None) -> None:
        """A client-cache hit: RAM-speed consume cost on the node, plus
        per-worker (and per-job) attribution (co-located workers share
        the node tier, so the breakdown is the only record of WHOSE read
        hit). On the serve-app lane the RAM cost lands on the concurrent
        serving timeline and the bytes are attributed to ``tenant`` as
        well."""
        with self._lock:
            clock = self.clocks[node_id]
            cost = self.net.cache_cost(item.size)
            if lane == "serve_app":
                clock.attribute_tenant(tenant or "anon", nbytes=item.size,
                                       cost_s=cost)
            else:
                clock.consume_s += cost
            clock.attribute_cache(worker_id, hit=True, nbytes=item.size,
                                  job=job)

    def account_cache_miss(self, node_id: int, *, worker_id: int = 0,
                           job: Optional[str] = None) -> None:
        with self._lock:
            self.clocks[node_id].attribute_cache(worker_id, hit=False,
                                                 job=job)

    def account_cache_eviction(self, node_id: int, count: int = 1) -> None:
        with self._lock:
            self.clocks[node_id].cache_evictions += count

    # ---- async future API --------------------------------------------------
    @property
    def pool(self) -> ThreadPoolExecutor:
        with self._lifecycle:
            if self._closed:
                # same contract as _lazy_start: submitting after close()
                # must error, not silently respawn workers that no further
                # close() would ever join
                raise RuntimeError(
                    "transport backend is closed (drain futures before "
                    "close(), or call start() to reopen)")
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self._num_threads,
                    thread_name_prefix="fanstore-io")
            return self._pool

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        """Run any fetch callable on the shared I/O pool; returns a Future."""
        return self.pool.submit(fn, *args, **kwargs)

    def fetch_remote_batch_async(self, requester: int, owner: int,
                                 items: Sequence[FetchItem], *,
                                 materialize: bool = True,
                                 lane: str = "consume",
                                 tenant: Optional[str] = None) -> Future:
        return self.submit(self.fetch_remote_batch, requester, owner, items,
                           materialize=materialize, lane=lane, tenant=tenant)

    def fetch_window_async(self, requester: int, owner: int,
                           items: Sequence[FetchItem], *,
                           materialize: bool = True) -> Future:
        return self.submit(self.fetch_window, requester, owner, items,
                           materialize=materialize)
