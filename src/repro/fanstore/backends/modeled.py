"""The modeled backend: PR 1's in-process transport, now one wire among
several.

``InterconnectModel`` is the first-order fabric cost model (per-message
latency + per-byte cost) every backend accounts against; it lives here
because the modeled backend is its reference consumer (it is re-exported
from :mod:`repro.fanstore.transport` and :mod:`repro.fanstore.cluster`
for compatibility).

``ModeledBackend`` moves payloads by direct in-process calls against the
owner's ``NodeStore`` — exactly what the pre-seam ``Transport`` did, and
regression-pinned to stay byte-for-byte identical: the movement is the
same ``serve_remote``/``stage_output`` call sequence, and the modeled
clock accrual lives unchanged in :class:`TransportBackend`. It records no
measured wall time (``measured = False``): predictions stay the modeled
clocks' job, hardware truth is the socket/shm backends' job.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.fanstore.backends.base import TransportBackend
from repro.fanstore.wire import FetchItem

__all__ = ["InterconnectModel", "ModeledBackend"]


@dataclass
class InterconnectModel:
    """First-order fabric model: per-message latency + per-byte cost.

    Defaults approximate the paper's CPU cluster (100 Gb/s OPA, ~1.5 us):
    latency_s per round trip, bandwidth_Bps per NIC direction. Local tier
    is modeled with disk_bw_Bps (SSD) and a per-open syscall overhead.
    cache_bw_Bps is the client-side read-cache (RAM) service rate.
    """
    latency_s: float = 1.5e-6
    bandwidth_Bps: float = 100e9 / 8
    disk_bw_Bps: float = 2.0e9
    open_overhead_s: float = 3e-6
    decompress_Bps: float = 1.5e9     # LZSS-class decode rate per core
    cache_bw_Bps: float = 20e9        # DRAM-resident read cache
    # one-sided (RDMA-class) arm: a registered read skips the owner's CPU
    # entirely — the requester pays a registration-table lookup instead of
    # a request/response latency, then line-rate bytes. Only the rdma
    # backend consults these.
    rdma_lookup_s: float = 2e-7       # table lookup + doorbell, no RTT
    rdma_bandwidth_Bps: float = 100e9 / 8

    def remote_cost(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps

    def local_cost(self, nbytes: int, *, compressed: bool = False) -> float:
        t = self.open_overhead_s + nbytes / self.disk_bw_Bps
        if compressed:
            t += nbytes / self.decompress_Bps
        return t

    def cache_cost(self, nbytes: int) -> float:
        return nbytes / self.cache_bw_Bps


class ModeledBackend(TransportBackend):
    """In-process payload movement + modeled accounting (the default)."""

    name = "modeled"
    measured = False

    def _move_fetch(self, requester: int, owner: int,
                    items: Sequence[FetchItem], materialize: bool,
                    verb: str) -> Tuple[List[bytes], int]:
        if materialize:
            out = [self.nodes[owner].serve_remote(it.path) for it in items]
        else:
            out = [b"" for _ in items]
        return out, 0

    def _move_put(self, writer: int, owner: int,
                  pairs: Sequence[Tuple[FetchItem, bytes]]) -> int:
        node = self.nodes[owner]
        for item, data in pairs:
            node.stage_output(writer, item.path, data)
        return 0
