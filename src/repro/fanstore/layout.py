"""Partition binary format — the paper's Table 3.

A partition is one binary blob holding an exclusive subset of the dataset's
files::

    field       num_files | file_name | stat    | compressed_size | data | ...
    byte_range  0 - 3     | 4 - 259   | 260-403 | 404 - 411       | 412..|

Notes on fidelity:
  * Table 3 gives ``num_files`` the byte range 0-3 (u32) while the prose says
    "an integer (eight bytes)". The table fully determines all later offsets
    (file_name at 4, stat at 260, ...), so we follow the table: u32 count.
  * ``file_name`` is a 256-byte NUL-padded relative path.
  * ``stat`` is a 144-byte record laid out like glibc's x86-64 ``struct stat``
    (see :mod:`repro.fanstore.metadata`).
  * ``compressed_size`` is u64; 0 means "stored uncompressed" and the true
    length is ``stat.st_size`` (paper §5.2 semantics).
"""
from __future__ import annotations

import io
import struct
from dataclasses import dataclass
from typing import Iterator, List, Sequence, Tuple

from repro.fanstore.metadata import StatRecord
from repro.fanstore import lzss

NAME_LEN = 256
STAT_LEN = 144
HEADER_FMT = "<I"          # num_files, u32 per Table 3
CSIZE_FMT = "<Q"           # compressed_size, u64

_CODECS = ("none", "lzss", "zstd")


@dataclass(frozen=True)
class FileRecord:
    """One file inside a partition: header fields + payload offsets."""
    path: str
    stat: StatRecord
    compressed_size: int      # 0 => stored raw (length == stat.st_size)
    data_offset: int          # absolute offset of payload inside the partition
    codec: str = "lzss"

    @property
    def stored_size(self) -> int:
        return self.compressed_size if self.compressed_size else self.stat.st_size


@dataclass
class Partition:
    """A parsed partition: raw bytes + an index of its records."""
    blob: bytes
    records: List[FileRecord]

    @property
    def num_files(self) -> int:
        return len(self.records)

    def read_file(self, rec: FileRecord) -> bytes:
        raw = self.blob[rec.data_offset: rec.data_offset + rec.stored_size]
        if rec.compressed_size == 0:
            return bytes(raw)
        return _decompress(rec.codec, bytes(raw), rec.stat.st_size)


def _compress(codec: str, data: bytes) -> bytes:
    if codec == "lzss":
        return lzss.compress(data)
    if codec == "zstd":
        import zstandard
        return zstandard.ZstdCompressor(level=3).compress(data)
    raise ValueError(f"unknown codec {codec!r}")


def _decompress(codec: str, data: bytes, orig_size: int) -> bytes:
    if codec == "lzss":
        out = lzss.decompress(data)
    elif codec == "zstd":
        import zstandard
        out = zstandard.ZstdDecompressor().decompress(data, max_output_size=orig_size)
    else:
        raise ValueError(f"unknown codec {codec!r}")
    if len(out) != orig_size:
        raise IOError(f"decompressed size {len(out)} != stat.st_size {orig_size}")
    return out


def pack_partition(
    files: Sequence[Tuple[str, bytes]],
    *,
    compress: bool = False,
    codec: str = "lzss",
    stat_template: StatRecord | None = None,
) -> bytes:
    """Pack ``(path, data)`` pairs into one partition blob (paper §5.2).

    Compression is per-file and *adaptive* as in the paper: if the compressed
    payload is not smaller, the file is stored raw with compressed_size=0.
    """
    if codec not in _CODECS:
        raise ValueError(f"codec must be one of {_CODECS}")
    if len(files) >= 2 ** 32:
        raise ValueError("partition file count exceeds u32")
    out = io.BytesIO()
    out.write(struct.pack(HEADER_FMT, len(files)))
    for path, data in files:
        name = path.encode()
        if len(name) > NAME_LEN:
            raise ValueError(f"path longer than {NAME_LEN} bytes: {path!r}")
        st = (stat_template or StatRecord.for_data(len(data))).replace(st_size=len(data))
        payload = data
        csize = 0
        if compress and len(data) > 0:
            comp = _compress(codec, data)
            if len(comp) < len(data):
                payload, csize = comp, len(comp)
        out.write(name.ljust(NAME_LEN, b"\0"))
        out.write(st.pack())
        out.write(struct.pack(CSIZE_FMT, csize))
        out.write(payload)
    return out.getvalue()


def iter_partition(blob: bytes, *, codec: str = "lzss") -> Iterator[FileRecord]:
    """Walk a partition blob yielding :class:`FileRecord` (no payload copies)."""
    (num_files,) = struct.unpack_from(HEADER_FMT, blob, 0)
    off = struct.calcsize(HEADER_FMT)
    for _ in range(num_files):
        name = blob[off: off + NAME_LEN].rstrip(b"\0").decode()
        off += NAME_LEN
        st = StatRecord.unpack(blob[off: off + STAT_LEN])
        off += STAT_LEN
        (csize,) = struct.unpack_from(CSIZE_FMT, blob, off)
        off += struct.calcsize(CSIZE_FMT)
        rec = FileRecord(path=name, stat=st, compressed_size=csize,
                         data_offset=off, codec=codec)
        off += rec.stored_size
        yield rec
    if off != len(blob):
        raise IOError(f"partition trailing bytes: parsed {off} of {len(blob)}")


def load_partition(blob: bytes, *, codec: str = "lzss") -> Partition:
    return Partition(blob=blob, records=list(iter_partition(blob, codec=codec)))
