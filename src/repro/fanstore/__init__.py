"""FanStore host tier — the faithful reproduction of the paper's runtime FS.

Layers:
  layout      Table-3 partition binary format
  lzss        LZSS compression codec (the paper uses LZSSE8)
  metadata    stat records, replicated input metadata tables
  placement   path -> owner policies (modulo / consistent-hash ring) and
              replica selection (least-loaded / power-of-two-choices)
  store       per-node store: partitions, refcount cache, write buffers
  wire        framed message protocol (the byte format real backends speak)
  backends    pluggable transports behind one verb seam: modeled
              (interconnect cost model), socket (real TCP serving loops),
              shm (zero-copy co-located fast path)
  transport   compatibility shim over wire + backends (Transport is the
              modeled backend)
  cache       optional per-node byte-budget read cache (LRU / Belady / 2Q)
  prefetch    clairvoyant epoch-horizon schedule + window prefetch driver
  accounting  per-node clocks + cluster aggregates for the benchmarks
  metrics     observability plane: reduce-mode accumulators, the
              cluster-owned MetricsCollector, streaming JsonlSink, and
              declarative SloGuard threshold checks
  cluster     the composition of the above behind one deployment object
  api         FanStoreSession: the unified descriptor-based client surface
              (fd table, batched read/write verbs, CheckpointWriter)
  fs          deprecated POSIX-style file-object adapter over the session
  intercept   optional path- and fd-level call interception
  prepare     the data-preparation program (files -> partitions)
"""
from repro.fanstore.layout import Partition, pack_partition, iter_partition, FileRecord
from repro.fanstore.metadata import StatRecord, MetadataTable
from repro.fanstore.placement import (ConsistentHashRing, ModuloPlacement,
                                      RingPlacement, LeastLoadedSelector,
                                      PowerOfTwoSelector)
from repro.fanstore.store import NodeStore
from repro.fanstore.accounting import (ClusterAccounting, NodeClock,
                                       WallClock, WindowAccount)
from repro.fanstore.backends import (BACKENDS, ModeledBackend, SharedMemoryBackend,
                                     ShmArena, SocketBackend, TransportBackend,
                                     make_backend)
from repro.fanstore.transport import FetchItem, InterconnectModel, Transport
from repro.fanstore.cache import (BeladyCache, ByteCache, ByteLRUCache,
                                  CacheStats, NodeCacheTier, TwoQCache,
                                  make_cache)
from repro.fanstore.metrics import (JsonlSink, MetricsCollector, Mode,
                                    QuantileSketch, Reduce, Ref, SloGuard,
                                    check_slos)
from repro.fanstore.spec import ClusterSpec, WorkerContext
from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.prefetch import (EpochSchedule, PrefetchScheduler,
                                     ScheduledRead, SchedulerGroup)
from repro.fanstore.api import (CheckpointWriter, FanStoreDirEntry,
                                FanStoreSession)
from repro.fanstore.fs import FanStoreFS
from repro.fanstore.prepare import prepare_dataset

__all__ = [
    "Partition", "pack_partition", "iter_partition", "FileRecord",
    "StatRecord", "ConsistentHashRing", "MetadataTable",
    "ModuloPlacement", "RingPlacement", "LeastLoadedSelector",
    "PowerOfTwoSelector", "ClusterAccounting", "NodeClock", "WallClock",
    "WindowAccount", "FetchItem", "Transport", "TransportBackend",
    "ModeledBackend", "SocketBackend", "SharedMemoryBackend", "ShmArena",
    "BACKENDS", "make_backend", "ByteCache", "ByteLRUCache", "BeladyCache",
    "TwoQCache", "CacheStats", "NodeCacheTier", "make_cache",
    "EpochSchedule", "PrefetchScheduler", "ScheduledRead", "SchedulerGroup",
    "NodeStore", "FanStoreCluster", "ClusterSpec", "WorkerContext",
    "InterconnectModel",
    "MetricsCollector", "Reduce", "Mode", "QuantileSketch", "JsonlSink",
    "SloGuard", "Ref", "check_slos",
    "FanStoreSession", "FanStoreDirEntry", "CheckpointWriter", "FanStoreFS",
    "prepare_dataset",
]
