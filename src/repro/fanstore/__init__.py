"""FanStore host tier — the faithful reproduction of the paper's runtime FS.

Layers:
  layout     Table-3 partition binary format
  lzss       LZSS compression codec (the paper uses LZSSE8)
  metadata   stat records, replicated input metadata, consistent-hash ring
  store      per-node store: partitions, refcount cache, write buffers
  cluster    simulated multi-node deployment with an interconnect model
  fs         POSIX-style file API under a /fanstore mount prefix
  intercept  optional builtins.open/os.stat/os.listdir interception
  prepare    the data-preparation program (files -> partitions)
"""
from repro.fanstore.layout import Partition, pack_partition, iter_partition, FileRecord
from repro.fanstore.metadata import StatRecord, ConsistentHashRing, MetadataTable
from repro.fanstore.store import NodeStore
from repro.fanstore.cluster import FanStoreCluster, InterconnectModel
from repro.fanstore.fs import FanStoreFS
from repro.fanstore.prepare import prepare_dataset

__all__ = [
    "Partition", "pack_partition", "iter_partition", "FileRecord",
    "StatRecord", "ConsistentHashRing", "MetadataTable",
    "NodeStore", "FanStoreCluster", "InterconnectModel", "FanStoreFS",
    "prepare_dataset",
]
