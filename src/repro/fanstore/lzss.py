"""LZSS codec — Lempel–Ziv–Storer–Szymanski textual substitution.

The paper compresses partitions with LZSSE8 (an SSE-accelerated LZSS variant).
This is a faithful, dependency-free LZSS with the classic parameters:

  * 4 KiB sliding window (12-bit match offset)
  * match lengths 3..18 (4-bit length field, bias 3)
  * token stream framed by flag bytes, 8 tokens per flag (bit=1 -> literal)

Format:  [u32 original_size] [flag byte] [8 tokens] [flag byte] ...
A match token is two bytes: ``oooooooo oooollll`` (12-bit offset back from the
current position, 1-based; 4-bit length-3).

The encoder is greedy with a 3-byte hash chain, like LZSSE's fast levels.
Pure Python keeps it portable; throughput is adequate for the partition sizes
used in tests/benchmarks, and the benchmark harness also exposes zstd as the
"production speed" codec (see DESIGN.md §2).
"""
from __future__ import annotations

import struct

WINDOW = 1 << 12          # 4096
MIN_MATCH = 3
MAX_MATCH = MIN_MATCH + 15  # 18
_CHAIN = 32               # max hash-chain probes (compression/speed tradeoff)


def compress(data: bytes, *, max_probes: int = _CHAIN) -> bytes:
    """Greedy LZSS encode. Returns header + token stream."""
    n = len(data)
    out = bytearray(struct.pack("<I", n))
    if n == 0:
        return bytes(out)
    # hash of 3-byte prefix -> list of recent positions (most recent last)
    table: dict = {}
    i = 0
    flags_pos = -1
    flag = 0
    nbits = 0

    def _flush_flag():
        nonlocal flags_pos, flag, nbits
        if flags_pos >= 0:
            out[flags_pos] = flag
        flags_pos = len(out)
        out.append(0)
        flag = 0
        nbits = 0

    _flush_flag()
    while i < n:
        best_len = 0
        best_off = 0
        if i + MIN_MATCH <= n:
            key = data[i: i + MIN_MATCH]
            chain = table.get(key)
            if chain:
                lo = i - WINDOW
                probes = 0
                for j in reversed(chain):
                    if j < lo or probes >= max_probes:
                        break
                    probes += 1
                    # extend match
                    k = 0
                    maxk = min(MAX_MATCH, n - i)
                    while k < maxk and data[j + k] == data[i + k]:
                        k += 1
                    if k > best_len:
                        best_len, best_off = k, i - j
                        if k == MAX_MATCH:
                            break
        if best_len >= MIN_MATCH:
            token = ((best_off - 1) << 4) | (best_len - MIN_MATCH)
            out += struct.pack("<H", token)
            # index every covered position (bounded chains)
            end = i + best_len
            while i < end and i + MIN_MATCH <= n:
                key = data[i: i + MIN_MATCH]
                chain = table.setdefault(key, [])
                chain.append(i)
                if len(chain) > 4 * max_probes:
                    del chain[: 2 * max_probes]
                i += 1
            i = end
        else:
            flag |= 1 << nbits
            out.append(data[i])
            if i + MIN_MATCH <= n:
                key = data[i: i + MIN_MATCH]
                chain = table.setdefault(key, [])
                chain.append(i)
                if len(chain) > 4 * max_probes:
                    del chain[: 2 * max_probes]
            i += 1
        nbits += 1
        if nbits == 8:
            _flush_flag()
    out[flags_pos] = flag
    return bytes(out)


def decompress(blob: bytes) -> bytes:
    """Decode a :func:`compress` stream back to the original bytes."""
    (n,) = struct.unpack_from("<I", blob, 0)
    pos = 4
    out = bytearray()
    while len(out) < n:
        flag = blob[pos]
        pos += 1
        for bit in range(8):
            if len(out) >= n:
                break
            if flag & (1 << bit):
                out.append(blob[pos])
                pos += 1
            else:
                (token,) = struct.unpack_from("<H", blob, pos)
                pos += 2
                off = (token >> 4) + 1
                length = (token & 0xF) + MIN_MATCH
                start = len(out) - off
                if start < 0:
                    raise IOError("corrupt LZSS stream: offset before start")
                for k in range(length):      # may self-overlap (RLE-style)
                    out.append(out[start + k])
    return bytes(out)
