"""LZSS codec — Lempel–Ziv–Storer–Szymanski textual substitution.

The paper compresses partitions with LZSSE8 (an SSE-accelerated LZSS variant).
This is a faithful, dependency-free LZSS with the classic parameters:

  * 4 KiB sliding window (12-bit match offset)
  * match lengths 3..18 (4-bit length field, bias 3)
  * token stream framed by flag bytes, 8 tokens per flag (bit=1 -> literal)

Format:  [u32 original_size] [flag byte] [8 tokens] [flag byte] ...
A match token is two bytes: ``oooooooo oooollll`` (12-bit offset back from the
current position, 1-based; 4-bit length-3).

The encoder is greedy with a 3-byte hash chain, like LZSSE's fast levels.
Pure Python keeps it portable; :func:`compress` is the tuned hot loop
(numpy-assisted integer prefix keys, a one-byte candidate prune before each
match extension, and flag/token emission without per-token ``struct`` calls)
and :func:`compress_reference` is the straightforward transliteration of the
format — both produce byte-identical streams (``benchmarks/compression.py``
asserts the identity and the >=2x encode speedup). The benchmark harness
also exposes zstd as the "production speed" codec (see DESIGN.md §2).
"""
from __future__ import annotations

import struct
from collections import deque as _deque
from itertools import islice as _islice

try:                       # numpy only accelerates key precomputation
    import numpy as _np
except ImportError:        # pragma: no cover - numpy is a repo-wide dep
    _np = None

WINDOW = 1 << 12          # 4096
MIN_MATCH = 3
MAX_MATCH = MIN_MATCH + 15  # 18
_CHAIN = 32               # max hash-chain probes (compression/speed tradeoff)


def _prefix_keys(data: bytes):
    """24-bit int key per position: data[i] | data[i+1]<<8 | data[i+2]<<16.

    Equal keys <=> equal 3-byte prefixes, so chains behave exactly like the
    reference encoder's bytes-keyed table — without allocating a 3-byte
    slice per position.
    """
    if len(data) < MIN_MATCH:
        return []
    if _np is not None:
        arr = _np.frombuffer(data, dtype=_np.uint8).astype(_np.uint32)
        return (arr[:-2] | (arr[1:-1] << 8) | (arr[2:] << 16)).tolist()
    return [data[i] | (data[i + 1] << 8) | (data[i + 2] << 16)
            for i in range(len(data) - 2)]


def compress(data: bytes, *, max_probes: int = _CHAIN) -> bytes:
    """Greedy LZSS encode. Returns header + token stream.

    Byte-identical to :func:`compress_reference` (same greedy choices, same
    bounded chains); only the constant factors differ.
    """
    n = len(data)
    out = bytearray(struct.pack("<I", n))
    if n == 0:
        return bytes(out)
    keys = _prefix_keys(data)
    nk = n - 2                      # positions with a full 3-byte prefix
    # int key -> recent positions, oldest first. A bounded deque keeps the
    # most recent 4*max_probes entries — a superset of what the reference
    # encoder's trimmed lists retain (they never drop below 2*max_probes),
    # and the scan only ever reads the newest max_probes, so greedy choices
    # are identical while append stays O(1) with no length checks.
    table: dict = {}
    tget = table.get
    d = data
    append = out.append
    i = 0
    flags_pos = len(out)
    append(0)
    flag = 0
    nbits = 0
    depth = 4 * max_probes
    while i < n:
        best_len = 0
        best_off = 0
        chain = tget(keys[i]) if i < nk else None
        if chain:
            lo = i - WINDOW
            maxk = MAX_MATCH if n - i > MAX_MATCH else n - i
            bl = 0
            prune = -1          # d[i + bl], cached across probes
            # islice caps the probe count without a per-iteration counter;
            # chains at or under the cap skip the wrapper entirely
            recent = reversed(chain)
            if len(chain) > max_probes:
                recent = _islice(recent, max_probes)
            for j in recent:
                if j < lo:
                    break
                # a longer match needs d[j+bl] == d[i+bl]; one byte
                # rules out most candidates without extending
                if bl and (bl >= maxk or d[j + bl] != prune):
                    continue
                # same chain => same 3-byte prefix: extension starts at 3
                k = MIN_MATCH
                while k < maxk and d[j + k] == d[i + k]:
                    k += 1
                if k > bl:
                    bl, best_off = k, i - j
                    if k == MAX_MATCH:
                        break
                    if k < maxk:
                        prune = d[i + k]
            best_len = bl
        if best_len >= MIN_MATCH:
            token = ((best_off - 1) << 4) | (best_len - MIN_MATCH)
            append(token & 0xFF)
            append(token >> 8)
            # index every covered position (bounded chains)
            end = i + best_len
            if chain is None and i < nk:
                table[keys[i]] = chain = _deque((), depth)
            if chain is not None:
                chain.append(i)
            pos = i + 1
            stop = end if end < nk else nk
            for ki in keys[pos:stop]:
                c = tget(ki)
                if c is None:
                    table[ki] = _deque((pos,), depth)
                else:
                    c.append(pos)
                pos += 1
            i = end
        else:
            flag |= 1 << nbits
            append(d[i])
            if i < nk:
                if chain is None:
                    table[keys[i]] = _deque((i,), depth)
                else:
                    chain.append(i)
            i += 1
        nbits += 1
        if nbits == 8:
            out[flags_pos] = flag
            flags_pos = len(out)
            append(0)
            flag = 0
            nbits = 0
    out[flags_pos] = flag
    return bytes(out)


def compress_reference(data: bytes, *, max_probes: int = _CHAIN) -> bytes:
    """The straightforward (slow) encoder — the format's executable spec.

    Kept for the byte-identity + speedup assertions in
    ``benchmarks/compression.py`` and the regression tests.
    """
    n = len(data)
    out = bytearray(struct.pack("<I", n))
    if n == 0:
        return bytes(out)
    # hash of 3-byte prefix -> list of recent positions (most recent last)
    table: dict = {}
    i = 0
    flags_pos = -1
    flag = 0
    nbits = 0

    def _flush_flag():
        nonlocal flags_pos, flag, nbits
        if flags_pos >= 0:
            out[flags_pos] = flag
        flags_pos = len(out)
        out.append(0)
        flag = 0
        nbits = 0

    _flush_flag()
    while i < n:
        best_len = 0
        best_off = 0
        if i + MIN_MATCH <= n:
            key = data[i: i + MIN_MATCH]
            chain = table.get(key)
            if chain:
                lo = i - WINDOW
                probes = 0
                for j in reversed(chain):
                    if j < lo or probes >= max_probes:
                        break
                    probes += 1
                    # extend match
                    k = 0
                    maxk = min(MAX_MATCH, n - i)
                    while k < maxk and data[j + k] == data[i + k]:
                        k += 1
                    if k > best_len:
                        best_len, best_off = k, i - j
                        if k == MAX_MATCH:
                            break
        if best_len >= MIN_MATCH:
            token = ((best_off - 1) << 4) | (best_len - MIN_MATCH)
            out += struct.pack("<H", token)
            # index every covered position (bounded chains)
            end = i + best_len
            while i < end and i + MIN_MATCH <= n:
                key = data[i: i + MIN_MATCH]
                chain = table.setdefault(key, [])
                chain.append(i)
                if len(chain) > 4 * max_probes:
                    del chain[: 2 * max_probes]
                i += 1
            i = end
        else:
            flag |= 1 << nbits
            out.append(data[i])
            if i + MIN_MATCH <= n:
                key = data[i: i + MIN_MATCH]
                chain = table.setdefault(key, [])
                chain.append(i)
                if len(chain) > 4 * max_probes:
                    del chain[: 2 * max_probes]
            i += 1
        nbits += 1
        if nbits == 8:
            _flush_flag()
    out[flags_pos] = flag
    return bytes(out)


def decompress(blob: bytes) -> bytes:
    """Decode a :func:`compress` stream back to the original bytes."""
    (n,) = struct.unpack_from("<I", blob, 0)
    pos = 4
    out = bytearray()
    while len(out) < n:
        flag = blob[pos]
        pos += 1
        for bit in range(8):
            if len(out) >= n:
                break
            if flag & (1 << bit):
                out.append(blob[pos])
                pos += 1
            else:
                (token,) = struct.unpack_from("<H", blob, pos)
                pos += 2
                off = (token >> 4) + 1
                length = (token & 0xF) + MIN_MATCH
                start = len(out) - off
                if start < 0:
                    raise IOError("corrupt LZSS stream: offset before start")
                for k in range(length):      # may self-overlap (RLE-style)
                    out.append(out[start + k])
    return bytes(out)
