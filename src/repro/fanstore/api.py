"""The unified descriptor-based client API (paper §5.5, session form).

The real FanStore detours glibc so unmodified binaries see one POSIX
surface. Our Python-idiomatic equivalent grew three overlapping entry
points instead (``FanStoreFS`` file objects, raw ``FanStoreCluster``
methods, ``PrefetchLoader`` plumbing). :class:`FanStoreSession` is the one
surface they all route through now: a per-process file-descriptor table
with ``open/pread/pwrite/fsync/close/opendir`` semantics over the layered
engine, plus the batched verbs (``read_many``/``write_many``/
``prefetch_window``) that make the engine fast.

Consistency surface (paper §3.5): multi-read / single-write. Reads
materialize the whole decompressed payload at ``open`` (so ``pread``/
``lseek`` are RAM operations); writes are append-only, streamed to the
placement owner by ``fsync`` (the write lane), and become visible on
``close``.

:class:`CheckpointWriter` rides on the session: it chunks checkpoint
shards through ``write``/``fsync`` so each chunk's fabric shipment (on the
concurrent ``NodeClock.write_s`` lane) overlaps both the production of the
next chunk and any active prefetch window — epoch makespan models
``max(consume, serve, prefetch, write)`` instead of write-then-prefetch
serialization.

Old names remain as deprecation shims: ``FanStoreFS``/``FanStoreFile``
(:mod:`repro.fanstore.fs`) are thin adapters over a session, and
``FanStoreCluster.write_file`` is the per-file serialized writer.
"""
from __future__ import annotations

import io
import json
import os
import threading
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.metadata import StatRecord
from repro.fanstore.spec import WorkerContext

__all__ = ["MOUNT", "FD_BASE", "FanStoreSession", "FanStoreDirEntry",
           "CheckpointWriter", "WorkerContext"]

MOUNT = "/fanstore"

# session fds start far above any real OS fd so the interception layer can
# route os.read/os.write/os.close by value without a table lookup race
FD_BASE = 1 << 20

_WRITE_FLAGS = os.O_WRONLY | os.O_RDWR


@dataclass
class _OpenFile:
    """One descriptor-table entry."""
    path: str                     # store-relative (mount stripped)
    writing: bool
    lane: str                     # "write" (concurrent) or "consume" (legacy)
    pos: int = 0
    data: Optional[bytes] = None  # read mode: whole materialized payload


class FanStoreDirEntry:
    """``os.DirEntry``-shaped result of :meth:`FanStoreSession.scandir`."""

    __slots__ = ("name", "path", "_st")

    def __init__(self, name: str, path: str, st: StatRecord):
        self.name = name
        self.path = path
        self._st = st

    def is_dir(self, *, follow_symlinks: bool = True) -> bool:
        return self._st.is_dir

    def is_file(self, *, follow_symlinks: bool = True) -> bool:
        return not self._st.is_dir

    def is_symlink(self) -> bool:
        return False

    def stat(self, *, follow_symlinks: bool = True) -> StatRecord:
        return self._st

    def inode(self) -> int:
        return self._st.st_ino

    def __fspath__(self) -> str:
        return self.path

    def __repr__(self) -> str:
        return f"<FanStoreDirEntry {self.name!r}>"


class _ScandirIterator:
    """Context-manager iterator, so ``os.walk`` over an intercepted mount
    works unmodified."""

    def __init__(self, entries: List[FanStoreDirEntry]):
        self._it = iter(entries)

    def __iter__(self) -> Iterator[FanStoreDirEntry]:
        return self._it

    def __next__(self) -> FanStoreDirEntry:
        return next(self._it)

    def __enter__(self) -> "_ScandirIterator":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def close(self) -> None:
        pass


class FanStoreSession:
    """The per-process client: node-local descriptor table over the engine.

    Every consumer goes through one of these — the POSIX-style adapters
    (``FanStoreFS``, interception), the data pipeline, checkpointing, the
    examples, and the benchmarks — instead of picking among layers.

    Paths may be given mount-prefixed (``/fanstore/train/x.bin``) or
    store-relative (``train/x.bin``); both resolve to the same file.

    Sessions are bound to a :class:`~repro.fanstore.spec.WorkerContext`
    (node + worker coordinates in the declared topology) — prefer
    ``cluster.connect(node_id, worker_id)`` over constructing directly.
    Co-located sessions (same node, different worker) share that node's
    cache tier; each read is attributed to its worker.

    ``lane`` picks the writer-side timeline for fd writes: ``"write"``
    (default) is the concurrent lane that overlaps demand reads and
    prefetch; ``"consume"`` reproduces the legacy serialized
    ``write_file`` accounting (the FS shim uses it).
    """

    def __init__(self, cluster: FanStoreCluster, node_id: int, *,
                 worker_id: int = 0, mount: str = MOUNT,
                 lane: str = "write", read_lane: str = "consume",
                 tenant: Optional[str] = None,
                 job: Optional[str] = None):
        self.cluster = cluster
        self.context = WorkerContext(node_id, worker_id)
        # direct construction must reject out-of-range coordinates just
        # like cluster.connect() — otherwise a bad worker_id fails late
        # (first cached read) or silently (cache disabled)
        declared = getattr(cluster, "workers_per_node", None)
        if declared is not None and worker_id >= declared:
            raise ValueError(
                f"worker_id {worker_id} outside workers_per_node="
                f"{declared} (declare more workers in the ClusterSpec)")
        self.node_id = node_id
        self.worker_id = worker_id
        self.mount = mount.rstrip("/")
        self.lane = lane
        # tenant-aware read routing (the serving plane): read_lane
        # "serve_app" books every read onto the concurrent serving
        # timeline attributed to `tenant` — cluster.connect(node, worker,
        # read_lane="serve_app", tenant="t-003") is how ServeGroup opens
        # its tenant sessions
        self.read_lane = read_lane
        self.tenant = tenant
        # multi-job seam: several jobs (train + eval) attach to one
        # namespace and share the node's cache tier; every read this
        # session issues is attributed to `job` on the tier ledger and
        # the NodeClock — cluster.connect(node, worker, job="eval") is
        # how the second job opens its sessions
        self.job = job
        self._fds: Dict[int, _OpenFile] = {}
        self._next_fd = FD_BASE
        self._lock = threading.Lock()

    # ---- path handling -----------------------------------------------------
    def resolve(self, path: str) -> str:
        """Strip the mount prefix; accept store-relative paths as-is."""
        path = os.fspath(path)
        if path == self.mount or path.startswith(self.mount + "/"):
            return path[len(self.mount):].strip("/")
        if path.startswith("/"):
            raise FileNotFoundError(
                f"{path}: outside FanStore mount {self.mount}")
        return path.strip("/")

    def owns(self, path: str) -> bool:
        path = os.fspath(path)
        return path == self.mount or path.startswith(self.mount + "/")

    # ---- descriptor table --------------------------------------------------
    def _alloc(self, entry: _OpenFile) -> int:
        with self._lock:
            fd = self._next_fd
            self._next_fd += 1
            self._fds[fd] = entry
        return fd

    def _entry(self, fd: int) -> _OpenFile:
        entry = self._fds.get(fd)
        if entry is None:
            raise OSError(9, "Bad file descriptor", str(fd))
        return entry

    def owns_fd(self, fd: int) -> bool:
        return fd in self._fds

    @property
    def open_fds(self) -> int:
        return len(self._fds)

    # ---- open/close --------------------------------------------------------
    @staticmethod
    def _writing_from(mode_or_flags: Union[str, int]) -> bool:
        if isinstance(mode_or_flags, int):
            return bool(mode_or_flags & _WRITE_FLAGS)
        mode = mode_or_flags.replace("b", "")
        if mode in ("r", "r+"):
            return False
        if mode in ("w", "x", "a", "w+", "x+"):
            return True
        raise ValueError(f"unsupported mode {mode_or_flags!r}")

    def open(self, path: str, mode_or_flags: Union[str, int] = "rb") -> int:
        """POSIX-style open: returns an integer descriptor. Accepts either a
        stdlib mode string (``"rb"``/``"wb"``/...) or ``os.O_*`` flags (the
        fd-level interception path)."""
        rel = self.resolve(path)
        if self._writing_from(mode_or_flags):
            self.cluster.write_begin(self.node_id, rel)
            return self._alloc(_OpenFile(rel, True, self.lane))
        data = self.cluster.read(self.node_id, rel,
                                 worker_id=self.worker_id,
                                 lane=self.read_lane, tenant=self.tenant,
                                 job=self.job)
        return self._alloc(_OpenFile(rel, False, self.lane, data=data))

    def close(self, fd: int) -> Optional[StatRecord]:
        """Close a descriptor. Closing a write fd commits it: the remaining
        buffer ships to the placement owner and the file becomes globally
        visible (returns its published stat)."""
        entry = self._entry(fd)
        try:
            if entry.writing:
                return self.cluster.commit_write(self.node_id, entry.path,
                                                 lane=entry.lane)
            return None
        finally:
            del self._fds[fd]

    def abort(self, fd: int) -> None:
        """Discard a descriptor without committing: an open write's
        buffered AND already-fsync'd (owner-staged) bytes are dropped, so
        a later writer of the same path starts clean."""
        entry = self._entry(fd)
        try:
            if entry.writing:
                self.cluster.abort_write(self.node_id, entry.path)
        finally:
            del self._fds[fd]

    # ---- reads -------------------------------------------------------------
    def pread(self, fd: int, count: int = -1,
              offset: Optional[int] = None) -> bytes:
        """Positional read; ``offset=None`` reads at (and advances) the
        cursor, an explicit offset leaves the cursor alone."""
        entry = self._entry(fd)
        if entry.writing or entry.data is None:
            raise io.UnsupportedOperation("not open for reading")
        at = entry.pos if offset is None else offset
        if count is None or count < 0:
            out = entry.data[at:]
        else:
            out = entry.data[at: at + count]
        if offset is None:
            entry.pos = at + len(out)
        return out

    def read(self, fd: int, count: int = -1) -> bytes:
        return self.pread(fd, count)

    # ---- writes ------------------------------------------------------------
    def pwrite(self, fd: int, data: bytes,
               offset: Optional[int] = None) -> int:
        """Append-only positional write: the effective offset (explicit, or
        the fd cursor — which an ``lseek`` may have moved) must equal the
        bytes written so far (outputs are write-once streams, §3.5).
        Seek-back-and-overwrite errors instead of silently appending."""
        entry = self._entry(fd)
        if not entry.writing:
            raise io.UnsupportedOperation("not open for writing")
        written = self.cluster.nodes[self.node_id].write_size(entry.path)
        at = entry.pos if offset is None else offset
        if at != written:
            raise io.UnsupportedOperation(
                f"{entry.path}: FanStore outputs are append-only "
                f"(offset {at} != size {written})")
        n = self.cluster.write_append(self.node_id, entry.path, data)
        entry.pos = written + n
        return n

    def write(self, fd: int, data: bytes) -> int:
        return self.pwrite(fd, data)

    def fsync(self, fd: int) -> int:
        """Flush a write fd's buffered bytes to the placement owner (the
        streaming half of the write path; metadata still publishes on
        close). No-op on read fds. Returns bytes shipped."""
        entry = self._entry(fd)
        if not entry.writing:
            return 0
        return self.cluster.flush_write(self.node_id, entry.path,
                                        lane=entry.lane)

    # ---- cursor / stat -----------------------------------------------------
    def lseek(self, fd: int, offset: int, whence: int = os.SEEK_SET) -> int:
        entry = self._entry(fd)
        if whence not in (os.SEEK_SET, os.SEEK_CUR, os.SEEK_END):
            raise ValueError(f"invalid whence {whence!r}")
        if entry.writing and whence == os.SEEK_END:
            raise io.UnsupportedOperation(
                "SEEK_END on an open write (size is undefined until close)")
        base = {os.SEEK_SET: 0, os.SEEK_CUR: entry.pos,
                os.SEEK_END: len(entry.data or b"")}[whence]
        entry.pos = max(0, base + offset)
        return entry.pos

    def fstat(self, fd: int) -> StatRecord:
        entry = self._entry(fd)
        if entry.writing:
            size = self.cluster.nodes[self.node_id].write_size(entry.path)
            return StatRecord.for_data(size)
        return StatRecord.for_data(len(entry.data or b""))

    # ---- namespace ops -----------------------------------------------------
    def stat(self, path: str) -> StatRecord:
        return self.cluster.stat(self.resolve(path))

    def exists(self, path: str) -> bool:
        try:
            self.stat(path)
            return True
        except FileNotFoundError:
            return False

    def getsize(self, path: str) -> int:
        return self.stat(path).st_size

    def listdir(self, path: str = "") -> List[str]:
        return self.cluster.readdir(self.resolve(path) if path else "")

    def unlink(self, path: str) -> None:
        """Delete a committed output file (output GC): the owner-side
        payload and the replicated metadata record drop together, and the
        name becomes writable again. Inputs are immutable
        (``PermissionError``); missing paths raise ``FileNotFoundError``.
        ``os.unlink``/``os.remove`` detour here under ``intercept()``."""
        self.cluster.unlink(self.node_id, self.resolve(path))

    remove = unlink

    def scandir(self, path: str = "") -> _ScandirIterator:
        """``os.scandir`` equivalent: entries carry name, joined path, and
        a ready stat (the paper's preprocessed metadata hash table — no
        per-entry round trips)."""
        raw = os.fspath(path) if path else self.mount
        rel = self.resolve(raw) if path else ""
        entries = []
        for name in self.cluster.readdir(rel):
            child = f"{rel}/{name}" if rel else name
            entries.append(FanStoreDirEntry(
                name, f"{raw.rstrip('/')}/{name}", self.cluster.stat(child)))
        return _ScandirIterator(entries)

    opendir = scandir

    def walk_count(self, path: str = "") -> int:
        """The start-of-training metadata traversal (paper §3.3): count
        files — committed outputs included, across both namespaces."""
        rel = self.resolve(path) if path else ""
        todo = [rel]
        n = 0
        while todo:
            d = todo.pop()
            for name in self.cluster.readdir(d):
                child = f"{d}/{name}" if d else name
                if self.cluster.is_dir(child):
                    todo.append(child)
                else:
                    n += 1
        return n

    # ---- batched verbs (the engine's fast path) ----------------------------
    def read_many(self, paths: Sequence[str], *,
                  materialize: bool = True) -> List[bytes]:
        """Batched whole-file reads: one modeled round trip per (this node,
        owner) pair instead of one per file. A serving session
        (``read_lane="serve_app"``) books the cost onto the concurrent
        serving timeline, attributed to its tenant."""
        return self.cluster.read_many(
            self.node_id, [self.resolve(p) for p in paths],
            worker_id=self.worker_id, materialize=materialize,
            lane=self.read_lane, tenant=self.tenant, job=self.job)

    def read_many_async(self, paths: Sequence[str], *,
                        materialize: bool = True) -> "Future[List[bytes]]":
        return self.cluster.read_many_async(
            self.node_id, [self.resolve(p) for p in paths],
            worker_id=self.worker_id, materialize=materialize,
            lane=self.read_lane, tenant=self.tenant, job=self.job)

    def write_many(self, entries: Sequence[Tuple[str, bytes]], *,
                   batched: bool = True) -> List[StatRecord]:
        """Batched writes: all payloads for one placement owner ride one
        round trip on the concurrent write lane."""
        return self.cluster.write_many(
            self.node_id, [(self.resolve(p), d) for p, d in entries],
            batched=batched, lane=self.lane)

    def write_many_async(self, entries: Sequence[Tuple[str, bytes]], *,
                         batched: bool = True) -> "Future[List[StatRecord]]":
        return self.cluster.write_many_async(
            self.node_id, [(self.resolve(p), d) for p, d in entries],
            batched=batched, lane=self.lane)

    def prefetch_window(self, paths: Sequence[str], *,
                        materialize: bool = True) -> int:
        return self.cluster.prefetch_window(
            self.node_id, [self.resolve(p) for p in paths],
            worker_id=self.worker_id, materialize=materialize)

    def checkpoint_writer(self, **kw) -> "CheckpointWriter":
        return CheckpointWriter(self, **kw)

    def transport_stats(self) -> Dict[str, object]:
        """This node's measured wire ledger: per-stripe wall time / bytes
        plus the on-the-wire codec's raw-vs-sent byte counts (all zero on
        purely modeled backends — the modeled view lives on the clocks)."""
        w = self.cluster.accounting.wall[self.node_id]
        return {
            "backend": self.cluster.backend,
            "stripes": dict(w.stripe_bytes),
            "stripe_ns": dict(w.stripe_ns),
            "wire_raw_bytes": w.wire_raw_bytes,
            "wire_sent_bytes": w.wire_sent_bytes,
            "wire_saved_bytes": w.wire_raw_bytes - w.wire_sent_bytes,
        }

    def metrics(self) -> Dict[str, object]:
        """This session's PER_RANK observability view (the metric
        counterpart of :meth:`transport_stats`): app-level series this
        (node, worker) rank recorded through ``cluster.metrics``, plus
        its node's modeled lanes and its own worker-attributed cache
        counters, all from one consistent accounting snapshot."""
        return self.cluster.metrics.rank_view(self.node_id, self.worker_id)

    def record_metric(self, name: str, value: float, **kw) -> None:
        """Record one observation on the cluster collector, attributed
        to this session's (node, worker) rank. Keyword arguments pass
        through to :meth:`repro.fanstore.metrics.MetricsCollector.
        record_metric` (``reduce=``, ``rate=``)."""
        self.cluster.metrics.record_metric(
            name, value, rank=(self.node_id, self.worker_id), **kw)

    def fault_stats(self) -> Dict[str, object]:
        """The cluster's fault ledger: injector counters (injected/
        dropped/errored/delayed, whether the kill trigger fired), the
        accounting retry total, and the current failed-node set. All
        counters are zero with no ``faults`` policy in the spec."""
        return self.cluster.fault_stats()

    # ---- lifecycle ---------------------------------------------------------
    def close_all(self) -> None:
        """Abort open writes (uncommitted data is discarded — visible-until-
        finish means nothing published, including owner-staged fsync'd
        chunks) and drop all descriptors. The cluster (and its transport
        backend) stays up: sessions are per-process views, many share one
        cluster — tear the wire itself down with ``cluster.close()``."""
        for fd in list(self._fds):
            self.abort(fd)

    def close_session(self) -> None:
        """Session teardown: drop every descriptor (open writes abort)."""
        self.close_all()

    def __enter__(self) -> "FanStoreSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close_all()


class CheckpointWriter:
    """Stream checkpoint shards through a session in fsync'd chunks.

    Each shard is one output file: ``write_shard`` opens it, writes
    ``chunk_bytes``-sized chunks, and fsyncs after each so the chunk's
    shipment to the placement owner rides the concurrent ``write_s`` lane
    while the next chunk is produced — and while any active prefetch
    window keeps fetching. Epoch makespan is then
    ``max(consume, serve, prefetch, write)`` per node rather than the
    serialized write-then-prefetch sum (pinned by tests/benchmarks).
    """

    def __init__(self, session: FanStoreSession, *,
                 chunk_bytes: int = 1 << 20):
        if chunk_bytes < 1:
            raise ValueError("chunk_bytes must be >= 1")
        self.session = session
        self.chunk_bytes = chunk_bytes
        self.shards_written = 0
        self.bytes_written = 0
        self.chunks_flushed = 0

    def write_shard(self, path: str, payload: bytes) -> StatRecord:
        """Stream one shard; visible (and immutable) once this returns."""
        fd = self.session.open(path, "wb")
        try:
            view = memoryview(payload)
            for off in range(0, max(len(view), 1), self.chunk_bytes):
                self.session.write(fd, bytes(view[off:off + self.chunk_bytes]))
                self.session.fsync(fd)
                self.chunks_flushed += 1
        except BaseException:
            self.session.abort(fd)       # drops buffered + staged chunks
            raise
        st = self.session.close(fd)
        self.shards_written += 1
        self.bytes_written += len(payload)
        return st

    def write_json(self, path: str, obj) -> StatRecord:
        """Serialize + stream a manifest; write it LAST — its visibility is
        the checkpoint's commit marker (mirrors the on-disk atomic rename)."""
        return self.write_shard(
            path, json.dumps(obj, sort_keys=True).encode())
