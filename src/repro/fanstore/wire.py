"""Wire protocol: the framed messages a real FanStore fabric speaks.

The modeled transport never needed a byte format — payloads moved as
Python references. A *real* backend (:mod:`repro.fanstore.backends.socket`)
needs one, and this module is its single source of truth: every message is
one length-prefixed frame, and every request/response body has an explicit
``encode_*``/``decode_*`` pair so the server loop and the client stub can
never drift apart.

Frame layout (all integers big-endian)::

    +------+----------+------------------+
    | type | body len |       body       |
    | u8   | u32      | <len> bytes      |
    +------+----------+------------------+

Request bodies:

  FETCH / FETCH_BATCH / FETCH_WINDOW
      u8 materialize | u32 count | count x (u16 path len + utf-8 path)
      The three verbs share one body shape; the distinct type codes keep
      the transport's intent (demand / batched / scheduled window) visible
      on the wire, mirroring the modeled backend's accounting lanes.
  PUT_BATCH
      u32 writer | u32 count | count x (u16 path len + path
                                        + u8 flags + u64 data len + data)
      One frame carries a whole (writer, owner) fan-in group — the wire
      twin of the modeled ``round_trips=1`` coalescing.
  STAT
      u16 path len + path

Response bodies:

  DATA      u64 serve_ns | u32 count | count x (u8 flags + u64 len + payload)
            ``serve_ns`` is the server-side handling time, so the client
            can account the owner's measured serve lane without a second
            message.
  OK        u64 serve_ns                      (PUT_BATCH acknowledgement)
  STAT_OK   u64 serve_ns | 144-byte packed ``StatRecord``
  ERR       u16 exc-name len + name | u16 msg len + msg
            The server maps any handler exception into an error frame; the
            client re-raises the same exception class (``decode_error``),
            so remote failures surface exactly like local ones.

Per-payload ``flags`` carry the on-the-wire codec bit (``FLAG_LZSS``): a
sender MAY compress any individual payload with the in-tree LZSS codec when
its :class:`WireCodecPolicy` cost model predicts the CPU spent compressing
plus decompressing is cheaper than the wire time the smaller body saves;
incompressible payloads (the attempt didn't shrink them) always ship raw
with the flag clear. Decoders are symmetric: ``decode_data``/``decode_put``
hand back the original bytes whatever the sender chose, so the codec is
invisible above the wire.

Striping and pipelining need no extra framing state: a striped batch is
split into contiguous per-stripe sub-batches (:func:`split_stripes`), each
riding its OWN connection as an ordinary ``FETCH_*`` frame, and pipelined
frames on one connection rely on TCP's FIFO ordering plus the server's
strict one-response-per-request discipline — responses can never
interleave, so :func:`reassemble` only has to slot each stripe's payload
run back into its original index range, whatever order the stripes finish
in.

``FetchItem`` also lives here: it is the resolved request descriptor every
backend verb takes (path + the sizes the modeled cost accounting needs),
shared by the wire encoders and the in-process backends alike.
"""
from __future__ import annotations

import socket
import struct
from dataclasses import dataclass
from enum import IntEnum
from typing import List, Optional, Sequence, Tuple

from repro.fanstore import lzss
from repro.fanstore.metadata import StatRecord

__all__ = ["MsgType", "FetchItem", "WireError", "MAX_FRAME_BYTES",
           "WIRE_CODECS", "FLAG_LZSS", "WireCodecPolicy",
           "write_frame", "write_frame_parts", "read_frame", "recv_exact",
           "sendmsg_all", "frame", "split_stripes", "reassemble",
           "encode_fetch", "decode_fetch", "encode_data", "decode_data",
           "decode_data_ex", "encode_data_parts",
           "encode_put", "decode_put", "encode_put_parts",
           "encode_ok", "decode_ok",
           "encode_stat", "decode_stat", "encode_stat_ok", "decode_stat_ok",
           "encode_error", "decode_error"]


class MsgType(IntEnum):
    """Frame type codes. Requests < 16 <= responses."""
    FETCH = 1          # one file, one round trip (the paper's sync client)
    FETCH_BATCH = 2    # coalesced (requester, owner) group
    FETCH_WINDOW = 3   # scheduled lookahead window (prefetch lane)
    PUT_BATCH = 4      # output chunks fanned in to the placement owner
    STAT = 5
    DATA = 17
    OK = 18
    STAT_OK = 19
    ERR = 20


@dataclass(frozen=True)
class FetchItem:
    """One resolved read request: path + the sizes the cost model needs."""
    path: str
    size: int             # decompressed (st_size) bytes
    stored: int           # bytes on the wire (compressed size if packed)
    compressed: bool = False


class WireError(IOError):
    """Protocol-level failure (bad magic, truncated frame, oversized body)."""


_HEADER = struct.Struct("!BI")
_U8 = struct.Struct("!B")
_U16 = struct.Struct("!H")
_U32 = struct.Struct("!I")
_U64 = struct.Struct("!Q")

# one frame carries at most one coalesced window of payloads; 1 GiB bounds
# a corrupted length prefix before it turns into an allocation bomb
MAX_FRAME_BYTES = 1 << 30

#: on-the-wire payload codecs a sender may negotiate (``ClusterSpec.wire_codec``)
WIRE_CODECS = ("none", "lzss")

#: per-payload flag bit: body is an LZSS stream, decompress on receipt
FLAG_LZSS = 0x01

# sendmsg gathers at most IOV_MAX buffers per call; stay far under it
_IOV_CHUNK = 512


@dataclass(frozen=True)
class WireCodecPolicy:
    """Per-payload compress-or-not decision for the wire codec.

    The sender compresses a payload only when the modeled CPU time of the
    round trip through the codec (encode on the sender + decode on the
    receiver) is smaller than the modeled wire time the smaller body is
    expected to save::

        n / compress_Bps + n*expected_ratio / decompress_Bps
            <  n * (1 - expected_ratio) / wire_Bps

    ``expected_ratio`` is the predicted compressed/raw size (LZSS on
    fp32 tensors and text lands around 0.5–0.7); the prediction only
    gates the ATTEMPT — if the actual stream fails to shrink, the payload
    ships raw with the flag clear (the incompressible escape hatch), so a
    wrong ratio guess costs CPU, never correctness or wire bytes. With the
    defaults (a pure-Python LZSS against a 100 Gb/s-class loopback) the
    model correctly predicts compression never wins; deployments behind a
    slow fabric (or with a native codec) override the rates via
    ``backend_options={"wire_policy": {...}}``.
    """
    codec: str = "none"
    wire_Bps: float = 100e9 / 8       # fabric the savings are valued at
    compress_Bps: float = 40e6        # in-tree LZSS encode rate (per core)
    decompress_Bps: float = 150e6     # in-tree LZSS decode rate
    expected_ratio: float = 0.6       # predicted compressed/raw size
    min_bytes: int = 1 << 12          # below this, framing noise dominates

    def __post_init__(self) -> None:
        if self.codec not in WIRE_CODECS:
            raise ValueError(f"unknown wire codec {self.codec!r}; "
                             f"choose from {sorted(WIRE_CODECS)}")

    def should_compress(self, nbytes: int) -> bool:
        """The cost model: modeled codec CPU < modeled wire time saved."""
        if self.codec == "none" or nbytes < self.min_bytes:
            return False
        cpu_s = (nbytes / self.compress_Bps
                 + nbytes * self.expected_ratio / self.decompress_Bps)
        saved_s = nbytes * (1.0 - self.expected_ratio) / self.wire_Bps
        return cpu_s < saved_s

    def encode(self, payload) -> Tuple[bytes, int]:
        """(wire bytes, flags) for one payload: compressed iff the cost
        model says try AND the stream actually shrank."""
        if not self.should_compress(len(payload)):
            return payload, 0
        packed = lzss.compress(bytes(payload))
        if len(packed) >= len(payload):   # incompressible: ship raw
            return payload, 0
        return packed, FLAG_LZSS


def _codec_decode(raw: bytes, flags: int) -> bytes:
    if flags & FLAG_LZSS:
        return lzss.decompress(raw)
    return raw

# exceptions a server may legitimately raise while serving; anything else
# degrades to IOError on the client (same contract as a real RPC layer)
_EXC_TYPES = {
    "FileNotFoundError": FileNotFoundError,
    "PermissionError": PermissionError,
    "IsADirectoryError": IsADirectoryError,
    "KeyError": KeyError,
    "ValueError": ValueError,
    "IOError": IOError,
    "OSError": OSError,
}


# ---- framing ---------------------------------------------------------------
def recv_exact(sock: socket.socket, n: int,
               buf: Optional[bytearray] = None) -> memoryview:
    """Read exactly ``n`` bytes (or raise ``ConnectionError`` on EOF),
    returned as a memoryview over the single receive buffer — a frame
    body is a whole coalesced window's payloads, so the decoders slice
    payloads straight out of this buffer with exactly one copy each
    instead of copying the full frame first.

    ``buf`` is an optional REUSABLE receive buffer (grown geometrically,
    never shrunk): a long-lived connection then allocates nothing per
    frame. The returned view aliases it — decode before the next read."""
    if buf is None:
        buf = bytearray(n)
    elif len(buf) < n:
        try:
            buf.extend(bytes(max(n - len(buf), len(buf))))
        except BufferError:
            # the previous frame's view is still alive somewhere (a caller
            # loop keeps its last `body` bound across reads): a bytearray
            # cannot resize while exported, so serve THIS read from a
            # fresh buffer; the shared one grows on a later, unexported
            # call. Costs one allocation, never correctness.
            buf = bytearray(n)
    view = memoryview(buf)[:n]
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise ConnectionError("peer closed mid-frame")
        got += k
    return view


def sendmsg_all(sock: socket.socket, parts: Sequence) -> None:
    """Vectored ``sendall``: gather ``parts`` (bytes / memoryviews) onto the
    wire without concatenating them — a whole DATA frame (header + every
    per-payload prefix + the payload views themselves) goes out in a few
    syscalls and no payload is ever copied into a joined body. Falls back
    to plain ``sendall`` where ``sendmsg`` is unavailable."""
    views = [memoryview(p).cast("B") for p in parts if len(p)]
    if not views:
        return
    if not hasattr(sock, "sendmsg"):     # pragma: no cover - POSIX always has it
        for v in views:
            sock.sendall(v)
        return
    i = 0
    while i < len(views):
        sent = sock.sendmsg(views[i:i + _IOV_CHUNK])
        while sent:                      # advance past fully-sent buffers
            n = len(views[i])
            if sent >= n:
                sent -= n
                i += 1
            else:
                views[i] = views[i][sent:]
                sent = 0


def frame(msg_type: MsgType, body: bytes) -> bytes:
    """One small frame as contiguous bytes (header + body) — for request
    frames, which are tiny; response payloads use :func:`write_frame_parts`
    so they are never joined."""
    if len(body) > MAX_FRAME_BYTES:
        raise WireError(f"frame body {len(body)} exceeds {MAX_FRAME_BYTES}")
    return _HEADER.pack(int(msg_type), len(body)) + body


def write_frame(sock: socket.socket, msg_type: MsgType, body: bytes) -> None:
    if len(body) > MAX_FRAME_BYTES:
        raise WireError(f"frame body {len(body)} exceeds {MAX_FRAME_BYTES}")
    # two sendalls, not header+body concatenation: the body is a whole
    # coalesced window's payloads and must not be copied a second time
    sock.sendall(_HEADER.pack(int(msg_type), len(body)))
    if body:
        sock.sendall(body)


def write_frame_parts(sock: socket.socket, msg_type: MsgType,
                      parts: Sequence) -> None:
    """Send one frame whose body is scattered across ``parts`` — the
    vectored twin of :func:`write_frame` (same frame on the wire, zero
    body concatenation)."""
    total = sum(len(p) for p in parts)
    if total > MAX_FRAME_BYTES:
        raise WireError(f"frame body {total} exceeds {MAX_FRAME_BYTES}")
    sendmsg_all(sock, [_HEADER.pack(int(msg_type), total), *parts])


def read_frame(sock: socket.socket,
               buf: Optional[bytearray] = None) -> Tuple[MsgType, memoryview]:
    mtype, length = _HEADER.unpack(recv_exact(sock, _HEADER.size))
    if length > MAX_FRAME_BYTES:
        raise WireError(f"frame body {length} exceeds {MAX_FRAME_BYTES}")
    try:
        mtype = MsgType(mtype)
    except ValueError:
        raise WireError(f"unknown frame type {mtype}")
    return mtype, recv_exact(sock, length, buf) if length else memoryview(b"")


# ---- striping helpers ------------------------------------------------------
def split_stripes(items: Sequence, stripes: int,
                  ) -> List[Tuple[int, int]]:
    """Partition ``items`` into at most ``stripes`` CONTIGUOUS index ranges
    balanced by stored bytes (greedy equal-share cuts). Contiguity is what
    makes reassembly trivial and order-preserving: stripe ``i`` owns
    ``items[start:end]`` and its payloads slot straight back into that
    range whatever order the stripes complete in."""
    n = len(items)
    k = max(1, min(int(stripes), n))
    if k <= 1:
        return [(0, n)]
    weights = [max(1, getattr(it, "stored", 1) or 1) for it in items]
    remaining = sum(weights)
    bounds: List[Tuple[int, int]] = []
    start = 0
    for s in range(k):
        if s == k - 1:
            bounds.append((start, n))
            break
        share = remaining / (k - s)
        end = start
        acc = 0
        max_end = n - (k - s - 1)       # leave >= 1 item per later stripe
        while end < max_end and (end == start or acc < share):
            acc += weights[end]
            end += 1
        bounds.append((start, end))
        remaining -= acc
        start = end
    return bounds


def reassemble(count: int,
               chunks: Sequence[Tuple[Tuple[int, int], Sequence[bytes]]]
               ) -> List[bytes]:
    """Slot per-stripe payload runs back into original item order. Accepts
    the chunks in ANY completion order; raises :class:`WireError` on a
    short/overlong stripe or a missing range (a torn stripe must never
    silently yield misaligned payloads)."""
    out: List[Optional[bytes]] = [None] * count
    for (start, end), payloads in chunks:
        if end - start != len(payloads):
            raise WireError(
                f"stripe [{start}:{end}) returned {len(payloads)} payloads")
        out[start:end] = payloads
    missing = sum(1 for p in out if p is None)
    if missing:
        raise WireError(f"stripe reassembly left {missing} slots unfilled")
    return out  # type: ignore[return-value]


# ---- body encoders ---------------------------------------------------------
def _put_str(out: List[bytes], s: str) -> None:
    b = s.encode()
    out.append(_U16.pack(len(b)))
    out.append(b)


def _get_str(body, off: int) -> Tuple[str, int]:
    # body may be bytes or the frame memoryview; bytes() the short slice
    (n,) = _U16.unpack_from(body, off)
    off += _U16.size
    return bytes(body[off:off + n]).decode(), off + n


def encode_fetch(paths: Sequence[str], *, materialize: bool = True) -> bytes:
    parts: List[bytes] = [_U8.pack(1 if materialize else 0),
                          _U32.pack(len(paths))]
    for p in paths:
        _put_str(parts, p)
    return b"".join(parts)


def decode_fetch(body) -> Tuple[List[str], bool]:
    materialize = bool(body[0])
    (count,) = _U32.unpack_from(body, 1)
    off = 1 + _U32.size
    paths = []
    for _ in range(count):
        p, off = _get_str(body, off)
        paths.append(p)
    return paths, materialize


_BQ = struct.Struct("!BQ")            # per-payload (flags, wire length)


def encode_data_parts(payloads: Sequence[bytes], *, serve_ns: int = 0,
                      policy: Optional[WireCodecPolicy] = None
                      ) -> List[bytes]:
    """The DATA body as a scatter list for :func:`write_frame_parts`:
    per-payload prefixes interleave with the payload buffers themselves
    (zero-copy memoryviews straight off the store), so building the
    response never joins the payloads. ``policy`` applies the per-payload
    wire codec (see :class:`WireCodecPolicy`)."""
    parts: List[bytes] = [_U64.pack(serve_ns) + _U32.pack(len(payloads))]
    for p in payloads:
        flags = 0
        if policy is not None:
            p, flags = policy.encode(p)
        parts.append(_BQ.pack(flags, len(p)))
        parts.append(p)
    return parts


def encode_data(payloads: Sequence[bytes], *, serve_ns: int = 0,
                policy: Optional[WireCodecPolicy] = None) -> bytes:
    return b"".join(bytes(p) for p in encode_data_parts(
        payloads, serve_ns=serve_ns, policy=policy))


def decode_data_ex(body) -> Tuple[List[bytes], int, int, int]:
    """Decode a DATA body; also returns (raw_bytes, wire_bytes) — the
    payload sizes after and before codec decode — so the receiver can
    ledger what the wire codec actually saved."""
    (serve_ns,) = _U64.unpack_from(body, 0)
    (count,) = _U32.unpack_from(body, _U64.size)
    off = _U64.size + _U32.size
    out = []
    raw_bytes = wire_bytes = 0
    for _ in range(count):
        flags, n = _BQ.unpack_from(body, off)
        off += _BQ.size
        # the payload's ONLY copy out of the receive buffer: it must own
        # its memory (it outlives the frame — caches, output staging);
        # flagged payloads decompress out of the buffer instead of copying
        data = _codec_decode(bytes(body[off:off + n]), flags)
        out.append(data)
        wire_bytes += n
        raw_bytes += len(data)
        off += n
    return out, serve_ns, raw_bytes, wire_bytes


def decode_data(body) -> Tuple[List[bytes], int]:
    out, serve_ns, _, _ = decode_data_ex(body)
    return out, serve_ns


def encode_put_parts(writer: int, entries: Sequence[Tuple[str, bytes]], *,
                     policy: Optional[WireCodecPolicy] = None) -> List[bytes]:
    """The PUT_BATCH body as a scatter list (the write-side twin of
    :func:`encode_data_parts`: the writer compresses, the owner's serving
    loop decompresses)."""
    head: List[bytes] = [_U32.pack(writer), _U32.pack(len(entries))]
    parts: List[bytes] = [b"".join(head)]
    for path, data in entries:
        prefix: List[bytes] = []
        _put_str(prefix, path)
        flags = 0
        if policy is not None:
            data, flags = policy.encode(data)
        prefix.append(_BQ.pack(flags, len(data)))
        parts.append(b"".join(prefix))
        parts.append(data)
    return parts


def encode_put(writer: int, entries: Sequence[Tuple[str, bytes]], *,
               policy: Optional[WireCodecPolicy] = None) -> bytes:
    return b"".join(bytes(p) for p in encode_put_parts(
        writer, entries, policy=policy))


def decode_put(body) -> Tuple[int, List[Tuple[str, bytes]]]:
    (writer,) = _U32.unpack_from(body, 0)
    (count,) = _U32.unpack_from(body, _U32.size)
    off = 2 * _U32.size
    entries = []
    for _ in range(count):
        path, off = _get_str(body, off)
        flags, n = _BQ.unpack_from(body, off)
        off += _BQ.size
        entries.append((path, _codec_decode(bytes(body[off:off + n]), flags)))
        off += n
    return writer, entries


def encode_ok(*, serve_ns: int = 0) -> bytes:
    return _U64.pack(serve_ns)


def decode_ok(body) -> int:
    (serve_ns,) = _U64.unpack(body)
    return serve_ns


def encode_stat(path: str) -> bytes:
    parts: List[bytes] = []
    _put_str(parts, path)
    return b"".join(parts)


def decode_stat(body) -> str:
    path, _ = _get_str(body, 0)
    return path


def encode_stat_ok(st: StatRecord, *, serve_ns: int = 0) -> bytes:
    return _U64.pack(serve_ns) + st.pack()


def decode_stat_ok(body) -> Tuple[StatRecord, int]:
    (serve_ns,) = _U64.unpack_from(body, 0)
    return StatRecord.unpack(bytes(body[_U64.size:])), serve_ns


def encode_error(exc: BaseException) -> bytes:
    parts: List[bytes] = []
    _put_str(parts, type(exc).__name__)
    _put_str(parts, str(exc))
    return b"".join(parts)


def decode_error(body) -> BaseException:
    name, off = _get_str(body, 0)
    msg, _ = _get_str(body, off)
    return _EXC_TYPES.get(name, IOError)(msg)
