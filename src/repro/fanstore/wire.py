"""Wire protocol: the framed messages a real FanStore fabric speaks.

The modeled transport never needed a byte format — payloads moved as
Python references. A *real* backend (:mod:`repro.fanstore.backends.socket`)
needs one, and this module is its single source of truth: every message is
one length-prefixed frame, and every request/response body has an explicit
``encode_*``/``decode_*`` pair so the server loop and the client stub can
never drift apart.

Frame layout (all integers big-endian)::

    +------+----------+------------------+
    | type | body len |       body       |
    | u8   | u32      | <len> bytes      |
    +------+----------+------------------+

Request bodies:

  FETCH / FETCH_BATCH / FETCH_WINDOW
      u8 materialize | u32 count | count x (u16 path len + utf-8 path)
      The three verbs share one body shape; the distinct type codes keep
      the transport's intent (demand / batched / scheduled window) visible
      on the wire, mirroring the modeled backend's accounting lanes.
  PUT_BATCH
      u32 writer | u32 count | count x (u16 path len + path
                                        + u64 data len + data)
      One frame carries a whole (writer, owner) fan-in group — the wire
      twin of the modeled ``round_trips=1`` coalescing.
  STAT
      u16 path len + path

Response bodies:

  DATA      u64 serve_ns | u32 count | count x (u64 len + payload)
            ``serve_ns`` is the server-side handling time, so the client
            can account the owner's measured serve lane without a second
            message.
  OK        u64 serve_ns                      (PUT_BATCH acknowledgement)
  STAT_OK   u64 serve_ns | 144-byte packed ``StatRecord``
  ERR       u16 exc-name len + name | u16 msg len + msg
            The server maps any handler exception into an error frame; the
            client re-raises the same exception class (``decode_error``),
            so remote failures surface exactly like local ones.

``FetchItem`` also lives here: it is the resolved request descriptor every
backend verb takes (path + the sizes the modeled cost accounting needs),
shared by the wire encoders and the in-process backends alike.
"""
from __future__ import annotations

import socket
import struct
from dataclasses import dataclass
from enum import IntEnum
from typing import List, Sequence, Tuple

from repro.fanstore.metadata import StatRecord

__all__ = ["MsgType", "FetchItem", "WireError", "MAX_FRAME_BYTES",
           "write_frame", "read_frame", "recv_exact",
           "encode_fetch", "decode_fetch", "encode_data", "decode_data",
           "encode_put", "decode_put", "encode_ok", "decode_ok",
           "encode_stat", "decode_stat", "encode_stat_ok", "decode_stat_ok",
           "encode_error", "decode_error"]


class MsgType(IntEnum):
    """Frame type codes. Requests < 16 <= responses."""
    FETCH = 1          # one file, one round trip (the paper's sync client)
    FETCH_BATCH = 2    # coalesced (requester, owner) group
    FETCH_WINDOW = 3   # scheduled lookahead window (prefetch lane)
    PUT_BATCH = 4      # output chunks fanned in to the placement owner
    STAT = 5
    DATA = 17
    OK = 18
    STAT_OK = 19
    ERR = 20


@dataclass(frozen=True)
class FetchItem:
    """One resolved read request: path + the sizes the cost model needs."""
    path: str
    size: int             # decompressed (st_size) bytes
    stored: int           # bytes on the wire (compressed size if packed)
    compressed: bool = False


class WireError(IOError):
    """Protocol-level failure (bad magic, truncated frame, oversized body)."""


_HEADER = struct.Struct("!BI")
_U8 = struct.Struct("!B")
_U16 = struct.Struct("!H")
_U32 = struct.Struct("!I")
_U64 = struct.Struct("!Q")

# one frame carries at most one coalesced window of payloads; 1 GiB bounds
# a corrupted length prefix before it turns into an allocation bomb
MAX_FRAME_BYTES = 1 << 30

# exceptions a server may legitimately raise while serving; anything else
# degrades to IOError on the client (same contract as a real RPC layer)
_EXC_TYPES = {
    "FileNotFoundError": FileNotFoundError,
    "PermissionError": PermissionError,
    "IsADirectoryError": IsADirectoryError,
    "KeyError": KeyError,
    "ValueError": ValueError,
    "IOError": IOError,
    "OSError": OSError,
}


# ---- framing ---------------------------------------------------------------
def recv_exact(sock: socket.socket, n: int) -> memoryview:
    """Read exactly ``n`` bytes (or raise ``ConnectionError`` on EOF),
    returned as a memoryview over the single receive buffer — a frame
    body is a whole coalesced window's payloads, so the decoders slice
    payloads straight out of this buffer with exactly one copy each
    instead of copying the full frame first."""
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise ConnectionError("peer closed mid-frame")
        got += k
    return view


def write_frame(sock: socket.socket, msg_type: MsgType, body: bytes) -> None:
    if len(body) > MAX_FRAME_BYTES:
        raise WireError(f"frame body {len(body)} exceeds {MAX_FRAME_BYTES}")
    # two sendalls, not header+body concatenation: the body is a whole
    # coalesced window's payloads and must not be copied a second time
    sock.sendall(_HEADER.pack(int(msg_type), len(body)))
    if body:
        sock.sendall(body)


def read_frame(sock: socket.socket) -> Tuple[MsgType, memoryview]:
    mtype, length = _HEADER.unpack(recv_exact(sock, _HEADER.size))
    if length > MAX_FRAME_BYTES:
        raise WireError(f"frame body {length} exceeds {MAX_FRAME_BYTES}")
    try:
        mtype = MsgType(mtype)
    except ValueError:
        raise WireError(f"unknown frame type {mtype}")
    return mtype, recv_exact(sock, length) if length else memoryview(b"")


# ---- body encoders ---------------------------------------------------------
def _put_str(out: List[bytes], s: str) -> None:
    b = s.encode()
    out.append(_U16.pack(len(b)))
    out.append(b)


def _get_str(body, off: int) -> Tuple[str, int]:
    # body may be bytes or the frame memoryview; bytes() the short slice
    (n,) = _U16.unpack_from(body, off)
    off += _U16.size
    return bytes(body[off:off + n]).decode(), off + n


def encode_fetch(paths: Sequence[str], *, materialize: bool = True) -> bytes:
    parts: List[bytes] = [_U8.pack(1 if materialize else 0),
                          _U32.pack(len(paths))]
    for p in paths:
        _put_str(parts, p)
    return b"".join(parts)


def decode_fetch(body) -> Tuple[List[str], bool]:
    materialize = bool(body[0])
    (count,) = _U32.unpack_from(body, 1)
    off = 1 + _U32.size
    paths = []
    for _ in range(count):
        p, off = _get_str(body, off)
        paths.append(p)
    return paths, materialize


def encode_data(payloads: Sequence[bytes], *, serve_ns: int = 0) -> bytes:
    parts: List[bytes] = [_U64.pack(serve_ns), _U32.pack(len(payloads))]
    for p in payloads:
        parts.append(_U64.pack(len(p)))
        parts.append(bytes(p))
    return b"".join(parts)


def decode_data(body) -> Tuple[List[bytes], int]:
    (serve_ns,) = _U64.unpack_from(body, 0)
    (count,) = _U32.unpack_from(body, _U64.size)
    off = _U64.size + _U32.size
    out = []
    for _ in range(count):
        (n,) = _U64.unpack_from(body, off)
        off += _U64.size
        # the payload's ONLY copy out of the receive buffer: it must own
        # its memory (it outlives the frame — caches, output staging)
        out.append(bytes(body[off:off + n]))
        off += n
    return out, serve_ns


def encode_put(writer: int, entries: Sequence[Tuple[str, bytes]]) -> bytes:
    parts: List[bytes] = [_U32.pack(writer), _U32.pack(len(entries))]
    for path, data in entries:
        _put_str(parts, path)
        parts.append(_U64.pack(len(data)))
        parts.append(bytes(data))
    return b"".join(parts)


def decode_put(body) -> Tuple[int, List[Tuple[str, bytes]]]:
    (writer,) = _U32.unpack_from(body, 0)
    (count,) = _U32.unpack_from(body, _U32.size)
    off = 2 * _U32.size
    entries = []
    for _ in range(count):
        path, off = _get_str(body, off)
        (n,) = _U64.unpack_from(body, off)
        off += _U64.size
        entries.append((path, bytes(body[off:off + n])))
        off += n
    return writer, entries


def encode_ok(*, serve_ns: int = 0) -> bytes:
    return _U64.pack(serve_ns)


def decode_ok(body) -> int:
    (serve_ns,) = _U64.unpack(body)
    return serve_ns


def encode_stat(path: str) -> bytes:
    parts: List[bytes] = []
    _put_str(parts, path)
    return b"".join(parts)


def decode_stat(body) -> str:
    path, _ = _get_str(body, 0)
    return path


def encode_stat_ok(st: StatRecord, *, serve_ns: int = 0) -> bytes:
    return _U64.pack(serve_ns) + st.pack()


def decode_stat_ok(body) -> Tuple[StatRecord, int]:
    (serve_ns,) = _U64.unpack_from(body, 0)
    return StatRecord.unpack(bytes(body[_U64.size:])), serve_ns


def encode_error(exc: BaseException) -> bytes:
    parts: List[bytes] = []
    _put_str(parts, type(exc).__name__)
    _put_str(parts, str(exc))
    return b"".join(parts)


def decode_error(body) -> BaseException:
    name, off = _get_str(body, 0)
    msg, _ = _get_str(body, off)
    return _EXC_TYPES.get(name, IOError)(msg)
