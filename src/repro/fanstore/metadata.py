"""Metadata management (paper §5.3).

* ``StatRecord`` — the 144-byte per-file stat stored inline in partitions,
  laid out like glibc's x86-64 ``struct stat``.
* ``MetadataTable`` — the RAM hashtable replicated on every node for *input*
  files (path -> record + location), with a per-directory children cache so
  ``readdir()`` returns immediately (paper: "preprocessed and cached in a hash
  table to allow readdir() to return immediately").
* Output-file placement: the paper maps a path to a node with
  ``hash(path) % node_count`` (it calls this a consistent hash). The faithful
  ``modulo_placement`` lives here; the true ``ConsistentHashRing`` with
  virtual nodes (cheap elastic membership, used by :mod:`repro.train.elastic`)
  now lives in :mod:`repro.fanstore.placement` — a lazy re-export below keeps
  old imports working.
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

S_IFREG = 0o100000
S_IFDIR = 0o040000

# glibc x86-64 struct stat: dev ino nlink | mode uid gid pad | rdev size
# blksize blocks | atim mtim ctim (sec,nsec each) | 3x u64 reserved == 144 B
_STAT_FMT = "<QQQ IIiI Q q qq qq qq qq QQQ"
assert struct.calcsize(_STAT_FMT) == 144


@dataclass(frozen=True)
class StatRecord:
    st_dev: int = 0
    st_ino: int = 0
    st_nlink: int = 1
    st_mode: int = S_IFREG | 0o644
    st_uid: int = 0
    st_gid: int = 0
    st_rdev: int = 0
    st_size: int = 0
    st_blksize: int = 4096
    st_blocks: int = 0
    st_atime: float = 0.0
    st_mtime: float = 0.0
    st_ctime: float = 0.0

    @staticmethod
    def for_data(size: int, *, mode: int = S_IFREG | 0o644) -> "StatRecord":
        now = 0.0  # deterministic by default; callers may stamp real time
        return StatRecord(st_size=size, st_mode=mode,
                          st_blocks=(size + 511) // 512,
                          st_atime=now, st_mtime=now, st_ctime=now)

    def replace(self, **kw) -> "StatRecord":
        return dataclasses.replace(self, **kw)

    @property
    def is_dir(self) -> bool:
        return bool(self.st_mode & S_IFDIR)

    def pack(self) -> bytes:
        def ts(t: float) -> Tuple[int, int]:
            sec = int(t)
            return sec, int((t - sec) * 1e9)
        a, m, c = ts(self.st_atime), ts(self.st_mtime), ts(self.st_ctime)
        return struct.pack(
            _STAT_FMT, self.st_dev, self.st_ino, self.st_nlink,
            self.st_mode, self.st_uid, self.st_gid, 0, self.st_rdev,
            self.st_size, self.st_blksize, self.st_blocks,
            a[0], a[1], m[0], m[1], c[0], c[1], 0, 0, 0)

    @staticmethod
    def unpack(raw: bytes) -> "StatRecord":
        (dev, ino, nlink, mode, uid, gid, _pad, rdev, size, blksize, blocks,
         asec, ans, msec, mns, csec, cns, _r0, _r1, _r2) = struct.unpack(_STAT_FMT, raw)
        return StatRecord(st_dev=dev, st_ino=ino, st_nlink=nlink, st_mode=mode,
                          st_uid=uid, st_gid=gid, st_rdev=rdev, st_size=size,
                          st_blksize=blksize, st_blocks=blocks,
                          st_atime=asec + ans / 1e9, st_mtime=msec + mns / 1e9,
                          st_ctime=csec + cns / 1e9)


@dataclass(frozen=True)
class FileLocation:
    """Where a file's bytes live: owning node + partition + record index."""
    node_id: int
    partition_id: int
    record_index: int
    replicas: Tuple[int, ...] = ()   # other nodes holding a copy

    @property
    def all_owners(self) -> Tuple[int, ...]:
        return (self.node_id,) + self.replicas


def path_hash(path: str) -> int:
    """Stable 64-bit path hash (the paper's placement hash)."""
    return int.from_bytes(hashlib.blake2b(path.encode(), digest_size=8).digest(), "little")


def modulo_placement(path: str, node_count: int) -> int:
    """The paper's output-metadata placement: hash(path) % node_count."""
    return path_hash(path) % node_count


def __getattr__(name: str):
    # ConsistentHashRing moved to repro.fanstore.placement; resolve lazily so
    # the two modules can import each other's stable halves without a cycle.
    if name == "ConsistentHashRing":
        from repro.fanstore.placement import ConsistentHashRing
        return ConsistentHashRing
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class MetadataTable:
    """Replicated input-file metadata: path -> (StatRecord, FileLocation).

    Also maintains the directory -> children index that backs ``readdir()``.
    All mutating ops are idempotent inserts; inputs are immutable during
    training (paper §3.5), so no locking is needed for readers.
    """

    def __init__(self) -> None:
        self._files: Dict[str, Tuple[StatRecord, FileLocation]] = {}
        self._dirs: Dict[str, List[str]] = {"": []}

    def __len__(self) -> int:
        return len(self._files)

    @staticmethod
    def _parents(path: str) -> List[str]:
        parts = path.strip("/").split("/")
        return ["/".join(parts[:i]) for i in range(len(parts))]

    def insert(self, path: str, st: StatRecord, loc: FileLocation) -> None:
        path = path.strip("/")
        self._files[path] = (st, loc)
        # materialize parent dirs + child links
        child = path
        for parent in reversed(self._parents(path)):
            kids = self._dirs.setdefault(parent, [])
            name = child[len(parent):].lstrip("/") if parent else child.split("/")[0]
            if name not in kids:
                kids.append(name)
            child = parent

    def lookup(self, path: str) -> Optional[Tuple[StatRecord, FileLocation]]:
        return self._files.get(path.strip("/"))

    def remove(self, path: str) -> bool:
        """Unlink a file record and prune directories it leaves empty
        (parent dirs materialize with their first file and dissolve with
        their last; the root always exists). Returns False when the path
        held no file."""
        path = path.strip("/")
        if self._files.pop(path, None) is None:
            return False
        child = path
        for parent in reversed(self._parents(path)):
            kids = self._dirs.get(parent)
            name = child[len(parent):].lstrip("/") if parent \
                else child.split("/")[0]
            if kids is not None and name in kids:
                kids.remove(name)
            if parent == "" or (kids is not None and kids):
                break                  # still-populated dir: stop pruning
            self._dirs.pop(parent, None)
            child = parent
        return True

    def stat(self, path: str) -> Optional[StatRecord]:
        path = path.strip("/")
        hit = self._files.get(path)
        if hit:
            return hit[0]
        if path in self._dirs:
            return StatRecord(st_mode=S_IFDIR | 0o755, st_nlink=2)
        return None

    def readdir(self, path: str) -> Optional[List[str]]:
        kids = self._dirs.get(path.strip("/"))
        return sorted(kids) if kids is not None else None

    def is_dir(self, path: str) -> bool:
        return path.strip("/") in self._dirs

    def paths(self) -> Iterable[str]:
        return self._files.keys()
