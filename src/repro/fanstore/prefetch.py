"""Clairvoyant epoch-horizon prefetch scheduling (beyond-paper).

FanStore's access pattern is long-lasting, repeated, and *known in
advance*: the per-epoch permutation is fully determined by the sampler
seed, so a node can compute exactly which remote samples it will need,
when, and from whom. Clairvoyant Prefetching (Dryden et al., 2021) shows
that exploiting this foreknowledge recovers near-local throughput at
scale. Two pieces:

* :class:`EpochSchedule` — the materialized future: for every requester,
  the ordered list of ``(step, path, owner)`` it will read this epoch,
  derived either by replaying any sampler's state (``from_sampler``) or
  from an explicit per-step trace (``from_trace``). The schedule also
  yields each requester's demand-access sequence (``future_paths``) — the
  exact-reuse-distance oracle :class:`repro.fanstore.cache.BeladyCache`
  evicts by. ``from_sampler(epochs=K)`` stitches K consecutive epochs
  into ONE globally-stepped horizon, so prefetch windows flow across the
  epoch boundary (the tail of epoch e covers the head of e+1 — no
  drain-and-refill stall) and the Belady oracle stays exact at the seam;
  ``install_futures(extend=True)`` appends a later schedule to a tier's
  already-installed future for the same effect incrementally.
* :class:`PrefetchScheduler` — drives one requester's schedule through the
  transport's window-level async path: the horizon is cut into lookahead
  windows of ``window_steps`` training steps, and each window issues ONE
  coalesced round trip per owner (``Transport.fetch_window``) covering
  every file that owner serves *across all batches in the window* —
  amortizing latency far beyond per-batch coalescing. In-flight data is
  capped by ``max_inflight_bytes`` (backpressure: issuing a new window
  blocks on the oldest outstanding one), and fetched payloads land in the
  requester's client cache so the demand-path ``read_many`` hits at RAM
  speed. Prefetch cost accrues on the ``NodeClock.prefetch_s`` lane, so
  epoch makespan models I/O hidden behind compute instead of serializing.

The write half mirrors this: checkpoint flushes issued through
:class:`repro.fanstore.api.CheckpointWriter` land on the concurrent
``NodeClock.write_s`` lane, so a shard shipped while a prefetch window is
in flight costs ``max(prefetch, write)`` in the epoch makespan — the two
scheduled lanes overlap each other as well as the demand timeline.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import (Callable, Deque, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

__all__ = ["ScheduledRead", "EpochSchedule", "PrefetchScheduler",
           "SchedulerGroup", "Requester"]

#: a schedule requester: a bare node id (single-worker, the pre-topology
#: convention) or a (node_id, worker_id) coordinate from a ClusterSpec
Requester = Union[int, Tuple[int, int]]


def _req_key(requester: Requester) -> Tuple[int, int]:
    """(node_id, worker_id) for either requester form."""
    if isinstance(requester, tuple):
        node, worker = requester
        return int(node), int(worker)
    return int(requester), 0


def _normalize(requester: Requester) -> Requester:
    """Canonical dict key: plain int for bare nodes (compat with every
    pre-topology schedule), (int, int) tuple for worker coordinates."""
    if isinstance(requester, tuple):
        node, worker = requester
        return (int(node), int(worker))
    return int(requester)


def _req_sort_key(requester: Requester) -> Tuple[int, int]:
    return _req_key(requester)


@dataclass(frozen=True)
class ScheduledRead:
    """One future read: global step, path, and the node expected to serve
    it (the requester itself for node-local files; -1 when no cluster was
    available to resolve ownership). Paths are stored normalized
    (no leading slash) so they match client-cache keys exactly — the
    Belady oracle depends on that."""
    step: int
    path: str
    owner: int = -1


class EpochSchedule:
    """Per-requester ordered future reads for one epoch (or trace).

    A requester is either a bare node id (the pre-topology single-worker
    convention) or a ``(node_id, worker_id)`` coordinate from a
    :class:`~repro.fanstore.spec.ClusterSpec` topology — co-located
    workers each get their own axis of the schedule, which is what lets
    the training driver run one loader per (node, worker).

    ``reads_by_requester[r]`` is sorted by step; within a step, order is
    the batch's index order (which is the demand-read order).
    """

    def __init__(self, reads_by_requester:
                 Mapping[Requester, Sequence[ScheduledRead]]):
        self._reads: Dict[Requester, List[ScheduledRead]] = {
            _normalize(r): sorted(reads, key=lambda s: s.step)
            for r, reads in reads_by_requester.items()}
        self.num_steps = max(
            (reads[-1].step + 1 for reads in self._reads.values() if reads),
            default=0)
        # multi-epoch metadata (set by from_sampler(epochs=K)): steps are
        # GLOBAL across the stitched horizon — epoch e's step s is global
        # step e * steps_per_epoch + s, matching PrefetchLoader's
        # monotonically increasing schedule step
        self.epochs = 1
        self.steps_per_epoch = self.num_steps

    # ---- construction ------------------------------------------------------
    @classmethod
    def from_sampler(cls, sampler, paths: Sequence[str], *,
                     num_requesters: int, workers_per_node: int = 1,
                     cluster=None, epoch: Optional[int] = None,
                     epochs: int = 1) -> "EpochSchedule":
        """Materialize the permutation of ``epochs`` consecutive epochs
        from any checkpointable sampler (``state``/``restore``/
        ``next_batch``) without advancing it.

        Each global batch is split into ``num_requesters`` contiguous
        per-requester slices — the convention the device tier and
        ``StratifiedSampler`` already use. With ``workers_per_node=W > 1``
        slice ``r`` belongs to worker coordinate ``(r // W, r % W)``
        (node-major, matching ``ClusterSpec.workers()``) and the
        schedule's requester keys are those tuples; with ``W == 1`` keys
        stay bare node ids, so every pre-topology caller is unchanged.
        ``paths[i]`` maps sample index i to its file; ``cluster``
        (optional) annotates each read with its expected serving node
        (informational — the scheduler re-resolves owners at issue time
        against the live failure set).

        With ``epochs=K > 1`` the schedule is the STITCHED K-epoch
        horizon starting at ``epoch`` (default: the sampler's current
        epoch): each epoch is peeked via ``peek_epoch(base + e)`` and its
        steps offset by ``e * steps_per_epoch``, so one schedule spans
        the epoch boundary. Prefetch windows then flow straight across
        it (no drain-and-refill stall at epoch end) and Belady's oracle
        sees the next epoch's reuses instead of next-use = infinity for
        every path as the first epoch drains.
        """
        if workers_per_node < 1:
            raise ValueError("workers_per_node must be >= 1")
        if num_requesters % workers_per_node:
            raise ValueError("workers_per_node must divide num_requesters "
                             "(one slice per (node, worker))")
        if epochs < 1:
            raise ValueError("epochs must be >= 1")
        # minimal duck-typed samplers carry peek_epoch but no .state; they
        # keep working as long as no stitched base epoch must be derived
        base = (epoch if epoch is not None
                else getattr(getattr(sampler, "state", None), "epoch", None))
        if base is None and epochs > 1:
            raise ValueError("epochs > 1 needs a sampler with .state.epoch "
                             "(or an explicit epoch=) to number the "
                             "stitched horizon")

        def key(r: int) -> Requester:
            if workers_per_node == 1:
                return r
            return (r // workers_per_node, r % workers_per_node)

        reads: Dict[Requester, List[ScheduledRead]] = {
            key(r): [] for r in range(num_requesters)}
        step_base = 0
        steps_per_epoch = 0
        for e in range(epochs):
            batches = sampler.peek_epoch(None if base is None else base + e)
            for step, batch in enumerate(batches):
                if len(batch) % num_requesters:
                    raise ValueError(
                        "num_requesters must divide the global batch size")
                per = len(batch) // num_requesters
                for r in range(num_requesters):
                    node = _req_key(key(r))[0]
                    for idx in batch[r * per:(r + 1) * per]:
                        path = paths[int(idx)].strip("/")
                        owner = _resolve_owner(cluster, node, path)
                        reads[key(r)].append(
                            ScheduledRead(step_base + step, path, owner))
            if e == 0:
                steps_per_epoch = len(batches)
            step_base += len(batches)
        sched = cls(reads)
        sched.epochs = epochs
        sched.steps_per_epoch = steps_per_epoch
        return sched

    @classmethod
    def from_trace(cls, traces: Mapping[Requester, Sequence[Sequence[str]]],
                   cluster=None) -> "EpochSchedule":
        """Build from explicit per-step path lists:
        ``traces[requester] = [[paths of step 0], [paths of step 1], ...]``
        with requesters either bare node ids or (node, worker) tuples.
        """
        reads: Dict[Requester, List[ScheduledRead]] = {}
        for r, steps in traces.items():
            node = _req_key(r)[0]
            out: List[ScheduledRead] = []
            for step, batch in enumerate(steps):
                for path in batch:
                    path = path.strip("/")
                    out.append(ScheduledRead(
                        step, path, _resolve_owner(cluster, node, path)))
            reads[_normalize(r)] = out
        return cls(reads)

    # ---- views -------------------------------------------------------------
    @property
    def requesters(self) -> List[Requester]:
        return sorted(self._reads, key=_req_sort_key)

    def for_requester(self, requester: Requester) -> List[ScheduledRead]:
        return list(self._reads.get(_normalize(requester), []))

    def future_paths(self, requester: Requester) -> List[str]:
        """The requester's demand-access sequence — Belady's oracle."""
        return [s.path for s in self._reads.get(_normalize(requester), [])]

    def node_future(self, node_id: int) -> List[str]:
        """The NODE-merged demand sequence: every co-located worker's
        reads interleaved in (step, worker, in-batch) order — the oracle a
        SHARED cache tier needs, since it serves all workers' accesses
        against one budget. For a single-worker node this equals
        ``future_paths(node_id)``."""
        merged: List[Tuple[int, int, int, str]] = []
        for r, reads in self._reads.items():
            node, worker = _req_key(r)
            if node != node_id:
                continue
            merged.extend((s.step, worker, i, s.path)
                          for i, s in enumerate(reads))
        merged.sort(key=lambda t: t[:3])
        return [path for _, _, _, path in merged]

    def install_futures(self, cluster,
                        requesters: Optional[Sequence[Requester]] = None,
                        *, extend: bool = False) -> int:
        """Hand future traces to the requesters' cache tiers (no-op for
        policies without a ``set_future`` hook). A shared tier
        (``cache_scope="node"``) receives the node-merged trace ONCE per
        node — co-located workers must not clobber each other's oracle
        with single-worker views; private per-worker caches receive their
        own worker's trace. Returns the number of caches fed.

        ``extend=True`` APPENDS this schedule's traces after whatever is
        already installed instead of replacing it — the cross-epoch
        stitch: feed epoch e+1's schedule to a tier mid-epoch-e and
        clairvoyant eviction stays exact across the seam."""
        fed = 0
        reqs = list(requesters if requesters is not None
                    else self.requesters)
        tiers = getattr(cluster, "cache_tiers", None)
        if tiers is None:              # pre-topology cluster duck-type
            verb = "extend_future" if extend else "set_future"
            for r in reqs:
                cache = cluster.caches.get(r)
                if cache is not None and hasattr(cache, verb):
                    getattr(cache, verb)(self.future_paths(r))
                    fed += 1
            return fed
        done_nodes = set()
        for r in reqs:
            node, worker = _req_key(r)
            tier = tiers.get(node)
            if tier is None:
                continue
            if tier.scope == "node":
                if node in done_nodes:
                    continue
                done_nodes.add(node)
                feed = (tier.extend_future if extend else tier.set_future)
                if feed(self.node_future(node)):
                    fed += 1
            else:
                feed = (tier.extend_worker_future if extend
                        else tier.set_worker_future)
                if feed(worker, self.future_paths(r)):
                    fed += 1
        return fed


def _resolve_owner(cluster, requester: int, path: str) -> int:
    if cluster is None:
        return -1
    path = path.strip("/")
    if cluster.nodes[requester].has(path):
        return requester
    hit = cluster.metadata.lookup(path)
    if hit is None:
        return -1                     # output file: not prefetchable
    _, loc = hit
    for owner in loc.all_owners:
        if owner not in cluster.failed:
            return owner
    return -1


class PrefetchScheduler:
    """Issue one requester's epoch schedule as lookahead windows of
    coalesced async fetches, with a byte-budget in-flight cap.

    Typical use (or let ``PrefetchLoader(schedule=...)`` drive it)::

        sched = EpochSchedule.from_sampler(sampler, paths,
                                           num_requesters=N, cluster=c)
        pf = PrefetchScheduler(c, sched, requester=r, window_steps=8)
        for step in range(steps):
            pf.ensure(step + lookahead)     # non-blocking unless over cap
            c.read_many(r, batch_paths)     # hits the client cache
        pf.close()

    Windows are ``window_steps`` consecutive training steps; window *i* is
    issued as ONE ``cluster.prefetch_window`` call, which groups the
    window's files per owner and pays one round trip per (requester,
    owner, window). ``max_inflight_bytes`` caps outstanding prefetched-but-
    unconsumed bytes: when exceeded, :meth:`ensure` blocks on the oldest
    outstanding window (backpressure) before issuing the next.

    Construction installs the schedule's future trace into the requester's
    cache when the policy supports it (Belady), so prefetch, demand reads,
    and eviction all share one view of the future.
    """

    def __init__(self, cluster, schedule: EpochSchedule,
                 requester: Requester, *,
                 window_steps: int = 8,
                 max_inflight_bytes: int = 256 * 1024 * 1024,
                 materialize: bool = True,
                 install_future: bool = True):
        if window_steps < 1:
            raise ValueError("window_steps must be >= 1")
        if max_inflight_bytes < 1:
            raise ValueError("max_inflight_bytes must be >= 1")
        self.cluster = cluster
        self.schedule = schedule
        self.requester = requester
        self.node_id, self.worker_id = _req_key(requester)
        self.window_steps = window_steps
        self.max_inflight_bytes = max_inflight_bytes
        self.materialize = materialize
        self._windows = self._cut_windows(schedule.for_requester(requester))
        self._next_window = 0
        # in-flight windows, oldest first: (future, est_bytes, start_step)
        self._inflight: Deque[Tuple["object", int, int]] = deque()
        self._inflight_bytes = 0
        self._lock = threading.Lock()
        self.windows_issued = 0
        self.bytes_scheduled = 0
        if install_future:
            schedule.install_futures(cluster, [requester])

    # ---- window construction -----------------------------------------------
    def _cut_windows(self, reads: Sequence[ScheduledRead]
                     ) -> List[Tuple[int, List[str], int]]:
        """[(start_step, unique paths, est_bytes)] per lookahead window."""
        if not reads:
            return []
        meta = self.cluster.metadata
        w = self.window_steps
        paths_by_window: Dict[int, List[str]] = {}
        est_by_window: Dict[int, int] = {}
        seen_by_window: Dict[int, set] = {}
        for s in reads:                       # one pass, grouped by window
            start = (s.step // w) * w
            seen = seen_by_window.setdefault(start, set())
            if s.path in seen:
                continue
            seen.add(s.path)
            paths_by_window.setdefault(start, []).append(s.path)
            st = meta.stat(s.path)            # schedule paths are normalized
            est_by_window[start] = est_by_window.get(start, 0) + (
                st.st_size if st is not None else 0)
        return [(start, paths_by_window[start], est_by_window[start])
                for start in sorted(paths_by_window)]

    @property
    def num_windows(self) -> int:
        return len(self._windows)

    # ---- issue/backpressure -------------------------------------------------
    def _reap_done(self) -> None:
        while self._inflight and self._inflight[0][0].done():
            self._wait_oldest()

    def _wait_oldest(self) -> None:
        fut, nbytes, _ = self._inflight.popleft()
        self._inflight_bytes -= nbytes
        fut.result()                           # propagate fetch errors

    def ensure(self, step: int) -> int:
        """Issue every not-yet-issued window whose first step is <= ``step``.

        Issues are ASYNC — pair with :meth:`wait_ready` (or :meth:`drain`)
        before demand-reading a step that must hit the cache. Returns the
        number of windows issued. Blocks only when the in-flight byte cap
        would be exceeded (backpressure on the oldest outstanding window).
        """
        issued = 0
        with self._lock:
            self._reap_done()
            while (self._next_window < len(self._windows)
                   and self._windows[self._next_window][0] <= step):
                start, paths, est = self._windows[self._next_window]
                while (self._inflight
                       and self._inflight_bytes + est > self.max_inflight_bytes):
                    self._wait_oldest()
                fut = self.cluster.prefetch_window_async(
                    self.node_id, paths, worker_id=self.worker_id,
                    materialize=self.materialize)
                self._inflight.append((fut, est, start))
                self._inflight_bytes += est
                self._next_window += 1
                self.windows_issued += 1
                self.bytes_scheduled += est
                issued += 1
        return issued

    def wait_ready(self, step: int) -> None:
        """Block until every in-flight window covering steps <= ``step`` has
        completed, so the demand reads for ``step`` deterministically hit
        the cache while deeper lookahead windows keep fetching."""
        with self._lock:
            while self._inflight and self._inflight[0][2] <= step:
                self._wait_oldest()

    def run_all(self) -> int:
        """Issue the whole horizon (subject to the in-flight cap)."""
        return self.ensure(self.schedule.num_steps)

    def drain(self) -> None:
        """Block until every issued window has completed."""
        with self._lock:
            while self._inflight:
                self._wait_oldest()

    def close(self) -> None:
        self.drain()


class SchedulerGroup:
    """One clairvoyant driver per (node, worker), behind the single
    ``ensure``/``wait_ready``/``close`` surface ``PrefetchLoader`` speaks.

    This is the multi-requester mode of the scheduler: the training
    driver materializes ONE :class:`EpochSchedule` over the whole
    topology and fans it out as one :class:`PrefetchScheduler` per
    (node, worker) — every node keeps its own lookahead windows in
    flight, co-located workers stage into their shared node tier, and
    the old practice of pinning every read to node 0 dies. ``ensure``
    and ``wait_ready`` fan to every member, so a single loader gating on
    step ``t`` guarantees all workers' windows covering ``t`` landed.
    """

    def __init__(self, schedulers: Sequence[PrefetchScheduler]):
        if not schedulers:
            raise ValueError("need at least one scheduler")
        self.schedulers = list(schedulers)
        # PrefetchLoader reads window_steps to default its lookahead
        self.window_steps = max(s.window_steps for s in self.schedulers)

    @classmethod
    def for_schedule(cls, cluster, schedule: EpochSchedule, *,
                     requesters: Optional[Sequence[Requester]] = None,
                     install_future: bool = True,
                     **scheduler_kwargs) -> "SchedulerGroup":
        """One member per requester of ``schedule`` (or the given
        subset), sharing ``scheduler_kwargs`` (window_steps, caps...).
        Futures are installed ONCE here for the whole group (the
        ``install_futures`` node dedup applies across members) instead of
        once per member — W schedulers on a shared tier would otherwise
        rebuild the identical node-merged trace W times."""
        reqs = list(requesters if requesters is not None
                    else schedule.requesters)
        if install_future:
            schedule.install_futures(cluster, reqs)
        return cls([PrefetchScheduler(cluster, schedule, r,
                                      install_future=False,
                                      **scheduler_kwargs)
                    for r in reqs])

    def __len__(self) -> int:
        return len(self.schedulers)

    @property
    def num_windows(self) -> int:
        return sum(s.num_windows for s in self.schedulers)

    @property
    def windows_issued(self) -> int:
        return sum(s.windows_issued for s in self.schedulers)

    @property
    def bytes_scheduled(self) -> int:
        return sum(s.bytes_scheduled for s in self.schedulers)

    def ensure(self, step: int) -> int:
        return sum(s.ensure(step) for s in self.schedulers)

    def wait_ready(self, step: int) -> None:
        for s in self.schedulers:
            s.wait_ready(step)

    def run_all(self) -> int:
        return sum(s.run_all() for s in self.schedulers)

    def drop_node(self, node_id: int) -> None:
        """Membership: detach every member scheduler that RUNS ON the dead
        node (its windows can never be consumed) and drain it, swallowing
        transport failures — in-flight windows racing the node's death may
        surface connection errors that are exactly the event being
        handled. Members on surviving nodes are untouched; their later
        windows re-resolve owners against the live failure set."""
        keep: List[PrefetchScheduler] = []
        for s in self.schedulers:
            if s.node_id != node_id:
                keep.append(s)
                continue
            try:
                s.drain()
            except (ConnectionError, TimeoutError, IOError):
                pass
        if keep:                       # never empty the group entirely
            self.schedulers = keep
            self.window_steps = max(s.window_steps for s in keep)

    def drain(self) -> None:
        self._fan("drain")

    def close(self) -> None:
        """Close every member; the first error re-raises AFTER all have
        been closed (a failing node must not leak its siblings' windows)."""
        self._fan("close")

    def _fan(self, method: str) -> None:
        err: Optional[BaseException] = None
        for s in self.schedulers:
            try:
                getattr(s, method)()
            except BaseException as e:   # propagate after full teardown
                err = err or e
        if err is not None:
            raise err
