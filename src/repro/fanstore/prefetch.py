"""Clairvoyant epoch-horizon prefetch scheduling (beyond-paper).

FanStore's access pattern is long-lasting, repeated, and *known in
advance*: the per-epoch permutation is fully determined by the sampler
seed, so a node can compute exactly which remote samples it will need,
when, and from whom. Clairvoyant Prefetching (Dryden et al., 2021) shows
that exploiting this foreknowledge recovers near-local throughput at
scale. Two pieces:

* :class:`EpochSchedule` — the materialized future: for every requester,
  the ordered list of ``(step, path, owner)`` it will read this epoch,
  derived either by replaying any sampler's state (``from_sampler``) or
  from an explicit per-step trace (``from_trace``). The schedule also
  yields each requester's demand-access sequence (``future_paths``) — the
  exact-reuse-distance oracle :class:`repro.fanstore.cache.BeladyCache`
  evicts by.
* :class:`PrefetchScheduler` — drives one requester's schedule through the
  transport's window-level async path: the horizon is cut into lookahead
  windows of ``window_steps`` training steps, and each window issues ONE
  coalesced round trip per owner (``Transport.fetch_window``) covering
  every file that owner serves *across all batches in the window* —
  amortizing latency far beyond per-batch coalescing. In-flight data is
  capped by ``max_inflight_bytes`` (backpressure: issuing a new window
  blocks on the oldest outstanding one), and fetched payloads land in the
  requester's client cache so the demand-path ``read_many`` hits at RAM
  speed. Prefetch cost accrues on the ``NodeClock.prefetch_s`` lane, so
  epoch makespan models I/O hidden behind compute instead of serializing.

The write half mirrors this: checkpoint flushes issued through
:class:`repro.fanstore.api.CheckpointWriter` land on the concurrent
``NodeClock.write_s`` lane, so a shard shipped while a prefetch window is
in flight costs ``max(prefetch, write)`` in the epoch makespan — the two
scheduled lanes overlap each other as well as the demand timeline.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import (Callable, Deque, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

__all__ = ["ScheduledRead", "EpochSchedule", "PrefetchScheduler"]


@dataclass(frozen=True)
class ScheduledRead:
    """One future read: global step, path, and the node expected to serve
    it (the requester itself for node-local files; -1 when no cluster was
    available to resolve ownership). Paths are stored normalized
    (no leading slash) so they match client-cache keys exactly — the
    Belady oracle depends on that."""
    step: int
    path: str
    owner: int = -1


class EpochSchedule:
    """Per-requester ordered future reads for one epoch (or trace).

    ``reads_by_requester[r]`` is sorted by step; within a step, order is
    the batch's index order (which is the demand-read order).
    """

    def __init__(self, reads_by_requester: Mapping[int, Sequence[ScheduledRead]]):
        self._reads: Dict[int, List[ScheduledRead]] = {
            int(r): sorted(reads, key=lambda s: s.step)
            for r, reads in reads_by_requester.items()}
        self.num_steps = max(
            (reads[-1].step + 1 for reads in self._reads.values() if reads),
            default=0)

    # ---- construction ------------------------------------------------------
    @classmethod
    def from_sampler(cls, sampler, paths: Sequence[str], *,
                     num_requesters: int, cluster=None,
                     epoch: Optional[int] = None) -> "EpochSchedule":
        """Materialize the epoch's permutation from any checkpointable
        sampler (``state``/``restore``/``next_batch``) without advancing it.

        Each global batch is split into ``num_requesters`` contiguous
        per-requester slices — the convention the device tier and
        ``StratifiedSampler`` already use. ``paths[i]`` maps sample index i
        to its file; ``cluster`` (optional) annotates each read with its
        expected serving node (informational — the scheduler re-resolves
        owners at issue time against the live failure set).
        """
        batches = sampler.peek_epoch(epoch)
        reads: Dict[int, List[ScheduledRead]] = {
            r: [] for r in range(num_requesters)}
        for step, batch in enumerate(batches):
            if len(batch) % num_requesters:
                raise ValueError(
                    "num_requesters must divide the global batch size")
            per = len(batch) // num_requesters
            for r in range(num_requesters):
                for idx in batch[r * per:(r + 1) * per]:
                    path = paths[int(idx)].strip("/")
                    owner = _resolve_owner(cluster, r, path)
                    reads[r].append(ScheduledRead(step, path, owner))
        return cls(reads)

    @classmethod
    def from_trace(cls, traces: Mapping[int, Sequence[Sequence[str]]],
                   cluster=None) -> "EpochSchedule":
        """Build from explicit per-step path lists:
        ``traces[requester] = [[paths of step 0], [paths of step 1], ...]``.
        """
        reads: Dict[int, List[ScheduledRead]] = {}
        for r, steps in traces.items():
            out: List[ScheduledRead] = []
            for step, batch in enumerate(steps):
                for path in batch:
                    path = path.strip("/")
                    out.append(ScheduledRead(
                        step, path, _resolve_owner(cluster, r, path)))
            reads[int(r)] = out
        return cls(reads)

    # ---- views -------------------------------------------------------------
    @property
    def requesters(self) -> List[int]:
        return sorted(self._reads)

    def for_requester(self, requester: int) -> List[ScheduledRead]:
        return list(self._reads.get(requester, []))

    def future_paths(self, requester: int) -> List[str]:
        """The requester's demand-access sequence — Belady's oracle."""
        return [s.path for s in self._reads.get(requester, [])]

    def install_futures(self, cluster,
                        requesters: Optional[Sequence[int]] = None) -> int:
        """Hand each requester's future trace to its cluster cache (no-op
        for policies without a ``set_future`` hook). Returns caches fed."""
        fed = 0
        for r in (requesters if requesters is not None else self.requesters):
            cache = cluster.caches.get(r)
            if cache is not None and hasattr(cache, "set_future"):
                cache.set_future(self.future_paths(r))
                fed += 1
        return fed


def _resolve_owner(cluster, requester: int, path: str) -> int:
    if cluster is None:
        return -1
    path = path.strip("/")
    if cluster.nodes[requester].has(path):
        return requester
    hit = cluster.metadata.lookup(path)
    if hit is None:
        return -1                     # output file: not prefetchable
    _, loc = hit
    for owner in loc.all_owners:
        if owner not in cluster.failed:
            return owner
    return -1


class PrefetchScheduler:
    """Issue one requester's epoch schedule as lookahead windows of
    coalesced async fetches, with a byte-budget in-flight cap.

    Typical use (or let ``PrefetchLoader(schedule=...)`` drive it)::

        sched = EpochSchedule.from_sampler(sampler, paths,
                                           num_requesters=N, cluster=c)
        pf = PrefetchScheduler(c, sched, requester=r, window_steps=8)
        for step in range(steps):
            pf.ensure(step + lookahead)     # non-blocking unless over cap
            c.read_many(r, batch_paths)     # hits the client cache
        pf.close()

    Windows are ``window_steps`` consecutive training steps; window *i* is
    issued as ONE ``cluster.prefetch_window`` call, which groups the
    window's files per owner and pays one round trip per (requester,
    owner, window). ``max_inflight_bytes`` caps outstanding prefetched-but-
    unconsumed bytes: when exceeded, :meth:`ensure` blocks on the oldest
    outstanding window (backpressure) before issuing the next.

    Construction installs the schedule's future trace into the requester's
    cache when the policy supports it (Belady), so prefetch, demand reads,
    and eviction all share one view of the future.
    """

    def __init__(self, cluster, schedule: EpochSchedule, requester: int, *,
                 window_steps: int = 8,
                 max_inflight_bytes: int = 256 * 1024 * 1024,
                 materialize: bool = True,
                 install_future: bool = True):
        if window_steps < 1:
            raise ValueError("window_steps must be >= 1")
        if max_inflight_bytes < 1:
            raise ValueError("max_inflight_bytes must be >= 1")
        self.cluster = cluster
        self.schedule = schedule
        self.requester = requester
        self.window_steps = window_steps
        self.max_inflight_bytes = max_inflight_bytes
        self.materialize = materialize
        self._windows = self._cut_windows(schedule.for_requester(requester))
        self._next_window = 0
        # in-flight windows, oldest first: (future, est_bytes, start_step)
        self._inflight: Deque[Tuple["object", int, int]] = deque()
        self._inflight_bytes = 0
        self._lock = threading.Lock()
        self.windows_issued = 0
        self.bytes_scheduled = 0
        if install_future:
            schedule.install_futures(cluster, [requester])

    # ---- window construction -----------------------------------------------
    def _cut_windows(self, reads: Sequence[ScheduledRead]
                     ) -> List[Tuple[int, List[str], int]]:
        """[(start_step, unique paths, est_bytes)] per lookahead window."""
        if not reads:
            return []
        meta = self.cluster.metadata
        w = self.window_steps
        paths_by_window: Dict[int, List[str]] = {}
        est_by_window: Dict[int, int] = {}
        seen_by_window: Dict[int, set] = {}
        for s in reads:                       # one pass, grouped by window
            start = (s.step // w) * w
            seen = seen_by_window.setdefault(start, set())
            if s.path in seen:
                continue
            seen.add(s.path)
            paths_by_window.setdefault(start, []).append(s.path)
            st = meta.stat(s.path)            # schedule paths are normalized
            est_by_window[start] = est_by_window.get(start, 0) + (
                st.st_size if st is not None else 0)
        return [(start, paths_by_window[start], est_by_window[start])
                for start in sorted(paths_by_window)]

    @property
    def num_windows(self) -> int:
        return len(self._windows)

    # ---- issue/backpressure -------------------------------------------------
    def _reap_done(self) -> None:
        while self._inflight and self._inflight[0][0].done():
            self._wait_oldest()

    def _wait_oldest(self) -> None:
        fut, nbytes, _ = self._inflight.popleft()
        self._inflight_bytes -= nbytes
        fut.result()                           # propagate fetch errors

    def ensure(self, step: int) -> int:
        """Issue every not-yet-issued window whose first step is <= ``step``.

        Issues are ASYNC — pair with :meth:`wait_ready` (or :meth:`drain`)
        before demand-reading a step that must hit the cache. Returns the
        number of windows issued. Blocks only when the in-flight byte cap
        would be exceeded (backpressure on the oldest outstanding window).
        """
        issued = 0
        with self._lock:
            self._reap_done()
            while (self._next_window < len(self._windows)
                   and self._windows[self._next_window][0] <= step):
                start, paths, est = self._windows[self._next_window]
                while (self._inflight
                       and self._inflight_bytes + est > self.max_inflight_bytes):
                    self._wait_oldest()
                fut = self.cluster.prefetch_window_async(
                    self.requester, paths, materialize=self.materialize)
                self._inflight.append((fut, est, start))
                self._inflight_bytes += est
                self._next_window += 1
                self.windows_issued += 1
                self.bytes_scheduled += est
                issued += 1
        return issued

    def wait_ready(self, step: int) -> None:
        """Block until every in-flight window covering steps <= ``step`` has
        completed, so the demand reads for ``step`` deterministically hit
        the cache while deeper lookahead windows keep fetching."""
        with self._lock:
            while self._inflight and self._inflight[0][2] <= step:
                self._wait_oldest()

    def run_all(self) -> int:
        """Issue the whole horizon (subject to the in-flight cap)."""
        return self.ensure(self.schedule.num_steps)

    def drain(self) -> None:
        """Block until every issued window has completed."""
        with self._lock:
            while self._inflight:
                self._wait_oldest()

    def close(self) -> None:
        self.drain()
