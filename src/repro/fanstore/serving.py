"""Serving plane: multi-tenant read-mostly sessions over the engine.

Everything through PR 7 drives ONE training job; the ROADMAP's north star
is a production system serving heavy read traffic from many concurrent
clients. This module opens the engine to that workload: hundreds of
inference replicas ("tenants") per node streaming param / KV shards
through ``FanStoreSession.read_many`` on the concurrent
``NodeClock.serve_app_s`` lane, governed by three mechanisms a shared
store needs before it can take public traffic:

* **Admission control** — :class:`AdmissionGate`, one per node: a
  ``max_inflight_bytes`` byte gate that QUEUES new requests when the
  node's wire is saturated and SHEDS them (:class:`AdmissionShed`) when
  the queue itself is full, instead of oversubscribing the fabric. The
  same backpressure idea as the prefetch scheduler's inflight cap
  (PR 2), promoted to a multi-client gate.

* **Fairness** — queued requests release in deficit-round-robin order:
  every backlogged tenant accrues a byte quantum per scheduling round
  and admits requests against its deficit, so a zipf-head tenant
  pushing 10x the tail's load gets 10x the QUEUE time, not 10x the
  service share. Per-tenant byte/request/time attribution lands on
  ``NodeClock.tenant_*`` (sums tie out to the serve-app lane totals by
  construction, like PR 5's worker cache attribution).

* **Hot-shard replication** — :class:`placement.ShardPopularity` counts
  reads per partition online; when one crosses
  ``hot_shard_threshold`` reads the :class:`ServeGroup` promotes it to
  replicated placement through PR 7's ``cluster.replicate_partition``
  (write-lane wire cost, metadata replica-set extension) and subsequent
  reads spread over the replicas via the cluster's selector —
  ``selector="power-of-two"`` on the spec is the intended pairing
  (sample two owners, serve from the lighter).

Knob defaults come from the :class:`~repro.fanstore.spec.ClusterSpec`
serving fields (``max_inflight_bytes`` / ``serve_queue_depth`` /
``serve_quantum_bytes`` / ``hot_shard_threshold`` /
``hot_shard_replication``); ``ServeGroup`` kwargs override per group.

Hoard (PAPERS.md) is the closest prior shape — a shared node cache tier
absorbing many concurrent readers; FalconFS motivates keeping the
metadata path cheap as client count explodes (tenant sessions here add
zero metadata state: they are coordinates plus a ledger key).
"""
from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import Future
from typing import Deque, Dict, List, Optional, Sequence

from repro.fanstore.cluster import FanStoreCluster
from repro.fanstore.placement import ShardPopularity, make_selector

__all__ = ["AdmissionShed", "AdmissionGate", "TenantSession", "ServeGroup"]


class AdmissionShed(RuntimeError):
    """The admission gate refused a request instead of queueing it:
    either the per-node queue is at ``queue_depth`` (the node is beyond
    saturated — callers should back off / retry elsewhere) or a single
    request exceeds ``max_inflight_bytes`` outright (it could never be
    admitted and would deadlock the queue)."""


class _Ticket:
    """One queued admission request (internal)."""

    __slots__ = ("tenant", "nbytes", "admitted", "event")

    def __init__(self, tenant: str, nbytes: int):
        self.tenant = tenant
        self.nbytes = nbytes
        self.admitted = False
        self.event = threading.Event()


class AdmissionGate:
    """Per-node ``max_inflight_bytes`` gate with deficit-round-robin
    release order.

    ``acquire(tenant, nbytes)`` admits immediately while the node's
    inflight budget covers the request, blocks the caller while it does
    not, and raises :class:`AdmissionShed` when the queue is full. Every
    ``release(nbytes)`` pumps the queue: backlogged tenants are visited
    round-robin, each visit tops up the tenant's byte deficit by
    ``quantum_bytes``, and its head request admits once the deficit
    covers it AND the budget fits it — classic DRR, so service share
    under contention is per-tenant, not per-request (a zipf-head tenant
    cannot starve the tail by queueing more).

    ``max_inflight_bytes=None`` (or 0 via the spec) disables the cap:
    every request admits immediately, but the inflight/peak ledger is
    still kept so benchmarks can report actual concurrency.

    The deterministic test surface: :meth:`submit` enqueues without
    blocking and returns the ticket; tests drive :meth:`release` and
    assert on admission order. ``acquire`` is submit + wait.
    """

    def __init__(self, max_inflight_bytes: Optional[int], *,
                 quantum_bytes: int = 1 << 20, queue_depth: int = 1024):
        if max_inflight_bytes is not None and max_inflight_bytes <= 0:
            max_inflight_bytes = None
        self.max_inflight_bytes = max_inflight_bytes
        self.quantum_bytes = max(1, int(quantum_bytes))
        self.queue_depth = max(1, int(queue_depth))
        self._lock = threading.Lock()
        self._queues: Dict[str, Deque[_Ticket]] = {}
        self._ring: Deque[str] = deque()
        self._deficit: Dict[str, int] = {}
        self._queued = 0
        # ledger (read under the lock via stats())
        self.inflight_bytes = 0
        self.peak_inflight_bytes = 0
        self.admitted = 0
        self.waits = 0          # acquires that had to queue
        self.shed = 0
        self.queued_peak = 0

    def _fits(self, nbytes: int) -> bool:
        return self.max_inflight_bytes is None or \
            self.inflight_bytes + nbytes <= self.max_inflight_bytes

    def _admit(self, ticket: _Ticket) -> None:
        self.inflight_bytes += ticket.nbytes
        self.peak_inflight_bytes = max(self.peak_inflight_bytes,
                                       self.inflight_bytes)
        self.admitted += 1
        ticket.admitted = True
        ticket.event.set()

    def _pump(self) -> None:
        """Admit every queued request the budget and deficits allow
        (call under the lock). One DRR round per pass over the ring;
        stops when the head-of-ring request no longer fits the budget —
        WITHOUT accruing that tenant's quantum, and without rotating, so
        the next ``release`` resumes at the same tenant. Deficit only
        accrues on visits where the budget could serve the tenant:
        otherwise a backlogged tenant banks unbounded deficit while the
        gate is full and drains it all ahead of everyone else once bytes
        free up (the starvation DRR exists to prevent)."""
        progressed = True
        while progressed and self._ring:
            progressed = False
            for _ in range(len(self._ring)):
                tenant = self._ring[0]
                q = self._queues.get(tenant)
                if not q:
                    # drained tenant leaves the ring; its unused deficit
                    # dies with it (standard DRR — no banking across
                    # idle periods)
                    self._ring.popleft()
                    self._queues.pop(tenant, None)
                    self._deficit.pop(tenant, None)
                    progressed = True
                    continue
                if not self._fits(q[0].nbytes):
                    return                # budget-bound: wait for release
                self._deficit[tenant] = \
                    self._deficit.get(tenant, 0) + self.quantum_bytes
                while q and self._deficit[tenant] >= q[0].nbytes:
                    if not self._fits(q[0].nbytes):
                        break             # spent the freed budget
                    ticket = q.popleft()
                    self._queued -= 1
                    self._deficit[tenant] -= ticket.nbytes
                    self._admit(ticket)
                    progressed = True
                self._ring.rotate(-1)

    def submit(self, tenant: str, nbytes: int) -> _Ticket:
        """Enqueue one admission request without blocking; the returned
        ticket's ``event`` fires when it admits. Raises
        :class:`AdmissionShed` on a full queue or an unserviceable
        (over-budget) request."""
        nbytes = max(0, int(nbytes))
        ticket = _Ticket(tenant, nbytes)
        with self._lock:
            if self.max_inflight_bytes is not None \
                    and nbytes > self.max_inflight_bytes:
                self.shed += 1
                raise AdmissionShed(
                    f"request of {nbytes} bytes exceeds max_inflight_bytes="
                    f"{self.max_inflight_bytes} (tenant {tenant})")
            # fast path: idle queue + budget headroom -> admit in place
            if not self._queued and self._fits(nbytes):
                self._admit(ticket)
                return ticket
            if self._queued >= self.queue_depth:
                self.shed += 1
                raise AdmissionShed(
                    f"admission queue full ({self.queue_depth} deep); "
                    f"shedding tenant {tenant}")
            self.waits += 1
            if tenant not in self._queues:
                self._queues[tenant] = deque()
                self._ring.append(tenant)
            self._queues[tenant].append(ticket)
            self._queued += 1
            self.queued_peak = max(self.queued_peak, self._queued)
            self._pump()
        return ticket

    def acquire(self, tenant: str, nbytes: int,
                timeout: Optional[float] = None) -> None:
        """Block until ``nbytes`` are admitted under the gate (or raise
        :class:`AdmissionShed`). ``timeout`` bounds the wait; on timeout
        the request counts as shed."""
        ticket = self.submit(tenant, nbytes)
        if ticket.event.wait(timeout):
            return
        with self._lock:
            if ticket.admitted:        # admitted as the wait expired
                return
            self._queues[tenant].remove(ticket)
            self._queued -= 1
            self.shed += 1
        raise AdmissionShed(
            f"tenant {tenant} timed out awaiting {nbytes} bytes")

    def release(self, nbytes: int) -> None:
        """Return ``nbytes`` of budget and admit what now fits."""
        with self._lock:
            self.inflight_bytes = max(0, self.inflight_bytes - int(nbytes))
            self._pump()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "max_inflight_bytes": self.max_inflight_bytes or 0,
                "inflight_bytes": self.inflight_bytes,
                "peak_inflight_bytes": self.peak_inflight_bytes,
                "admitted": self.admitted,
                "waits": self.waits,
                "shed": self.shed,
                "queued": self._queued,
                "queued_peak": self.queued_peak,
            }


class TenantSession:
    """One tenant's read-mostly handle: a :class:`FanStoreSession` bound
    to (node, worker) with ``read_lane="serve_app"`` + the tenant id,
    fronted by the node's admission gate and the group's hot-shard
    tracker. Non-read verbs (``exists``/``listdir``/``stat``/...)
    delegate untouched, so pytree restore helpers
    (``repro.train.checkpoint.restore_from_session``) work on a tenant
    session unmodified — params and KV shards stream through the gated
    serve-app lane."""

    def __init__(self, group: "ServeGroup", tenant: str, session):
        self.group = group
        self.tenant = tenant
        self.session = session
        self.node_id = session.node_id

    def read_many(self, paths: Sequence[str], *,
                  materialize: bool = True) -> List[bytes]:
        """Gated batched read on the serve-app lane: admission is sized
        by the batch's metadata byte total BEFORE any payload moves, so
        a saturated node queues (or sheds) the request instead of
        oversubscribing its wire."""
        return self.group._gated_read(self, paths, materialize=materialize)

    def read_many_async(self, paths: Sequence[str], *,
                        materialize: bool = True) -> "Future[List[bytes]]":
        """Gated read on the transport's I/O pool (the gate blocks the
        pool thread, not the caller)."""
        return self.group.cluster.transport.submit(
            self.read_many, list(paths), materialize=materialize)

    def __getattr__(self, name):
        # everything that is not a gated read (exists/listdir/stat/
        # resolve/open/...) is the plain session surface
        return getattr(self.session, name)


class ServeGroup:
    """The serving plane over one cluster: opens ``num_tenants``
    read-mostly tenant sessions spread round-robin across the live
    nodes, gates their admissions per node, attributes every byte per
    tenant, and promotes hot shards to replicated placement.

    >>> spec = ClusterSpec(num_nodes=8, selector="power-of-two",
    ...                    max_inflight_bytes=4 << 20,
    ...                    hot_shard_threshold=64)
    >>> with FanStoreCluster.from_spec(spec) as cluster:
    ...     group = ServeGroup(cluster, num_tenants=128)
    ...     data = group.read_many("tenant-0007", shard_paths)

    Thread-safe end to end: tenants are expected to call in from many
    threads (or via :meth:`submit` on the transport pool).
    """

    def __init__(self, cluster: FanStoreCluster, num_tenants: int, *,
                 worker_id: int = 0,
                 max_inflight_bytes: Optional[int] = None,
                 quantum_bytes: Optional[int] = None,
                 queue_depth: Optional[int] = None,
                 hot_shard_threshold: Optional[int] = None,
                 hot_shard_replication: Optional[int] = None,
                 selector: Optional[str] = None):
        if num_tenants < 1:
            raise ValueError("num_tenants must be >= 1")
        spec = cluster.spec
        self.cluster = cluster
        if max_inflight_bytes is None:
            max_inflight_bytes = spec.max_inflight_bytes
        self.max_inflight_bytes = max_inflight_bytes or 0
        quantum = quantum_bytes or spec.serve_quantum_bytes
        depth = queue_depth or spec.serve_queue_depth
        self.hot_shard_threshold = spec.hot_shard_threshold \
            if hot_shard_threshold is None else hot_shard_threshold
        self.hot_shard_replication = spec.hot_shard_replication \
            if hot_shard_replication is None else hot_shard_replication
        if self.hot_shard_threshold > 0 \
                and self.hot_shard_replication > cluster.num_nodes:
            raise ValueError(
                f"hot_shard_replication={self.hot_shard_replication} "
                f"exceeds the {cluster.num_nodes}-node topology")
        if selector is not None:
            # the power-of-two pairing: promotion only pays off when
            # reads actually spread over the new replicas
            cluster.selector = make_selector(selector)
        live = cluster.live_nodes()
        if not live:
            raise RuntimeError("no live nodes to serve from")
        self.gates: Dict[int, AdmissionGate] = {
            n: AdmissionGate(self.max_inflight_bytes or None,
                             quantum_bytes=quantum, queue_depth=depth)
            for n in cluster.nodes}
        self.popularity = ShardPopularity()
        # output files have no partition id; their heat is tracked by
        # path and promoted through cluster.replicate_output instead
        self.output_popularity = ShardPopularity()
        self.promoted: List[int] = []
        self.promoted_outputs: List[str] = []
        self._promo_lock = threading.Lock()
        self._sessions: Dict[str, TenantSession] = {}
        for i in range(num_tenants):
            tenant = f"tenant-{i:04d}"
            node = live[i % len(live)]
            raw = cluster.connect(node, worker_id, read_lane="serve_app",
                                  tenant=tenant)
            self._sessions[tenant] = TenantSession(self, tenant, raw)

    # ---- tenant surface ----------------------------------------------------
    @property
    def tenants(self) -> List[str]:
        return sorted(self._sessions)

    def session(self, tenant: str) -> TenantSession:
        try:
            return self._sessions[tenant]
        except KeyError:
            raise KeyError(f"unknown tenant {tenant!r} "
                           f"(group has {len(self._sessions)})") from None

    def read_many(self, tenant: str, paths: Sequence[str], *,
                  materialize: bool = True) -> List[bytes]:
        return self.session(tenant).read_many(paths, materialize=materialize)

    def submit(self, tenant: str, paths: Sequence[str], *,
               materialize: bool = True) -> "Future[List[bytes]]":
        return self.session(tenant).read_many_async(
            paths, materialize=materialize)

    # ---- the gated read path ----------------------------------------------
    def _gated_read(self, ts: TenantSession, paths: Sequence[str], *,
                    materialize: bool) -> List[bytes]:
        session = ts.session
        resolved = [session.resolve(p) for p in paths]
        nbytes = 0
        pids: List[int] = []
        outs: List[str] = []
        for path in resolved:
            st, loc = self.cluster._lookup(path)
            nbytes += st.st_size
            if loc.partition_id >= 0:
                pids.append(loc.partition_id)
            else:
                outs.append(path)            # committed output: heat by path
        gate = self.gates[ts.node_id]
        gate.acquire(ts.tenant, nbytes)
        try:
            out = self.cluster.read_many(
                ts.node_id, resolved, worker_id=session.worker_id,
                materialize=materialize, lane="serve_app", tenant=ts.tenant)
        finally:
            gate.release(nbytes)
        for pid in pids:
            self.popularity.note(pid)
        for path in outs:
            self.output_popularity.note(path)
        if self.hot_shard_threshold > 0:
            self._maybe_promote()
        return out

    # ---- hot-shard promotion ----------------------------------------------
    def _maybe_promote(self) -> None:
        """Promote everything past the popularity threshold to
        ``hot_shard_replication`` live copies: input partitions through
        PR 7's ``replicate_partition``, committed outputs through
        ``replicate_output`` (both pay write-lane wire cost and extend
        the replica-set metadata). Runs inline on the reader thread that
        tripped the threshold; the promo lock keeps concurrent readers
        from double-shipping the same shard."""
        hot = self.popularity.hot(min_reads=self.hot_shard_threshold)
        hot_outs = self.output_popularity.hot(
            min_reads=self.hot_shard_threshold)
        if not hot and not hot_outs:
            return
        with self._promo_lock:
            for pid in hot:
                self._promote_locked(pid)
            for path in hot_outs:
                self._promote_output_locked(path)

    def _promote_locked(self, pid: int) -> None:
        cluster = self.cluster
        live = set(cluster.live_nodes())
        holders = [n for n in live if pid in cluster.nodes[n].partition_ids]
        if not holders:
            return
        want = min(self.hot_shard_replication, len(live))
        while len(holders) < want:
            candidates = [n for n in live if n not in holders]
            if not candidates:
                break
            # least-serve-loaded live node takes the new copy
            dst = min(candidates,
                      key=lambda n: (cluster.clocks[n].serve_s, n))
            src = min(holders,
                      key=lambda n: (cluster.clocks[n].serve_s, n))
            cluster.replicate_partition(pid, src, dst)
            holders.append(dst)
            if pid not in self.promoted:
                self.promoted.append(pid)

    def _promote_output_locked(self, path: str) -> None:
        cluster = self.cluster
        hit = cluster.output_ns.lookup(path)
        if hit is None:                      # unlinked since it got hot
            return
        _, loc = hit
        live = set(cluster.live_nodes())
        holders = [n for n in loc.all_owners if n in live]
        if not holders:
            return
        want = min(self.hot_shard_replication, len(live))
        while len(holders) < want:
            candidates = [n for n in live if n not in holders]
            if not candidates:
                break
            dst = min(candidates,
                      key=lambda n: (cluster.clocks[n].serve_s, n))
            src = min(holders,
                      key=lambda n: (cluster.clocks[n].serve_s, n))
            cluster.replicate_output(path, src, dst)
            holders.append(dst)
            if path not in self.promoted_outputs:
                self.promoted_outputs.append(path)

    # ---- observability -----------------------------------------------------
    def gate_stats(self) -> Dict[int, Dict[str, int]]:
        return {n: g.stats() for n, g in self.gates.items()}

    def peak_inflight_bytes(self) -> int:
        """Max measured inflight bytes across every node gate — the
        BENCH guard asserts this never exceeds ``max_inflight_bytes``."""
        return max((g.peak_inflight_bytes for g in self.gates.values()),
                   default=0)

    def stats(self) -> Dict[str, object]:
        acct = self.cluster.accounting
        gates = self.gate_stats()
        return {
            "tenants": len(self._sessions),
            "max_inflight_bytes": self.max_inflight_bytes,
            "peak_inflight_bytes": self.peak_inflight_bytes(),
            "admitted": sum(g["admitted"] for g in gates.values()),
            "waits": sum(g["waits"] for g in gates.values()),
            "shed": sum(g["shed"] for g in gates.values()),
            "promoted_partitions": sorted(self.promoted),
            "promoted_outputs": sorted(self.promoted_outputs),
            "serve_app_bytes": acct.serve_app_bytes(),
            "serve_app_requests": acct.serve_app_requests(),
            "tenant_bytes": acct.tenant_bytes(),
            "tenant_requests": acct.tenant_requests(),
            "tenant_serve_s": acct.tenant_serve_s(),
        }

    def attribution_ok(self) -> bool:
        """Exact tie-out: per-tenant sums equal the serve-app lane totals
        on every node (the PR-5 attribution contract, serving edition)."""
        for clock in self.cluster.clocks.values():
            if sum(clock.tenant_bytes.values()) != clock.serve_app_bytes:
                return False
            if sum(clock.tenant_requests.values()) != clock.serve_app_requests:
                return False
            if abs(sum(clock.tenant_serve_s.values())
                   - clock.serve_app_s) > 1e-9 * max(1.0, clock.serve_app_s):
                return False
        return True
