"""Observability plane: metric accumulators, reduce modes, and a
streaming telemetry pipeline.

The engine's clocks (:mod:`repro.fanstore.accounting`) are rich but
passive — every benchmark and driver used to hand-roll its own dict
plumbing to get numbers out. This module is the one pipeline they all
emit through now:

* :class:`Reduce` / :class:`Mode` — how a series folds (SUM / MEAN /
  MAX / MIN / COUNT / P50 / P99) and whether the collector keeps
  (node, worker)-keyed series (``PER_RANK``) or folds them across the
  topology at flush (``GLOBAL_REDUCE``).
* :class:`QuantileSketch` — bounded-memory streaming quantiles behind
  P50/P99: a capacity-``C`` buffer of (value, weight) clusters that
  pairwise-merges adjacent clusters when full, so memory stays O(C)
  independent of sample count and the rank error stays ~2/C.
* The :class:`MetricAccumulator` hierarchy — :class:`ScalarAccumulator`
  (sum/count/min/max), :class:`DistributionAccumulator` (scalar stats +
  sketch), :class:`RateAccumulator` (value per wall-clock second).
* :class:`MetricsCollector` — thread-safe, owned by the cluster
  (``cluster.metrics``). ``record_metric(name, value, reduce=...)``
  takes only the collector's OWN lock, never the clock lock, so
  serving-loop / stripe / prefetch threads can flush into it without
  contending accrual. The ledger bridge happens at ``snapshot()`` time
  via :meth:`repro.fanstore.accounting.ClusterAccounting.snapshot` —
  one consistent copy of lane seconds, cache hit rates, tenant/job
  attribution, retry/fault counters, stripe bytes, and wire codec
  savings.
* :class:`JsonlSink` — streaming, crash-safe append of monotonically
  versioned snapshots: one JSON object per line, periodic
  (:meth:`JsonlSink.tick`) + explicit (:meth:`JsonlSink.flush`)
  flushes, size-based rotation, and a reloader that tolerates a torn
  trailing line (the crash case append-only files actually hit).
* :class:`SloGuard` / :func:`check_slos` — declarative threshold checks
  over a snapshot document (dotted paths with ``*`` wildcards,
  cross-path :class:`Ref` comparisons, conditional ``when`` clauses).
  ``benchmarks/run.py`` expresses every BENCH_io.json guard as a table
  of these instead of assert soup.

Provenance discipline: everything under ``snapshot()["nodes"][i]
["modeled"]`` / ``["cluster"]`` modeled aggregates is deterministic
model output; everything under ``["measured"]`` is hardware truth from
the real-wire backends. App-level series recorded through
``record_metric`` are whatever the caller measured (see the metric
catalog in ARCHITECTURE.md).
"""
from __future__ import annotations

import copy
import enum
import json
import os
import threading
import time
import weakref
from dataclasses import dataclass
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple, Union)

__all__ = [
    "Reduce", "Mode", "QuantileSketch",
    "MetricAccumulator", "ScalarAccumulator", "DistributionAccumulator",
    "RateAccumulator", "make_accumulator",
    "MetricsCollector", "JsonlSink",
    "SloGuard", "Ref", "check_slos", "resolve_path",
]


class Reduce(enum.Enum):
    """How a metric series folds to one number."""
    SUM = "sum"
    MEAN = "mean"
    MAX = "max"
    MIN = "min"
    COUNT = "count"
    P50 = "p50"
    P99 = "p99"


class Mode(enum.Enum):
    """Collection mode: keep (node, worker)-keyed series, or fold them
    across the topology at flush. The collector always STORES per-rank
    (so the two modes are views of the same data and provably agree
    under reduction); the mode picks what ``snapshot()`` renders."""
    PER_RANK = "per_rank"
    GLOBAL_REDUCE = "global_reduce"


# ---------------------------------------------------------------------------
# bounded-memory quantile sketch
# ---------------------------------------------------------------------------
class QuantileSketch:
    """Streaming quantile estimator with O(capacity) memory.

    Keeps at most ``capacity`` (value, weight) clusters, each value a
    REAL observed sample. When the buffer fills, adjacent clusters
    (after a sort by value) pairwise-merge — the heavier member's value
    survives with the pair's combined weight — halving the buffer in one
    pass. Each compaction at most doubles the maximum cluster weight,
    and ``n`` samples fit in ``log2(2n/capacity)`` compactions, so the
    worst-case cluster weight — and therefore the absolute rank error of
    :meth:`query` — is about ``2n/capacity`` (relative rank error
    ``~2/capacity``). ``capacity=512`` gives <1% rank error, enough to
    tell a 10x P99 regression from noise at any sample count.
    """

    __slots__ = ("capacity", "_entries", "compactions")

    def __init__(self, capacity: int = 512):
        if capacity < 8:
            raise ValueError("sketch capacity must be >= 8")
        self.capacity = int(capacity)
        self._entries: List[Tuple[float, int]] = []  # (value, weight)
        self.compactions = 0

    def __len__(self) -> int:
        """Number of retained clusters — bounded by ``capacity``."""
        return len(self._entries)

    @property
    def count(self) -> int:
        """Total weight observed (== number of ``add(w=1)`` calls)."""
        return sum(w for _, w in self._entries)

    def add(self, value: float, weight: int = 1) -> None:
        self._entries.append((float(value), int(weight)))
        if len(self._entries) > self.capacity:
            self._compact()

    def merge(self, other: "QuantileSketch") -> None:
        """Fold another sketch in (GLOBAL_REDUCE across ranks)."""
        self._entries.extend(other._entries)
        while len(self._entries) > self.capacity:
            self._compact()

    def _compact(self) -> None:
        """Sort by value and merge adjacent pairs, keeping the heavier
        member's (real) value with the pair's combined weight."""
        self._entries.sort()
        merged: List[Tuple[float, int]] = []
        it = iter(self._entries)
        for a in it:
            b = next(it, None)
            if b is None:
                merged.append(a)
            else:
                keep = a[0] if a[1] >= b[1] else b[0]
                merged.append((keep, a[1] + b[1]))
        self._entries = merged
        self.compactions += 1

    def query(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1] (0.0 when empty)."""
        if not self._entries:
            return 0.0
        q = min(1.0, max(0.0, float(q)))
        entries = sorted(self._entries)
        total = sum(w for _, w in entries)
        target = q * total
        cum = 0
        for value, weight in entries:
            cum += weight
            if cum >= target:
                return value
        return entries[-1][0]


# ---------------------------------------------------------------------------
# accumulator hierarchy
# ---------------------------------------------------------------------------
class MetricAccumulator:
    """One metric series' state for one rank. Subclasses define what is
    retained; :meth:`value` folds it per the declared :class:`Reduce`.
    NOT thread-safe on its own — the collector serializes access."""

    kind = "abstract"

    def __init__(self, reduce: Reduce):
        self.reduce = reduce

    def observe(self, value: float) -> None:
        raise NotImplementedError

    def merge(self, other: "MetricAccumulator") -> None:
        raise NotImplementedError

    def value(self) -> float:
        raise NotImplementedError

    def summary(self) -> Dict[str, Any]:
        raise NotImplementedError

    def clone(self) -> "MetricAccumulator":
        return copy.deepcopy(self)


class ScalarAccumulator(MetricAccumulator):
    """sum / count / min / max — answers SUM, MEAN, MAX, MIN, COUNT."""

    kind = "scalar"

    def __init__(self, reduce: Reduce = Reduce.SUM):
        if reduce in (Reduce.P50, Reduce.P99):
            raise ValueError(
                f"{reduce.name} needs a DistributionAccumulator")
        super().__init__(reduce)
        self.sum = 0.0
        self.count = 0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def merge(self, other: "MetricAccumulator") -> None:
        self.sum += other.sum
        self.count += other.count
        for attr, pick in (("min", min), ("max", max)):
            mine, theirs = getattr(self, attr), getattr(other, attr)
            if theirs is not None:
                setattr(self, attr,
                        theirs if mine is None else pick(mine, theirs))

    def value(self) -> float:
        r = self.reduce
        if r is Reduce.SUM:
            return self.sum
        if r is Reduce.COUNT:
            return float(self.count)
        if r is Reduce.MEAN:
            return self.sum / self.count if self.count else 0.0
        if r is Reduce.MAX:
            return self.max if self.max is not None else 0.0
        if r is Reduce.MIN:
            return self.min if self.min is not None else 0.0
        raise ValueError(f"unhandled reduce {r}")  # pragma: no cover

    def summary(self) -> Dict[str, Any]:
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max}


class DistributionAccumulator(ScalarAccumulator):
    """Scalar stats plus a bounded-memory sketch — adds P50 / P99."""

    kind = "distribution"

    def __init__(self, reduce: Reduce = Reduce.P99,
                 sketch_capacity: int = 512):
        MetricAccumulator.__init__(self, reduce)
        self.sum = 0.0
        self.count = 0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.sketch = QuantileSketch(sketch_capacity)

    def observe(self, value: float) -> None:
        ScalarAccumulator.observe(self, value)
        self.sketch.add(float(value))

    def merge(self, other: "MetricAccumulator") -> None:
        ScalarAccumulator.merge(self, other)
        if isinstance(other, DistributionAccumulator):
            self.sketch.merge(other.sketch)

    def value(self) -> float:
        if self.reduce is Reduce.P50:
            return self.sketch.query(0.50)
        if self.reduce is Reduce.P99:
            return self.sketch.query(0.99)
        return ScalarAccumulator.value(self)

    def summary(self) -> Dict[str, Any]:
        out = ScalarAccumulator.summary(self)
        out["p50"] = self.sketch.query(0.50)
        out["p99"] = self.sketch.query(0.99)
        return out


class RateAccumulator(ScalarAccumulator):
    """Accumulated value per wall-clock second since the series was
    born (e.g. bytes/s). The reduce must be SUM — the rate is the sum
    divided by elapsed time; folding across ranks takes the earliest
    birth (the window every rank's traffic shares)."""

    kind = "rate"

    def __init__(self, reduce: Reduce = Reduce.SUM,
                 clock: Callable[[], float] = time.monotonic):
        if reduce is not Reduce.SUM:
            raise ValueError("rate metrics reduce as SUM over elapsed time")
        super().__init__(reduce)
        self._clock = clock
        self.start = clock()

    def merge(self, other: "MetricAccumulator") -> None:
        ScalarAccumulator.merge(self, other)
        if isinstance(other, RateAccumulator):
            self.start = min(self.start, other.start)

    @property
    def elapsed_s(self) -> float:
        return max(self._clock() - self.start, 1e-9)

    def value(self) -> float:
        return self.sum / self.elapsed_s

    def summary(self) -> Dict[str, Any]:
        out = ScalarAccumulator.summary(self)
        out["elapsed_s"] = self.elapsed_s
        return out


def make_accumulator(reduce: Reduce, *, rate: bool = False,
                     sketch_capacity: int = 512,
                     clock: Callable[[], float] = time.monotonic,
                     ) -> MetricAccumulator:
    """Route a (reduce, rate) declaration to its accumulator class."""
    if rate:
        return RateAccumulator(reduce, clock=clock)
    if reduce in (Reduce.P50, Reduce.P99):
        return DistributionAccumulator(reduce, sketch_capacity)
    return ScalarAccumulator(reduce)


# ---------------------------------------------------------------------------
# collector
# ---------------------------------------------------------------------------
RankKey = Optional[Tuple[int, int]]


def _rank_str(rank: RankKey) -> str:
    return "global" if rank is None else f"{rank[0]}/{rank[1]}"


class MetricsCollector:
    """Thread-safe metric registry, one per cluster (``cluster.metrics``).

    Recording takes ONLY the collector's own lock — never the clock
    lock — so serving-loop / stripe / prefetch threads flush app-level
    series in without contending accrual. Series are always stored
    per-rank (``rank=(node, worker)``, or the ``global`` rank when
    unranked); :class:`Mode` picks whether ``snapshot()`` renders the
    keyed series (PER_RANK) or only the topology fold (GLOBAL_REDUCE),
    so the two modes agree under reduction by construction.

    ``snapshot()`` additionally bridges every accounting ledger through
    one consistent :meth:`~repro.fanstore.accounting.ClusterAccounting.
    snapshot` copy, plus the cluster's fault counters when a cluster is
    attached. Snapshots are monotonically versioned (the version
    survives :meth:`reset`, so a JSONL stream never repeats one).
    """

    def __init__(self, accounting=None, *, cluster=None,
                 mode: Mode = Mode.GLOBAL_REDUCE,
                 sketch_capacity: int = 512,
                 clock: Callable[[], float] = time.monotonic):
        self.accounting = accounting if accounting is not None else (
            cluster.accounting if cluster is not None else None)
        # weakref: the cluster owns its collector (cluster.metrics), so a
        # strong back-reference would make a cycle and keep an abandoned
        # cluster — and its lazily spawned transport pool threads — alive
        # until the cycle GC runs instead of dying by refcount
        self._cluster = (weakref.ref(cluster)
                         if cluster is not None else None)
        self.mode = Mode(mode)
        self.sketch_capacity = int(sketch_capacity)
        self.clock = clock
        self._lock = threading.Lock()
        self._series: Dict[str, Dict[RankKey, MetricAccumulator]] = {}
        self._decl: Dict[str, Tuple[Reduce, bool]] = {}
        self._blocks: Dict[str, Any] = {}
        self._version = 0

    @property
    def cluster(self):
        """The owning cluster, or None once it has been collected."""
        return self._cluster() if self._cluster is not None else None

    # ---- recording ---------------------------------------------------------
    def record_metric(self, name: str, value: float, *,
                      reduce: Reduce = Reduce.SUM,
                      rank: RankKey = None,
                      rate: bool = False) -> None:
        """Observe one value on the series ``name`` (for ``rank``).

        A name binds to ONE (reduce, rate) declaration for the life of
        the collector; a conflicting re-declaration raises rather than
        silently forking the series.
        """
        reduce = Reduce(reduce)
        if rank is not None:
            rank = (int(rank[0]), int(rank[1]))
        with self._lock:
            decl = self._decl.get(name)
            if decl is None:
                self._decl[name] = (reduce, rate)
            elif decl != (reduce, rate):
                raise ValueError(
                    f"metric {name!r} already declared as "
                    f"(reduce={decl[0].name}, rate={decl[1]}); got "
                    f"(reduce={reduce.name}, rate={rate})")
            ranks = self._series.setdefault(name, {})
            acc = ranks.get(rank)
            if acc is None:
                acc = make_accumulator(
                    reduce, rate=rate,
                    sketch_capacity=self.sketch_capacity, clock=self.clock)
                ranks[rank] = acc
            acc.observe(value)

    def record_block(self, name: str, block: Any) -> None:
        """Attach one structured, JSON-ready benchmark block. Snapshots
        re-emit the blocks verbatim under ``"bench"`` — this is how
        ``benchmarks/run.py`` routes BENCH_io.json through the pipeline
        without changing the emitted schema."""
        with self._lock:
            self._blocks[name] = copy.deepcopy(block)

    def reset(self) -> None:
        """Drop every series and block. The snapshot version is NOT
        reset — it stays monotonic across the collector's life."""
        with self._lock:
            self._series.clear()
            self._decl.clear()
            self._blocks.clear()

    # ---- views -------------------------------------------------------------
    @staticmethod
    def _fold(ranks: Dict[RankKey, MetricAccumulator]) -> MetricAccumulator:
        accs = list(ranks.values())
        folded = accs[0].clone()
        for a in accs[1:]:
            folded.merge(a)
        return folded

    @staticmethod
    def _entry(acc: MetricAccumulator) -> Dict[str, Any]:
        out = {"reduce": acc.reduce.value, "kind": acc.kind,
               "value": acc.value()}
        out.update(acc.summary())
        return out

    def snapshot(self, *, mode: Optional[Mode] = None) -> Dict[str, Any]:
        """One monotonically versioned, JSON-ready view of everything:
        recorded series (folded, plus per-rank under PER_RANK), attached
        bench blocks, and the full accounting-ledger bridge."""
        mode = self.mode if mode is None else Mode(mode)
        # ledgers first (clock lock), then our lock — never nested
        ledgers = (self.accounting.snapshot()
                   if self.accounting is not None else None)
        out: Dict[str, Any] = {"schema": 1, "mode": mode.value}
        with self._lock:
            self._version += 1
            out["version"] = self._version
            metrics: Dict[str, Any] = {}
            for name in sorted(self._series):
                ranks = self._series[name]
                entry = self._entry(self._fold(ranks))
                if mode is Mode.PER_RANK:
                    entry["ranks"] = {
                        _rank_str(r): self._entry(a)
                        for r, a in sorted(
                            ranks.items(),
                            key=lambda kv: _rank_str(kv[0]))}
                metrics[name] = entry
            out["metrics"] = metrics
            if self._blocks:
                out["bench"] = copy.deepcopy(self._blocks)
        if ledgers is not None:
            out["nodes"] = ledgers["nodes"]
            out["cluster"] = ledgers["cluster"]
        cluster = self.cluster     # deref the weakref once
        if cluster is not None:
            out["faults"] = cluster.fault_stats()
        return out

    def rank_view(self, node: int, worker: int) -> Dict[str, Any]:
        """The PER_RANK slice one bound session sees: its own recorded
        series plus its node's lanes and its worker-attributed cache
        counters (``FanStoreSession.metrics()``)."""
        rank = (int(node), int(worker))
        out: Dict[str, Any] = {"rank": _rank_str(rank), "metrics": {}}
        with self._lock:
            for name, ranks in sorted(self._series.items()):
                if rank in ranks:
                    out["metrics"][name] = self._entry(ranks[rank])
        if self.accounting is not None:
            nodes = self.accounting.snapshot()["nodes"]
            nd = nodes.get(rank[0])
            if nd is not None:
                m = nd["modeled"]
                out["node"] = {k: m[k] for k in (
                    "consume_s", "serve_s", "prefetch_s", "write_s",
                    "serve_app_s", "busy_s", "bytes_in", "local_bytes",
                    "cache_hit_rate")}
                out["cache"] = {
                    "hits": m["worker_cache_hits"].get(rank[1], 0),
                    "misses": m["worker_cache_misses"].get(rank[1], 0),
                    "hit_bytes":
                        m["worker_cache_hit_bytes"].get(rank[1], 0)}
        return out

    def flush(self, sink: Optional["JsonlSink"] = None, *,
              mode: Optional[Mode] = None) -> Dict[str, Any]:
        """Take a snapshot and (when a sink is given) append it."""
        snap = self.snapshot(mode=mode)
        if sink is not None:
            sink.emit(snap)
        return snap


# ---------------------------------------------------------------------------
# streaming sink
# ---------------------------------------------------------------------------
class JsonlSink:
    """Append-only JSONL stream of snapshots: one JSON object per line.

    Crash-safe by construction — each :meth:`emit` appends one complete
    line and flushes the OS buffer before returning, so a crash can tear
    at most the line being written, and :meth:`load` tolerates exactly
    that (a torn FINAL line is dropped; a torn middle line is real
    corruption and raises). Size-based rotation renames the live file to
    ``<path>.1``, ``<path>.2``, ... before the append that would
    overflow ``rotate_bytes``.
    """

    def __init__(self, path, *, every_s: Optional[float] = None,
                 rotate_bytes: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.path = str(path)
        self.every_s = every_s
        self.rotate_bytes = rotate_bytes
        self.clock = clock
        self._lock = threading.Lock()
        self._fh = None
        self._last_emit: Optional[float] = None
        self.rotations = 0
        self.records_written = 0

    # -- write side ----------------------------------------------------------
    def _open(self):
        if self._fh is None:
            self._fh = open(self.path, "a", encoding="utf-8")
        return self._fh

    def emit(self, record: Dict[str, Any]) -> None:
        """Append one record now (explicit flush)."""
        line = json.dumps(record, sort_keys=True, default=str) + "\n"
        data = line.encode("utf-8")
        with self._lock:
            fh = self._open()
            if (self.rotate_bytes is not None and fh.tell() > 0
                    and fh.tell() + len(data) > self.rotate_bytes):
                fh.close()
                self._fh = None
                self.rotations += 1
                os.replace(self.path, f"{self.path}.{self.rotations}")
                fh = self._open()
            fh.write(line)
            fh.flush()
            self.records_written += 1
            self._last_emit = self.clock()

    def tick(self, collector: MetricsCollector, *,
             mode: Optional[Mode] = None) -> bool:
        """Periodic flush: emit a snapshot when ``every_s`` has elapsed
        since the last emission (always emits when ``every_s`` is None
        or nothing was emitted yet). Returns whether it emitted."""
        with self._lock:
            due = (self.every_s is None or self._last_emit is None
                   or self.clock() - self._last_emit >= self.every_s)
        if due:
            self.emit(collector.snapshot(mode=mode))
        return due

    def flush(self, collector: MetricsCollector, *,
              mode: Optional[Mode] = None) -> Dict[str, Any]:
        """Explicit flush: emit a snapshot unconditionally."""
        snap = collector.snapshot(mode=mode)
        self.emit(snap)
        return snap

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read side -----------------------------------------------------------
    @staticmethod
    def load(path, *, include_rotated: bool = True) -> List[Dict[str, Any]]:
        """Reload a stream (rotated segments first, oldest to newest).
        A torn trailing line in the LIVE file is dropped; corruption
        anywhere else raises ``ValueError``."""
        path = str(path)
        files: List[str] = []
        if include_rotated:
            k = 1
            while os.path.exists(f"{path}.{k}"):
                files.append(f"{path}.{k}")
                k += 1
        if os.path.exists(path):
            files.append(path)
        records: List[Dict[str, Any]] = []
        for fname in files:
            with open(fname, "r", encoding="utf-8") as fh:
                lines = [ln for ln in fh.read().splitlines() if ln.strip()]
            for i, ln in enumerate(lines):
                try:
                    records.append(json.loads(ln))
                except json.JSONDecodeError:
                    if fname == path and i == len(lines) - 1:
                        break  # torn tail from a crash mid-append
                    raise ValueError(
                        f"corrupt JSONL record in {fname} line {i + 1}")
        return records


# ---------------------------------------------------------------------------
# declarative SLO guards
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Ref:
    """A threshold that is itself a path into the document. Wildcards in
    the ref path consume the metric path's wildcard bindings in order;
    any LEFTOVER ref wildcards expand to a for-all comparison (e.g.
    "belady >= every policy on the same arm")."""
    path: str


@dataclass(frozen=True)
class SloGuard:
    """One declarative threshold check over a snapshot document.

    ``metric`` is a dotted path (``*`` matches every dict value / list
    element); ``op`` one of ``> >= < <= == != truthy nonempty min_len
    subset in``; ``threshold`` a literal or a :class:`Ref`; ``when`` an
    optional ``(path, op, literal)`` gate — when it does not hold, the
    guard is skipped. A metric path that matches NOTHING is itself a
    violation (guards fail loudly on missing data).
    """
    name: str
    metric: str
    op: str
    threshold: Any = None
    when: Optional[Tuple[str, str, Any]] = None


def resolve_path(doc: Any, path: str) -> List[Tuple[Tuple, Any]]:
    """Resolve a dotted path with ``*`` wildcards against nested
    dicts/lists; returns ``[(bindings, value), ...]`` where bindings are
    the keys/indices each ``*`` matched, in order. Dict keys may
    themselves contain dots (metric names like ``train.loss``): at each
    dict the LONGEST joined run of remaining segments that names a key
    wins, so ``metrics.train.loss.value`` finds
    ``doc["metrics"]["train.loss"]["value"]``."""
    parts = path.split(".")
    out: List[Tuple[Tuple, Any]] = []

    def walk(node: Any, i: int, bindings: List) -> None:
        if i == len(parts):
            out.append((tuple(bindings), node))
            return
        p = parts[i]
        if p == "*":
            if isinstance(node, dict):
                for k, v in node.items():
                    walk(v, i + 1, bindings + [k])
            elif isinstance(node, (list, tuple)):
                for j, v in enumerate(node):
                    walk(v, i + 1, bindings + [j])
        elif isinstance(node, dict):
            for j in range(len(parts), i, -1):
                key = ".".join(parts[i:j])
                if key in node:
                    walk(node[key], j, bindings)
                    return
        elif isinstance(node, (list, tuple)):
            try:
                idx = int(p)
            except ValueError:
                return
            if -len(node) <= idx < len(node):
                walk(node[idx], i + 1, bindings)

    walk(doc, 0, [])
    return out


def _substitute(ref_path: str, bindings: Tuple) -> str:
    parts = ref_path.split(".")
    bi = 0
    for i, p in enumerate(parts):
        if p == "*" and bi < len(bindings):
            parts[i] = str(bindings[bi])
            bi += 1
    return ".".join(parts)


def _compare(op: str, value: Any, threshold: Any) -> bool:
    if op == ">":
        return value > threshold
    if op == ">=":
        return value >= threshold
    if op == "<":
        return value < threshold
    if op == "<=":
        return value <= threshold
    if op == "==":
        return value == threshold
    if op == "!=":
        return value != threshold
    if op == "truthy":
        return bool(value)
    if op == "nonempty":
        return len(value) > 0
    if op == "min_len":
        return len(value) >= threshold
    if op == "subset":
        return set(value) <= set(threshold)
    if op == "in":
        return value in threshold
    raise ValueError(f"unknown guard op {op!r}")


def check_slos(doc: Any, guards: Sequence[SloGuard]) -> List[str]:
    """Evaluate every guard against ``doc``; returns violation messages
    (empty == all pass). Multi-match semantics are for-all: every metric
    match must satisfy the op against every resolved threshold."""
    violations: List[str] = []
    for g in guards:
        if g.when is not None:
            wpath, wop, wlit = g.when
            wmatches = resolve_path(doc, wpath)
            if not wmatches:
                violations.append(
                    f"{g.name}: when-path {wpath!r} missing from document")
                continue
            if not all(_compare(wop, v, wlit) for _, v in wmatches):
                continue  # gate not met — guard does not apply
        matches = resolve_path(doc, g.metric)
        if not matches:
            violations.append(
                f"{g.name}: no value at {g.metric!r}")
            continue
        for bindings, value in matches:
            if isinstance(g.threshold, Ref):
                rpath = _substitute(g.threshold.path, bindings)
                refs = [v for _, v in resolve_path(doc, rpath)]
                if not refs:
                    violations.append(
                        f"{g.name}: no threshold value at {rpath!r}")
                    continue
            else:
                refs = [g.threshold]
            for t in refs:
                try:
                    ok = _compare(g.op, value, t)
                except TypeError as e:
                    ok = False
                    violations.append(
                        f"{g.name}: {_substitute(g.metric, bindings)} "
                        f"uncomparable ({e})")
                    continue
                if not ok:
                    where = _substitute(g.metric, bindings) \
                        if bindings else g.metric
                    violations.append(
                        f"{g.name}: {where} = {value!r} violates "
                        f"{g.op} {t!r}")
    return violations
