"""Transport layer: the modeled fabric and the code that moves payloads.

``InterconnectModel`` is the first-order cost model (per-message latency +
per-byte cost) the simulated cluster accounts against; it used to live in
:mod:`repro.fanstore.cluster` and is re-exported there for compatibility.

``Transport`` is the seam every byte crosses. It knows nothing about
placement or metadata — callers hand it resolved (path, owner, sizes)
tuples and it (a) performs the actual payload movement against the
``NodeStore`` instances and (b) accrues the modeled cost onto the right
``NodeClock``. Two shapes:

* ``fetch_local`` / ``fetch_remote`` — the per-file round trips the paper's
  synchronous client issues (one ``latency_s`` per file).
* ``fetch_remote_batch`` — the batched path: all requests for one
  (requester, owner) pair ride a single round trip, so a batch of K files
  from one owner accrues exactly one ``latency_s`` plus the summed byte
  cost. This is what makes small-file workloads latency-bound -> bandwidth-
  bound (Clairvoyant-prefetching-style request coalescing).
* ``fetch_window`` / ``prefetch_local`` — the scheduled-prefetch lane used
  by :mod:`repro.fanstore.prefetch`: one round trip per (requester, owner,
  lookahead window) spanning many batches, accounted on the concurrent
  ``NodeClock.prefetch_s`` timeline so makespan models I/O hidden behind
  compute.
* ``put_local`` / ``put_remote_batch`` — the write half, symmetric with the
  read half: output payload chunks ship TO the placement owner (batched:
  one round trip per (writer, owner) group), accounted on the concurrent
  ``NodeClock.write_s`` lane so checkpoint flushes overlap the prefetch and
  demand timelines instead of serializing in front of them. The legacy
  ``write_file`` path books the same movement onto ``consume_s``.

``submit``/``fetch_batch_async`` run any fetch on a shared thread pool and
return a ``concurrent.futures.Future`` so data pipelines can overlap the
next batch's I/O with compute without threading code of their own.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.fanstore.accounting import NodeClock, WindowAccount
from repro.fanstore.store import NodeStore


@dataclass
class InterconnectModel:
    """First-order fabric model: per-message latency + per-byte cost.

    Defaults approximate the paper's CPU cluster (100 Gb/s OPA, ~1.5 us):
    latency_s per round trip, bandwidth_Bps per NIC direction. Local tier
    is modeled with disk_bw_Bps (SSD) and a per-open syscall overhead.
    cache_bw_Bps is the client-side read-cache (RAM) service rate.
    """
    latency_s: float = 1.5e-6
    bandwidth_Bps: float = 100e9 / 8
    disk_bw_Bps: float = 2.0e9
    open_overhead_s: float = 3e-6
    decompress_Bps: float = 1.5e9     # LZSS-class decode rate per core
    cache_bw_Bps: float = 20e9        # DRAM-resident read cache

    def remote_cost(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps

    def local_cost(self, nbytes: int, *, compressed: bool = False) -> float:
        t = self.open_overhead_s + nbytes / self.disk_bw_Bps
        if compressed:
            t += nbytes / self.decompress_Bps
        return t

    def cache_cost(self, nbytes: int) -> float:
        return nbytes / self.cache_bw_Bps


@dataclass(frozen=True)
class FetchItem:
    """One resolved read request: path + the sizes the cost model needs."""
    path: str
    size: int             # decompressed (st_size) bytes
    stored: int           # bytes on the wire (compressed size if packed)
    compressed: bool = False


class Transport:
    """Moves payloads between node stores and accounts the modeled cost."""

    def __init__(self, net: InterconnectModel, nodes: Dict[int, NodeStore],
                 clocks: Dict[int, NodeClock], *, num_threads: int = 8):
        self.net = net
        self.nodes = nodes
        self.clocks = clocks
        self._lock = threading.Lock()     # clock accrual from pool threads
        self._pool: Optional[ThreadPoolExecutor] = None
        self._num_threads = num_threads

    # ---- local tier --------------------------------------------------------
    def fetch_local(self, node_id: int, item: FetchItem, *,
                    materialize: bool = True) -> bytes:
        """Read a file the requesting node already holds (SSD tier)."""
        node = self.nodes[node_id]
        if materialize:
            data = node.open_local(item.path)
            node.release(item.path)
        else:
            data = b""
        with self._lock:
            clock = self.clocks[node_id]
            clock.consume_s += self.net.local_cost(item.size,
                                                   compressed=item.compressed)
            clock.local_bytes += item.size
        return data

    # ---- remote tier -------------------------------------------------------
    def fetch_remote(self, requester: int, owner: int, item: FetchItem, *,
                     materialize: bool = True) -> bytes:
        """One synchronous round trip: one ``latency_s`` for one file."""
        data = self.nodes[owner].serve_remote(item.path) if materialize else b""
        with self._lock:
            self._account_remote(requester, owner, [item])
        return data

    def fetch_remote_batch(self, requester: int, owner: int,
                           items: Sequence[FetchItem], *,
                           materialize: bool = True) -> List[bytes]:
        """Coalesced fetch: K files from one owner, ONE round-trip latency.

        The requester pays ``latency_s`` once for the whole group and the
        owner pays one request-handling ``open_overhead_s`` (one message,
        one scatter-gather over its already-open partition blobs); per-byte
        costs are unchanged. See ``_account_remote`` for the exact model.
        """
        if not items:
            return []
        if materialize:
            out = [self.nodes[owner].serve_remote(it.path) for it in items]
        else:
            out = [b"" for _ in items]
        with self._lock:
            self._account_remote(requester, owner, items, round_trips=1)
        return out

    def fetch_window(self, requester: int, owner: int,
                     items: Sequence[FetchItem], *,
                     materialize: bool = True) -> List[bytes]:
        """Scheduled-prefetch fetch: one round trip for a whole lookahead
        WINDOW of files from one owner — the window may span many training
        batches, so the per-owner latency is amortized far beyond per-batch
        coalescing.

        Cost accrues on the requester's *prefetch lane*
        (``NodeClock.prefetch_s``), not ``consume_s``: the scheduler runs on
        the transport pool concurrently with demand reads, so makespan
        (``busy_s = max(consume, serve, prefetch)``) models the overlap
        instead of serializing prefetch behind consumption. Each call appends
        a :class:`WindowAccount` entry to the requester's per-window ledger.
        The owner's serve side is accounted identically to
        ``fetch_remote_batch`` (it answers one message either way).
        """
        if not items:
            return []
        if materialize:
            out = [self.nodes[owner].serve_remote(it.path) for it in items]
        else:
            out = [b"" for _ in items]
        with self._lock:
            self._account_remote(requester, owner, items, round_trips=1,
                                 lane="prefetch")
        return out

    def prefetch_local(self, node_id: int, items: Sequence[FetchItem], *,
                       materialize: bool = True) -> List[bytes]:
        """Stage node-local files (SSD tier) into the client cache ahead of
        demand; costs accrue on the prefetch lane so the disk reads overlap
        the consume timeline."""
        node = self.nodes[node_id]
        out: List[bytes] = []
        total = 0
        cost = 0.0
        for it in items:
            if materialize:
                data = node.open_local(it.path)
                node.release(it.path)
            else:
                data = b""
            out.append(data)
            total += it.size
            cost += self.net.local_cost(it.size, compressed=it.compressed)
        with self._lock:
            clock = self.clocks[node_id]
            clock.prefetch_s += cost
            clock.prefetch_bytes += total    # sole ledger for staged bytes
        return out

    def _account_remote(self, requester: int, owner: int,
                        items: Sequence[FetchItem], *,
                        round_trips: Optional[int] = None,
                        lane: str = "consume") -> None:
        """Accrue modeled cost; ``round_trips`` defaults to one per item.

        With ``round_trips=1`` (batched) the requester pays one ``latency_s``
        for the whole group and the owner pays one request-handling
        ``open_overhead_s``: the server answers a single message with one
        scatter-gather over its already-open partition blobs instead of K
        per-request handlings. Byte costs (NIC both sides, server storage
        read, client decompress) are per-byte and unchanged.

        ``lane="prefetch"`` books the requester side onto the concurrent
        prefetch timeline (``prefetch_s`` + per-window ledger) instead of
        ``consume_s``; the owner's serve side is lane-independent.
        """
        trips = len(items) if round_trips is None else round_trips
        stored = sum(it.stored for it in items)
        clock = self.clocks[requester]
        cost = trips * self.net.latency_s + stored / self.net.bandwidth_Bps
        for it in items:
            if it.compressed:
                cost += it.size / self.net.decompress_Bps
        if lane == "prefetch":
            clock.prefetch_s += cost
            clock.prefetch_bytes += stored
            clock.prefetch_windows += trips
            clock.prefetch_log.append(WindowAccount(
                owner=owner, files=len(items), bytes=stored, cost_s=cost))
        else:
            clock.consume_s += cost
            clock.bytes_in += stored
        oc = self.clocks[owner]
        oc.serve_s += trips * self.net.open_overhead_s
        oc.serve_s += stored / self.net.disk_bw_Bps
        oc.serve_s += stored / self.net.bandwidth_Bps
        oc.bytes_out += stored

    # ---- write path (output payloads ship TO the placement owner) ----------
    def put_local(self, node_id: int, pairs: Sequence[Tuple[FetchItem, bytes]],
                  *, lane: str = "write") -> None:
        """Persist output chunks on the writer's own store (writer == owner):
        per-chunk SSD-tier flush cost on the writer's chosen lane."""
        node = self.nodes[node_id]
        total = 0
        cost = 0.0
        for item, data in pairs:
            node.stage_output(node_id, item.path, data)
            total += item.size
            cost += self.net.open_overhead_s + item.size / self.net.disk_bw_Bps
        with self._lock:
            self._accrue_write(node_id, cost, total, len(pairs), lane)

    def put_remote_batch(self, writer: int, owner: int,
                         pairs: Sequence[Tuple[FetchItem, bytes]], *,
                         lane: str = "write",
                         round_trips: Optional[int] = None) -> None:
        """Ship output chunks to the placement owner. With ``round_trips=1``
        (the batched ``write_many`` fan-in) K chunks for one owner ride ONE
        message: the writer pays ``latency_s`` once on its lane and the
        owner handles one request (one ``open_overhead_s``) before the
        per-byte NIC + SSD-flush costs — the exact mirror of
        ``fetch_remote_batch`` on the read side. The carried metadata
        publish rides the same message (no separate forward)."""
        if not pairs:
            return
        node = self.nodes[owner]
        for item, data in pairs:
            node.stage_output(writer, item.path, data)
        trips = len(pairs) if round_trips is None else round_trips
        stored = sum(item.size for item, _ in pairs)
        with self._lock:
            cost = trips * self.net.latency_s + stored / self.net.bandwidth_Bps
            self._accrue_write(writer, cost, stored, trips, lane)
            oc = self.clocks[owner]
            oc.serve_s += trips * self.net.open_overhead_s
            oc.serve_s += stored / self.net.bandwidth_Bps
            oc.serve_s += stored / self.net.disk_bw_Bps

    def _accrue_write(self, node_id: int, cost: float, nbytes: int,
                      rpcs: int, lane: str) -> None:
        """Book writer-side cost: ``lane="write"`` is the concurrent write
        timeline (overlaps consume/prefetch in ``busy_s``); ``"consume"``
        is the legacy serialized path ``write_file``/``commit_write`` keeps."""
        clock = self.clocks[node_id]
        if lane == "write":
            clock.write_s += cost
            clock.write_bytes += nbytes
            clock.write_rpcs += rpcs
        else:
            clock.consume_s += cost

    # ---- cache tier (accounting only; payload comes from the cache) --------
    def account_cache_hit(self, node_id: int, item: FetchItem) -> None:
        with self._lock:
            clock = self.clocks[node_id]
            clock.consume_s += self.net.cache_cost(item.size)
            clock.cache_hits += 1
            clock.cache_hit_bytes += item.size

    def account_cache_miss(self, node_id: int) -> None:
        with self._lock:
            self.clocks[node_id].cache_misses += 1

    def account_cache_eviction(self, node_id: int, count: int = 1) -> None:
        with self._lock:
            self.clocks[node_id].cache_evictions += count

    # ---- async future API --------------------------------------------------
    @property
    def pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._num_threads,
                thread_name_prefix="fanstore-io")
        return self._pool

    def submit(self, fn: Callable, *args, **kwargs) -> Future:
        """Run any fetch callable on the shared I/O pool; returns a Future."""
        return self.pool.submit(fn, *args, **kwargs)

    def fetch_remote_batch_async(self, requester: int, owner: int,
                                 items: Sequence[FetchItem], *,
                                 materialize: bool = True) -> Future:
        return self.submit(self.fetch_remote_batch, requester, owner, items,
                           materialize=materialize)

    def fetch_window_async(self, requester: int, owner: int,
                           items: Sequence[FetchItem], *,
                           materialize: bool = True) -> Future:
        return self.submit(self.fetch_window, requester, owner, items,
                           materialize=materialize)

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
