"""Compatibility shim: the transport layer moved behind a backend seam.

The PR-1 ``Transport`` (modeled fabric accounting + in-process payload
movement) is now one of several interchangeable wires:

* :mod:`repro.fanstore.wire` — the framed message protocol and the
  :class:`FetchItem` request descriptor;
* :mod:`repro.fanstore.backends` — the backend package:
  ``ModeledBackend`` (this module's old behavior, byte-for-byte),
  ``SocketBackend`` (real TCP serving loops), ``SharedMemoryBackend``
  (zero-copy co-located fast path), selected with
  ``FanStoreCluster(backend=...)``.

Old imports keep working: ``Transport`` is the modeled backend,
``InterconnectModel`` and ``FetchItem`` re-export from their new homes.
"""
from __future__ import annotations

from repro.fanstore.backends.base import TransportBackend
from repro.fanstore.backends.modeled import InterconnectModel, ModeledBackend
from repro.fanstore.wire import FetchItem

# the pre-seam name: per-file + batched + window fetches, thread-pool
# futures, modeled clocks — exactly what ModeledBackend preserves
Transport = ModeledBackend

__all__ = ["FetchItem", "InterconnectModel", "Transport", "TransportBackend"]
