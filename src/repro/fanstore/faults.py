"""Deterministic fault injection and failure classification.

The fault-tolerance layer has three pieces, and this module is the first
two of them:

* **Injection** — :class:`FaultInjector` sits on the transport seam
  (``TransportBackend._timed_fetch`` / ``put_remote_batch`` call
  :meth:`FaultInjector.check` before any bytes move) and deterministically
  raises/delays a policy-chosen fraction of operations. Everything is
  driven by a seeded RNG plus a monotone operation counter, so a given
  ``FaultPolicy`` produces the *same* fault sequence on every run — the
  property the failover tests and the ``failover`` BENCH block rely on.

* **Classification** — :func:`is_transport_failure` is the single
  predicate the failover read path uses to decide "retry on another
  replica" vs "re-raise": transport failures (socket resets, timeouts,
  ERR frames, injected faults) are retryable; anything else (a genuine
  ``FileNotFoundError``, a programming error) is not.

The third piece — the retry/strike/churn machinery — lives in
``cluster.py`` (``read_many`` failover loops, ``mark_failed`` /
``mark_joined``) and ``train/elastic.py`` (re-replication).
"""
from __future__ import annotations

import random
import threading
from typing import TYPE_CHECKING, Iterable, Optional, Tuple

from . import wire

if TYPE_CHECKING:   # pragma: no cover
    from .spec import FaultPolicy


class InjectedFault(ConnectionError):
    """A policy-injected transport failure (dropped fetch or killed node).

    Subclasses ``ConnectionError`` so the failover classifier treats it
    exactly like a real dead peer — the read path cannot (and must not)
    tell the difference.
    """


class InjectedError(wire.WireError):
    """A policy-injected server-side error (the ERR-frame failure mode)."""


class NodeLostError(IOError):
    """Data is unreachable: every replica of the named partitions is on a
    failed node. Raised by the failover read path when it runs out of
    live owners — the classified, actionable alternative to hanging.

    Attributes:
        partitions: sorted partition ids with no live replica.
        paths: the requested paths that became unreachable.
    """

    def __init__(self, msg: str, *, partitions: Tuple[int, ...] = (),
                 paths: Tuple[str, ...] = ()) -> None:
        super().__init__(msg)
        self.partitions = tuple(partitions)
        self.paths = tuple(paths)

    @classmethod
    def for_items(cls, lost: Iterable[Tuple[str, int]]) -> "NodeLostError":
        """Build from ``(path, partition_id)`` pairs of unreachable reads."""
        lost = list(lost)
        parts = tuple(sorted({pid for _, pid in lost}))
        paths = tuple(sorted({p for p, _ in lost}))
        head = ", ".join(str(p) for p in parts[:8])
        more = f" (+{len(parts) - 8} more)" if len(parts) > 8 else ""
        return cls(
            f"all replicas failed for partition(s) {head}{more}: "
            f"{len(paths)} path(s) unreachable",
            partitions=parts, paths=paths)


# a server that raises NodeLostError while re-serving must round-trip it
# through the ERR frame instead of degrading to bare IOError
wire._EXC_TYPES.setdefault("NodeLostError", NodeLostError)

#: exception classes the failover loop treats as "this owner is unhealthy,
#: retry elsewhere". TimeoutError covers socket timeouts (it is an OSError
#: subclass but classified explicitly for clarity); WireError covers
#: protocol damage and ERR frames raised by a sick server.
_RETRYABLE = (ConnectionError, TimeoutError, wire.WireError)


def is_transport_failure(exc: BaseException) -> bool:
    """True when ``exc`` means the *owner* (not the request) failed and the
    same read may succeed against another replica."""
    if isinstance(exc, NodeLostError):
        return False          # already the terminal classification
    return isinstance(exc, _RETRYABLE)


class FaultInjector:
    """Deterministic fault source driven by a :class:`FaultPolicy`.

    One injector per cluster, shared by all transport verbs. All state
    updates happen under a lock; the decision for operation *k* depends
    only on (seed, k, requester, owner, verb), so a fixed policy yields a
    reproducible fault schedule regardless of thread interleaving **when
    the operation order is deterministic** (the modeled backend; real
    wires get a reproducible *rate* rather than a reproducible schedule).

    Counters (all monotone, read via :meth:`stats`):
        ops        operations checked
        injected   faults raised (drops + kills + errors)
        dropped / errored / delayed   per-mode breakdown
        killed     True once the kill-node trigger has fired
    """

    def __init__(self, policy: "FaultPolicy") -> None:
        self.policy = policy
        self._rng = random.Random(policy.seed)
        self._lock = threading.Lock()
        self._ops = 0
        self._step = -1
        self.injected = 0
        self.dropped = 0
        self.errored = 0
        self.delayed = 0
        self.killed = False

    # ---- lifecycle ---------------------------------------------------------
    def on_step(self, step: int) -> None:
        """Advance the training-step clock (drives ``kill_at_step``)."""
        with self._lock:
            if step > self._step:
                self._step = step

    def stats(self) -> dict:
        with self._lock:
            return {"ops": self._ops, "injected": self.injected,
                    "dropped": self.dropped, "errored": self.errored,
                    "delayed": self.delayed, "killed": self.killed,
                    "step": self._step}

    # ---- the seam ----------------------------------------------------------
    def _applies(self, owner: int, verb: str) -> bool:
        p = self.policy
        if p.owners is not None and owner not in p.owners:
            return False
        if p.verbs is not None:
            return verb in p.verbs
        # default scope: data-plane fetches; writes only when asked for
        return verb != "put"

    def check(self, requester: int, owner: int, verb: str) -> float:
        """Decide the fate of one transport operation.

        Raises :class:`InjectedFault` (kill / drop) or
        :class:`InjectedError` (server-side error), or returns a delay in
        seconds (0.0 almost always) the backend must account as injected
        latency. Called with the requester/owner/verb of every movement.
        """
        p = self.policy
        with self._lock:
            self._ops += 1
            ops = self._ops
            # the kill trigger: once fired, EVERY op against the dead
            # owner fails until the membership layer routes around it
            if p.kill_node is not None and not self.killed:
                fire = ((p.kill_at_op is not None and ops >= p.kill_at_op)
                        or (p.kill_at_step is not None
                            and self._step >= p.kill_at_step))
                if fire:
                    self.killed = True
            if self.killed and owner == p.kill_node:
                self.injected += 1
                self.dropped += 1
                raise InjectedFault(
                    f"injected: node {owner} is dead "
                    f"(killed at op {ops}, step {self._step})")
            if not self._applies(owner, verb):
                return 0.0
            draw = self._rng.random()
            if draw < p.drop_fraction:
                self.injected += 1
                self.dropped += 1
                raise InjectedFault(
                    f"injected drop: {verb} {requester}->{owner} (op {ops})")
            draw -= p.drop_fraction
            if draw < p.error_fraction:
                self.injected += 1
                self.errored += 1
                raise InjectedError(
                    f"injected error: {verb} {requester}->{owner} (op {ops})")
            draw -= p.error_fraction
            if draw < p.delay_fraction:
                self.delayed += 1
                return p.delay_s
        return 0.0
