"""Topology-first runtime configuration: the :class:`ClusterSpec`.

FanStore's deployment shape (paper §3: N compute nodes, each running
*several* training workers against one global namespace) used to be
smeared across a kwargs soup on ``FanStoreCluster(...)`` plus raw ints
threaded through every verb. ``ClusterSpec`` is that shape as a value:

* **frozen** — a spec never mutates; derive variants with :meth:`replace`;
* **validated** — every registry-backed choice (backend, cache policy,
  placement, selector, codec) is checked at CONSTRUCTION time with a
  ``ValueError`` naming the valid choices, instead of failing late and
  cryptically deep in a registry lookup;
* **serializable** — :meth:`to_json`/:meth:`from_json` round-trip is
  identity, so a spawned worker process can rebuild the exact topology
  from a string and attach to the owner's shared-memory segments (see
  ``repro.fanstore.backends.shm.attach_and_digest``).

``FanStoreCluster.from_spec(spec)`` is the canonical constructor; the
legacy ``FanStoreCluster(num_nodes, **kwargs)`` shim builds a spec
internally and raises on unknown kwarg names with did-you-mean
suggestions. ``cluster.connect(node_id, worker_id)`` then hands out
per-worker sessions — topology in, sessions out, no threaded ints.
"""
from __future__ import annotations

import difflib
import json
from dataclasses import asdict, dataclass, field, fields, replace
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.fanstore.backends import BACKENDS
from repro.fanstore.backends.modeled import InterconnectModel
from repro.fanstore.cache import CACHE_POLICIES
from repro.fanstore.layout import _CODECS
from repro.fanstore.placement import (PLACEMENTS, SELECTORS, make_placement,
                                      make_selector)
from repro.fanstore.wire import WIRE_CODECS

__all__ = ["ClusterSpec", "FaultPolicy", "WorkerContext", "CACHE_SCOPES",
           "suggest_names"]

#: how one node's byte budget is carved up across its co-located workers:
#: ``"node"`` is ONE shared cache tier (Hoard-style — a payload fetched by
#: any worker serves them all), ``"worker"`` is private per-worker splits
#: of the same total budget (the baseline the shared tier beats).
CACHE_SCOPES = ("node", "worker")


def suggest_names(name: str, known, *, kind: str = "argument") -> str:
    """'unknown X; did you mean Y?' message body for a bad name."""
    close = difflib.get_close_matches(name, list(known), n=3, cutoff=0.5)
    hint = f"; did you mean {' or '.join(map(repr, close))}?" if close else ""
    return (f"unknown {kind} {name!r}{hint} "
            f"(known: {', '.join(sorted(known))})")


def _check_choice(value: str, known, *, kind: str) -> None:
    if value not in known:
        raise ValueError(suggest_names(value, known, kind=kind))


@dataclass(frozen=True)
class WorkerContext:
    """One worker's coordinates in the declared topology. Sessions are
    bound to one of these instead of carrying a raw ``node_id`` int —
    co-located workers (same node, different ``worker_id``) share that
    node's cache tier, and cache hits/misses are attributed per worker."""
    node_id: int
    worker_id: int = 0

    def __post_init__(self) -> None:
        if self.node_id < 0:
            raise ValueError("node_id must be >= 0")
        if self.worker_id < 0:
            raise ValueError("worker_id must be >= 0")

    @property
    def key(self) -> Tuple[int, int]:
        """The (node, worker) requester key schedules are axed on."""
        return (self.node_id, self.worker_id)


@dataclass(frozen=True)
class FaultPolicy:
    """Deterministic fault-injection knobs (the ``faults`` spec field).

    All randomness is drawn from ``random.Random(seed)`` inside one
    :class:`repro.fanstore.faults.FaultInjector`, so a fixed policy yields
    a reproducible fault sequence on the modeled backend (and a
    reproducible fault *rate* on real wires, where thread interleaving
    reorders operations).

    Failure modes, applied per transport operation in this order:

    * ``kill_node`` + (``kill_at_step`` | ``kill_at_op``) — once the
      trigger fires, EVERY operation against ``kill_node`` raises
      ``InjectedFault`` until the membership layer routes around it: the
      crashed-peer scenario end to end.
    * ``drop_fraction`` — probability an op raises ``InjectedFault``
      (a vanished connection: retryable on another replica).
    * ``error_fraction`` — probability an op raises ``InjectedError``
      (a server-side ERR frame: also retryable).
    * ``delay_fraction`` / ``delay_s`` — probability an op is delayed by
      ``delay_s`` (a straggler: accounted, never failed).

    ``owners``/``verbs`` scope injection to specific owner node ids or
    transport verbs (``fetch_remote``, ``fetch_remote_batch``,
    ``fetch_window``, ``put``...). By default every fetch verb is in
    scope and writes are exempt (set ``verbs=("put",)`` to fault the
    write path). The kill trigger ignores scoping — a dead node is dead
    for every verb.
    """
    seed: int = 0
    drop_fraction: float = 0.0
    error_fraction: float = 0.0
    delay_fraction: float = 0.0
    delay_s: float = 0.0
    kill_node: Optional[int] = None
    kill_at_step: Optional[int] = None
    kill_at_op: Optional[int] = None
    owners: Optional[Tuple[int, ...]] = None
    verbs: Optional[Tuple[str, ...]] = None

    def __post_init__(self) -> None:
        for name in ("drop_fraction", "error_fraction", "delay_fraction"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        total = self.drop_fraction + self.error_fraction + self.delay_fraction
        if total > 1.0:
            raise ValueError(
                f"drop+error+delay fractions must sum to <= 1, got {total}")
        if self.delay_s < 0.0:
            raise ValueError("delay_s must be >= 0")
        if self.kill_node is not None and self.kill_at_step is None \
                and self.kill_at_op is None:
            raise ValueError(
                "kill_node needs a trigger: set kill_at_step or kill_at_op")
        for name in ("owners", "verbs"):
            v = getattr(self, name)
            if v is not None:
                object.__setattr__(self, name, tuple(v))


@dataclass(frozen=True)
class ClusterSpec:
    """The whole deployment as one frozen, validated, serializable value.

    Every field is JSON-representable by construction; custom placement /
    selector / interconnect OBJECTS stay possible through the override
    kwargs of ``FanStoreCluster.from_spec`` (they are deployment-local and
    deliberately outside the serializable surface).
    """
    num_nodes: int
    workers_per_node: int = 1
    codec: str = "lzss"
    backend: str = "modeled"
    backend_options: Mapping[str, Any] = field(default_factory=dict)
    cache_policy: str = "lru"
    cache_bytes: int = 0              # per-NODE tier budget (all workers)
    cache_scope: str = "node"         # "node" shared tier | "worker" private
    # per-policy constructor knobs forwarded to make_cache (e.g.
    # {"kin": 0.25, "kout": 2.0} for 2Q, {"alpha": 0.3} for the
    # predictor, {"aging_interval": 1024} for LFU) — validated at build
    # time by the policy constructor itself
    cache_policy_options: Mapping[str, Any] = field(default_factory=dict)
    placement: str = "modulo"
    selector: str = "least-loaded"
    replication: int = 1
    io_threads: int = 8
    interconnect: Optional[Mapping[str, float]] = None
    # wire tuning (plumbed to every backend; connection-oriented wires
    # consult stripes, all wires validate the codec at build time)
    wire_stripes: int = 4
    wire_codec: str = "none"
    # fault tolerance: `faults` is a FaultPolicy as a mapping (kept
    # JSON-representable like every other field); the retry knobs bound
    # the failover read path's capped exponential backoff, and
    # fault_threshold is the consecutive-strike count after which an
    # owner is marked failed cluster-wide
    faults: Optional[Mapping[str, Any]] = None
    fault_threshold: int = 3
    retry_backoff_s: float = 1e-4
    retry_backoff_cap_s: float = 2e-3
    # serving plane (repro.fanstore.serving): per-node admission gate +
    # deficit-round-robin fairness + hot-shard promotion defaults.
    # max_inflight_bytes=0 disables the gate (unbounded admission);
    # hot_shard_threshold=0 disables popularity-driven promotion.
    max_inflight_bytes: int = 0
    serve_queue_depth: int = 1024
    serve_quantum_bytes: int = 1 << 20
    hot_shard_threshold: int = 0
    hot_shard_replication: int = 2

    def __post_init__(self) -> None:
        if not isinstance(self.num_nodes, int) or self.num_nodes < 1:
            raise ValueError("num_nodes must be an int >= 1")
        if not isinstance(self.workers_per_node, int) \
                or self.workers_per_node < 1:
            raise ValueError("workers_per_node must be an int >= 1")
        if self.cache_bytes < 0:
            raise ValueError("cache_bytes must be >= 0")
        if self.io_threads < 1:
            raise ValueError("io_threads must be >= 1")
        if not 1 <= self.replication <= self.num_nodes:
            raise ValueError(
                f"replication must be in [1, num_nodes={self.num_nodes}], "
                f"got {self.replication}")
        # registry-backed names fail HERE, not deep in a registry lookup
        _check_choice(self.codec, _CODECS, kind="codec")
        _check_choice(self.backend, BACKENDS, kind="transport backend")
        _check_choice(self.cache_policy, CACHE_POLICIES, kind="cache policy")
        _check_choice(self.cache_scope, CACHE_SCOPES, kind="cache scope")
        _check_choice(self.placement, PLACEMENTS, kind="placement")
        _check_choice(self.selector, SELECTORS, kind="selector")
        if not isinstance(self.wire_stripes, int) or self.wire_stripes < 1:
            raise ValueError("wire_stripes must be an int >= 1")
        _check_choice(self.wire_codec, WIRE_CODECS, kind="wire codec")
        if self.fault_threshold < 1:
            raise ValueError("fault_threshold must be >= 1")
        if self.retry_backoff_s < 0 or self.retry_backoff_cap_s < 0:
            raise ValueError(
                "retry_backoff_s / retry_backoff_cap_s must be >= 0")
        if self.max_inflight_bytes < 0:
            raise ValueError("max_inflight_bytes must be >= 0 (0 = no gate)")
        if self.serve_queue_depth < 1:
            raise ValueError("serve_queue_depth must be >= 1")
        if self.serve_quantum_bytes < 1:
            raise ValueError("serve_quantum_bytes must be >= 1")
        if self.hot_shard_threshold < 0:
            raise ValueError(
                "hot_shard_threshold must be >= 0 (0 = no promotion)")
        if self.hot_shard_replication < 1:
            raise ValueError("hot_shard_replication must be >= 1")
        if self.hot_shard_threshold > 0 \
                and self.hot_shard_replication > self.num_nodes:
            raise ValueError(
                f"hot_shard_replication must be <= num_nodes="
                f"{self.num_nodes} when promotion is enabled, "
                f"got {self.hot_shard_replication}")
        if self.faults is not None:
            known = {f.name for f in fields(FaultPolicy)}
            pol = dict(self.faults)
            for k in pol:
                if k not in known:
                    raise ValueError(
                        suggest_names(k, known, kind="FaultPolicy field"))
            FaultPolicy(**pol)      # validate values now, fail at build time
            object.__setattr__(self, "faults", pol)
        object.__setattr__(self, "backend_options",
                           dict(self.backend_options or {}))
        opts = dict(self.cache_policy_options or {})
        if opts:
            from repro.fanstore.cache import make_cache
            try:
                # build a throwaway 1-byte cache: unknown knob names and
                # out-of-range values fail HERE, at spec build time
                make_cache(self.cache_policy, 1, **opts)
            except TypeError:
                raise ValueError(
                    f"cache_policy_options {sorted(opts)} not accepted by "
                    f"cache policy {self.cache_policy!r}") from None
        object.__setattr__(self, "cache_policy_options", opts)
        if self.interconnect is not None:
            known = {f.name for f in fields(InterconnectModel)}
            net = dict(self.interconnect)
            for k in net:
                if k not in known:
                    raise ValueError(
                        suggest_names(k, known, kind="interconnect field"))
            object.__setattr__(self, "interconnect", net)

    # ---- derived views -----------------------------------------------------
    @property
    def total_workers(self) -> int:
        return self.num_nodes * self.workers_per_node

    def workers(self) -> Tuple[WorkerContext, ...]:
        """Every (node, worker) coordinate in the topology, node-major —
        the canonical requester order schedules and drivers slice by."""
        return tuple(WorkerContext(n, w)
                     for n in range(self.num_nodes)
                     for w in range(self.workers_per_node))

    def worker_cache_bytes(self) -> int:
        """Per-worker budget under ``cache_scope="worker"``: the node
        budget split evenly — same TOTAL bytes as the shared tier, so the
        two scopes compare like-for-like."""
        return self.cache_bytes // self.workers_per_node

    # ---- factories for the non-serializable runtime objects ---------------
    def make_interconnect(self) -> InterconnectModel:
        return InterconnectModel(**(self.interconnect or {}))

    def make_placement(self):
        return make_placement(self.placement, self.num_nodes)

    def make_selector(self):
        return make_selector(self.selector)

    def make_fault_policy(self) -> Optional[FaultPolicy]:
        """The ``faults`` mapping as a validated :class:`FaultPolicy`
        (None when no injection is configured)."""
        if self.faults is None:
            return None
        return FaultPolicy(**dict(self.faults))

    # ---- serialization (round-trip is identity; pinned in tests) -----------
    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ClusterSpec":
        known = {f.name for f in fields(cls)}
        for k in d:
            if k not in known:
                raise ValueError(
                    suggest_names(k, known, kind="ClusterSpec field"))
        return cls(**dict(d))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ClusterSpec":
        return cls.from_dict(json.loads(s))

    def replace(self, **changes) -> "ClusterSpec":
        """Derive a variant spec (re-validated on construction)."""
        return replace(self, **changes)

    # ---- the legacy-kwargs shim --------------------------------------------
    #: legacy FanStoreCluster kwarg -> spec field (identity unless renamed)
    LEGACY_KWARGS = ("codec", "backend", "backend_options", "cache_policy",
                     "cache_bytes", "cache_scope", "cache_policy_options",
                     "workers_per_node",
                     "placement", "selector", "replication", "io_threads",
                     "interconnect", "wire_stripes", "wire_codec",
                     "faults", "fault_threshold", "retry_backoff_s",
                     "retry_backoff_cap_s", "max_inflight_bytes",
                     "serve_queue_depth", "serve_quantum_bytes",
                     "hot_shard_threshold", "hot_shard_replication")

    @classmethod
    def from_kwargs(cls, num_nodes: int, **kwargs) -> "ClusterSpec":
        """Build a spec from the deprecated ``FanStoreCluster(...)`` kwarg
        surface. Unknown names raise ``TypeError`` with did-you-mean
        suggestions instead of being silently swallowed; placement /
        selector / interconnect OBJECTS are captured by name (and, for the
        interconnect, by field values) when possible.
        """
        unknown = [k for k in kwargs if k not in cls.LEGACY_KWARGS]
        if unknown:
            raise TypeError(suggest_names(
                unknown[0], cls.LEGACY_KWARGS,
                kind="FanStoreCluster argument"))
        # None means "not given" on the legacy surface: fall to spec default
        spec_kwargs: Dict[str, Any] = {
            k: v for k, v in kwargs.items() if v is not None}
        net = spec_kwargs.pop("interconnect", None)
        if isinstance(net, InterconnectModel):
            net = asdict(net)
        if isinstance(spec_kwargs.get("faults"), FaultPolicy):
            spec_kwargs["faults"] = asdict(spec_kwargs["faults"])
        if net is not None:
            spec_kwargs["interconnect"] = dict(net)
        for name, registry_default in (("placement", "modulo"),
                                       ("selector", "least-loaded")):
            obj = spec_kwargs.get(name)
            if obj is not None and not isinstance(obj, str):
                # an object: record its registry name when we know it, so
                # the spec stays an honest description; custom objects
                # fall back to the default name (the object itself still
                # drives the cluster via the from_spec override path)
                spec_kwargs[name] = _registry_name(name, obj,
                                                   registry_default)
        return cls(num_nodes=num_nodes, **spec_kwargs)


def _registry_name(kind: str, obj, default: str) -> str:
    from repro.fanstore.placement import (LeastLoadedSelector,
                                          ModuloPlacement,
                                          PowerOfTwoSelector, RingPlacement)
    table = {"placement": ((ModuloPlacement, "modulo"),
                           (RingPlacement, "ring")),
             "selector": ((LeastLoadedSelector, "least-loaded"),
                          (PowerOfTwoSelector, "power-of-two"))}
    for cls_, name in table[kind]:
        if type(obj) is cls_:
            return name
    return default
