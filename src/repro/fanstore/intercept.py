"""User-space call interception (paper §5.5, Python-idiomatic equivalent).

The paper detours glibc entry points so unmodified binaries hit FanStore.
In-process Python the analogous seam is the callable itself. Two levels:

* path-level: ``builtins.open``, ``os.stat``, ``os.listdir``,
  ``os.scandir``, ``os.path.exists``, ``os.path.getsize`` and
  ``os.unlink``/``os.remove`` (output GC) route any path under the mount
  prefix into the session;
* fd-level (the part a real detour library must get right): ``os.open``
  returns a session descriptor (numbered from ``FD_BASE``, far above any
  real fd), and ``os.read``/``os.write``/``os.lseek``/``os.close``/
  ``os.fstat`` route by descriptor value — FanStore fds to the session's
  descriptor table, everything else to the real syscalls.

Use as a context manager::

    with intercept(fs):
        fd = os.open("/fanstore/out/gen.bin", os.O_WRONLY | os.O_CREAT)
        os.write(fd, b"payload")
        os.close(fd)                       # visible-on-close commit
        data = open("/fanstore/out/gen.bin", "rb").read()

DESIGN.md §2 records why the binary-detour mechanism itself has no TPU or
Python analogue; this is the closest faithful seam.
"""
from __future__ import annotations

import builtins
import contextlib
import os
from typing import Iterator, Union

from repro.fanstore.api import FanStoreSession
from repro.fanstore.fs import FanStoreFS


@contextlib.contextmanager
def intercept(client: Union[FanStoreFS, FanStoreSession]
              ) -> Iterator[Union[FanStoreFS, FanStoreSession]]:
    """Patch the path- and fd-level entry points to detour mount-prefixed
    paths (and session descriptors) into ``client`` — a ``FanStoreSession``
    or the deprecated ``FanStoreFS`` adapter (whose session is used)."""
    session = client.session if isinstance(client, FanStoreFS) else client
    real_open = builtins.open
    real_stat = os.stat
    real_listdir = os.listdir
    real_scandir = os.scandir
    real_exists = os.path.exists
    real_getsize = os.path.getsize
    real_os_open = os.open
    real_os_read = os.read
    real_os_write = os.write
    real_os_lseek = os.lseek
    real_os_close = os.close
    real_os_fstat = os.fstat
    real_unlink = os.unlink
    real_remove = os.remove

    def _ours(path) -> bool:
        return isinstance(path, (str, os.PathLike)) and \
            session.owns(os.fspath(path))

    def _stat_result(st) -> os.stat_result:
        return os.stat_result((st.st_mode, st.st_ino, st.st_dev, st.st_nlink,
                               st.st_uid, st.st_gid, st.st_size,
                               int(st.st_atime), int(st.st_mtime),
                               int(st.st_ctime)))

    # ---- path level --------------------------------------------------------
    def _open(path, mode="r", *a, **kw):
        if _ours(path):
            from repro.fanstore.fs import FanStoreFile
            return FanStoreFile(session, os.fspath(path),
                                mode if "b" in mode else mode + "b")
        return real_open(path, mode, *a, **kw)

    def _stat(path, *a, **kw):
        if _ours(path):
            return _stat_result(session.stat(os.fspath(path)))
        return real_stat(path, *a, **kw)

    def _listdir(path=".", *a, **kw):
        if _ours(path):
            return session.listdir(os.fspath(path))
        return real_listdir(path, *a, **kw)

    def _scandir(path=".", *a, **kw):
        if _ours(path):
            return session.scandir(os.fspath(path))
        return real_scandir(path, *a, **kw)

    def _exists(path):
        if _ours(path):
            return session.exists(os.fspath(path))
        return real_exists(path)

    def _getsize(path):
        if _ours(path):
            return session.getsize(os.fspath(path))
        return real_getsize(path)

    def _unlink(path, *a, **kw):
        if _ours(path):
            return session.unlink(os.fspath(path))
        return real_unlink(path, *a, **kw)

    def _remove(path, *a, **kw):
        if _ours(path):
            return session.unlink(os.fspath(path))
        return real_remove(path, *a, **kw)

    # ---- fd level ----------------------------------------------------------
    def _os_open(path, flags, *a, **kw):
        if _ours(path):
            return session.open(os.fspath(path), flags)
        return real_os_open(path, flags, *a, **kw)

    def _os_read(fd, n, *a, **kw):
        if session.owns_fd(fd):
            return session.read(fd, n)
        return real_os_read(fd, n, *a, **kw)

    def _os_write(fd, data, *a, **kw):
        if session.owns_fd(fd):
            return session.write(fd, data)
        return real_os_write(fd, data, *a, **kw)

    def _os_lseek(fd, pos, how, *a, **kw):
        if session.owns_fd(fd):
            return session.lseek(fd, pos, how)
        return real_os_lseek(fd, pos, how, *a, **kw)

    def _os_close(fd, *a, **kw):
        if session.owns_fd(fd):
            session.close(fd)
            return None
        return real_os_close(fd, *a, **kw)

    def _os_fstat(fd, *a, **kw):
        if session.owns_fd(fd):
            return _stat_result(session.fstat(fd))
        return real_os_fstat(fd, *a, **kw)

    builtins.open = _open
    os.stat = _stat
    os.listdir = _listdir
    os.scandir = _scandir
    os.path.exists = _exists
    os.path.getsize = _getsize
    os.open = _os_open
    os.read = _os_read
    os.write = _os_write
    os.lseek = _os_lseek
    os.close = _os_close
    os.fstat = _os_fstat
    os.unlink = _unlink
    os.remove = _remove
    try:
        yield client
    finally:
        builtins.open = real_open
        os.stat = real_stat
        os.listdir = real_listdir
        os.scandir = real_scandir
        os.path.exists = real_exists
        os.path.getsize = real_getsize
        os.open = real_os_open
        os.read = real_os_read
        os.write = real_os_write
        os.lseek = real_os_lseek
        os.close = real_os_close
        os.fstat = real_os_fstat
        os.unlink = real_unlink
        os.remove = real_remove
