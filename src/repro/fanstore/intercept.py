"""User-space call interception (paper §5.5, Python-idiomatic equivalent).

The paper detours glibc entry points so unmodified binaries hit FanStore.
In-process Python the analogous seam is the callable itself: we patch
``builtins.open``, ``os.stat``, ``os.listdir`` and ``os.path.exists`` to
route any path under the mount prefix into a :class:`FanStoreFS`, and fall
through to the real implementations otherwise. Use as a context manager::

    with intercept(fs):
        data = open("/fanstore/train/img_000.bin", "rb").read()

DESIGN.md §2 records why the binary-detour mechanism itself has no TPU or
Python analogue; this is the closest faithful seam.
"""
from __future__ import annotations

import builtins
import contextlib
import os
from typing import Iterator

from repro.fanstore.fs import FanStoreFS


@contextlib.contextmanager
def intercept(fs: FanStoreFS) -> Iterator[FanStoreFS]:
    real_open = builtins.open
    real_stat = os.stat
    real_listdir = os.listdir
    real_exists = os.path.exists

    def _open(path, mode="r", *a, **kw):
        if isinstance(path, (str, os.PathLike)) and fs.owns(os.fspath(path)):
            return fs.open(os.fspath(path), mode if "b" in mode else mode + "b")
        return real_open(path, mode, *a, **kw)

    def _stat(path, *a, **kw):
        if isinstance(path, (str, os.PathLike)) and fs.owns(os.fspath(path)):
            st = fs.stat(os.fspath(path))
            return os.stat_result((st.st_mode, st.st_ino, st.st_dev, st.st_nlink,
                                   st.st_uid, st.st_gid, st.st_size,
                                   int(st.st_atime), int(st.st_mtime), int(st.st_ctime)))
        return real_stat(path, *a, **kw)

    def _listdir(path=".", *a, **kw):
        if isinstance(path, (str, os.PathLike)) and fs.owns(os.fspath(path)):
            return fs.listdir(os.fspath(path))
        return real_listdir(path, *a, **kw)

    def _exists(path):
        if isinstance(path, (str, os.PathLike)) and fs.owns(os.fspath(path)):
            return fs.exists(os.fspath(path))
        return real_exists(path)

    builtins.open = _open
    os.stat = _stat
    os.listdir = _listdir
    os.path.exists = _exists
    try:
        yield fs
    finally:
        builtins.open = real_open
        os.stat = real_stat
        os.listdir = real_listdir
        os.path.exists = real_exists
