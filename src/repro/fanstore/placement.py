"""Placement layer: where a path's bytes (or metadata) live, and which
replica serves a given read.

Two concerns, two pluggable protocols:

* :class:`Placement` — path -> owning node. ``ModuloPlacement`` is the
  paper's faithful ``hash(path) % node_count`` (§5.3 calls it a consistent
  hash; it is not). ``RingPlacement`` wraps a true consistent-hash ring with
  virtual nodes so membership changes move only O(changed/total) keys —
  the property :mod:`repro.train.elastic` builds its rebalance plans on.
  Output files route through this end-to-end: ``owner(path)`` decides not
  just the metadata shard but where the committed PAYLOAD lives — the
  write path (``write_many``/``commit_write``) ships bytes to that node's
  output tier, so under ``RingPlacement`` written outputs inherit the same
  elastic-membership story as ring-placed input partitions.
* :class:`ReplicaSelector` — given the live owners of a file and the current
  per-node load, pick who serves this read. ``LeastLoadedSelector`` is the
  straggler mitigation the cluster has always used; ``PowerOfTwoSelector``
  samples two owners and takes the lighter one, the classic low-coordination
  approximation that behaves identically under full load knowledge but
  models what a real client with stale load info would do.

``ConsistentHashRing`` historically lived in :mod:`repro.fanstore.metadata`;
it is defined here now (metadata keeps a lazy compatibility re-export).
"""
from __future__ import annotations

import bisect
import threading
from typing import Iterable, List, Mapping, Optional, Protocol, Sequence, Tuple

from repro.fanstore.metadata import modulo_placement, path_hash


class ConsistentHashRing:
    """True consistent hashing with virtual nodes (beyond-paper, for elasticity)."""

    def __init__(self, node_ids: Iterable[int], *, vnodes: int = 64):
        self.vnodes = vnodes
        self._ring: List[Tuple[int, int]] = []
        self._nodes: set = set()
        for nid in node_ids:
            self.add_node(nid)

    def _vhash(self, node_id: int, replica: int) -> int:
        return path_hash(f"node:{node_id}:v{replica}")

    def add_node(self, node_id: int) -> None:
        if node_id in self._nodes:
            return
        self._nodes.add(node_id)
        for r in range(self.vnodes):
            bisect.insort(self._ring, (self._vhash(node_id, r), node_id))

    def remove_node(self, node_id: int) -> None:
        if node_id not in self._nodes:
            return
        self._nodes.discard(node_id)
        self._ring = [(h, n) for (h, n) in self._ring if n != node_id]

    @property
    def nodes(self) -> Tuple[int, ...]:
        return tuple(sorted(self._nodes))

    def owner(self, path: str) -> int:
        if not self._ring:
            raise RuntimeError("empty hash ring")
        h = path_hash(path)
        idx = bisect.bisect_right(self._ring, (h, 1 << 62)) % len(self._ring)
        return self._ring[idx][1]

    def owners(self, path: str, k: int) -> List[int]:
        """First k distinct nodes clockwise from the path's point (replica set)."""
        if k > len(self._nodes):
            raise ValueError("k exceeds live node count")
        h = path_hash(path)
        idx = bisect.bisect_right(self._ring, (h, 1 << 62))
        picked: List[int] = []
        for step in range(len(self._ring)):
            nid = self._ring[(idx + step) % len(self._ring)][1]
            if nid not in picked:
                picked.append(nid)
                if len(picked) == k:
                    break
        return picked


class Placement(Protocol):
    """path -> owning node id (used for output-file metadata placement)."""

    def owner(self, path: str) -> int: ...

    def replica_set(self, path: str, k: int) -> List[int]: ...


class ModuloPlacement:
    """The paper's placement: ``hash(path) % node_count`` (§5.3)."""

    def __init__(self, num_nodes: int):
        if num_nodes < 1:
            raise ValueError("need at least one node")
        self.num_nodes = num_nodes

    def owner(self, path: str) -> int:
        return modulo_placement(path, self.num_nodes)

    def replica_set(self, path: str, k: int) -> List[int]:
        if k > self.num_nodes:
            raise ValueError("k exceeds node count")
        first = self.owner(path)
        return [(first + i) % self.num_nodes for i in range(k)]


class RingPlacement:
    """Consistent-hash placement: membership changes move O(changed/total) keys."""

    def __init__(self, node_ids: Iterable[int], *, vnodes: int = 64):
        self.ring = ConsistentHashRing(node_ids, vnodes=vnodes)

    def owner(self, path: str) -> int:
        return self.ring.owner(path)

    def replica_set(self, path: str, k: int) -> List[int]:
        return self.ring.owners(path, k)

    def add_node(self, node_id: int) -> None:
        self.ring.add_node(node_id)

    def remove_node(self, node_id: int) -> None:
        self.ring.remove_node(node_id)


#: registry for :class:`repro.fanstore.spec.ClusterSpec` — placement by name
PLACEMENTS = ("modulo", "ring")


def make_placement(name: str, num_nodes: int) -> "Placement":
    """Build a placement policy from its registry name (spec-driven path)."""
    if name == "modulo":
        return ModuloPlacement(num_nodes)
    if name == "ring":
        return RingPlacement(range(num_nodes))
    raise ValueError(f"unknown placement {name!r}; "
                     f"known: {sorted(PLACEMENTS)}")


class ReplicaSelector(Protocol):
    """Pick the owner that serves a read from the file's live replica set."""

    def choose(self, owners: Sequence[int], load: Mapping[int, float]) -> int: ...


class LeastLoadedSelector:
    """Full-knowledge straggler mitigation: serve from the least-busy owner."""

    def choose(self, owners: Sequence[int], load: Mapping[int, float]) -> int:
        return min(owners, key=lambda o: (load.get(o, 0.0), o))


class PowerOfTwoSelector:
    """Power-of-two-choices: sample two owners, take the lighter.

    Deterministic seeding keeps benchmarks reproducible; with R<=2 this
    degenerates to least-loaded (both choices are the whole owner set).
    """

    def __init__(self, seed: int = 0):
        self._state = (seed * 2654435761 + 1) & 0xFFFFFFFF
        self._lock = threading.Lock()   # draws stay a deterministic sequence
                                        # even from transport pool threads

    def _rand(self, n: int) -> int:
        # xorshift32: cheap, deterministic, no numpy dependency on hot path
        with self._lock:
            x = self._state or 1
            x ^= (x << 13) & 0xFFFFFFFF
            x ^= x >> 17
            x ^= (x << 5) & 0xFFFFFFFF
            self._state = x
        return x % n

    def choose(self, owners: Sequence[int], load: Mapping[int, float]) -> int:
        if len(owners) <= 2:
            return min(owners, key=lambda o: (load.get(o, 0.0), o))
        a = owners[self._rand(len(owners))]
        b = owners[self._rand(len(owners))]
        return min((a, b), key=lambda o: (load.get(o, 0.0), o))


class ShardPopularity:
    """Online read-popularity counter over partition ids — the hot-shard
    detector the serving plane (:mod:`repro.fanstore.serving`) promotes
    replicated placement from.

    Thread-safe: serving tenants note reads from many threads. ``hot()``
    answers "which partitions have crossed the promotion threshold",
    hottest first, so the promoter replicates the worst offender before
    the merely warm ones."""

    def __init__(self) -> None:
        self._counts: dict = {}
        self._total = 0
        self._lock = threading.Lock()

    def note(self, partition_id: int, n: int = 1) -> None:
        with self._lock:
            self._counts[partition_id] = \
                self._counts.get(partition_id, 0) + n
            self._total += n

    def count(self, partition_id: int) -> int:
        with self._lock:
            return self._counts.get(partition_id, 0)

    @property
    def total(self) -> int:
        with self._lock:
            return self._total

    def hot(self, *, min_reads: int) -> List[int]:
        """Partitions with at least ``min_reads`` noted reads, hottest
        first (ties broken by id for determinism)."""
        if min_reads < 1:
            raise ValueError("min_reads must be >= 1")
        with self._lock:
            return [pid for pid, c in sorted(self._counts.items(),
                                             key=lambda kv: (-kv[1], kv[0]))
                    if c >= min_reads]

    def snapshot(self) -> dict:
        with self._lock:
            return dict(self._counts)


#: registry for :class:`repro.fanstore.spec.ClusterSpec` — selector by name
SELECTORS = ("least-loaded", "power-of-two")


def make_selector(name: str, *, seed: int = 0) -> "ReplicaSelector":
    """Build a replica selector from its registry name (spec-driven path)."""
    if name == "least-loaded":
        return LeastLoadedSelector()
    if name == "power-of-two":
        return PowerOfTwoSelector(seed=seed)
    raise ValueError(f"unknown selector {name!r}; "
                     f"known: {sorted(SELECTORS)}")
