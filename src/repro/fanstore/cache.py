"""Client-side read cache layer (beyond-paper; Hoard-style).

The paper evicts a file's decompressed bytes the moment its refcount hits
zero (uniform random access defeats LRU *within one epoch over a dataset
larger than RAM*). But at cluster scale the dominant win — per Hoard
(Pinto et al., 2018) — is a client-side cache absorbing repeated remote
reads: hot validation files, small shared metadata, and any skewed access
pattern. This module is that tier: per-node, byte-budgeted caches that sit
in front of the transport. Hits, misses, and evictions are reported through
the node's ``NodeClock`` (see :mod:`repro.fanstore.accounting`) so
benchmarks can plot hit rate against the byte budget.

Seven eviction policies behind one interface (``ByteCache``):

* ``ByteLRUCache``   — classic least-recently-used. Uniform random access
  defeats it within an epoch; it is the baseline the others beat.
* ``BeladyCache``    — clairvoyant MIN/OPT: given the epoch's future access
  trace (from :class:`repro.fanstore.prefetch.EpochSchedule`), evict the
  resident whose next use is farthest away, and refuse admission when the
  incoming payload is itself the farthest. This is the optimal offline
  policy and the natural partner of the clairvoyant prefetch scheduler.
* ``TwoQCache``      — 2Q (Johnson & Shasha '94): a FIFO probation queue
  absorbs one-shot scans, a ghost list remembers recently-evicted keys, and
  only re-referenced files are promoted to the protected LRU main queue.
  Scan-resistant without needing the future.
* ``LFUCache``       — in-cache frequency with periodic aging: hot files
  survive arbitrary recency noise; aging keeps dead hotness from pinning
  entries forever.
* ``ArcCache``       — ARC (Megiddo & Modha '03), byte-weighted: resident
  recency (T1) and frequency (T2) lists balanced by a self-tuning target
  ``p``, steered by hits in the B1/B2 ghost lists of recently evicted keys.
* ``GdsfCache``      — Greedy-Dual-Size-Frequency (Cherkasova '98):
  priority = L + freq * cost / size, the right shape when file sizes are
  mixed — a huge once-read blob should not outlive many small hot files.
* ``PredictiveCache``— an online Belady approximation: estimate each
  path's next reuse from a per-path EWMA of its observed reuse distances
  and evict the entry whose predicted next use is farthest away. The
  oracle Belady needs, learned from history instead of given.

``FanStoreCluster(cache_policy=...)`` selects the policy via
:func:`make_cache`. Caches are OFF by default (``capacity_bytes=0``
disabled) so the paper-faithful read path is unchanged unless a deployment
opts in. Per-policy constructor knobs travel through
``ClusterSpec.cache_policy_options``.

Ownership sits one level up, in :class:`NodeCacheTier`: the paper's
deployment runs SEVERAL training workers per node (§3), and per Hoard the
node-local cache should be one shared tier across all of them — a payload
fetched by any co-located worker serves every other. The tier owns the
node's byte budget (``cache_scope="node"`` = one shared policy cache;
``"worker"`` = private per-worker splits of the same total, the baseline
the shared tier beats) and keeps a per-worker hit/miss attribution ledger
beside the cache's own totals, locked so the transport pool and socket
serving threads can hit it concurrently.
"""
from __future__ import annotations

import threading
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple, Union


@dataclass(frozen=True)
class CachedEntry:
    """One cache slot. ``data is None`` marks a size-only entry: benchmarks
    running with ``materialize=False`` model cache behavior without holding
    payload copies, so only the byte budget and timeline are exercised."""
    data: Optional[bytes]
    size: int


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    insertions: int = 0
    rejections: int = 0       # admission refused (Belady: farthest next use)
    hit_bytes: int = 0
    evicted_bytes: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class ByteCache:
    """Byte-budgeted cache over immutable payloads (input files never
    change, so entries are only evicted for space; the one exception is
    :meth:`invalidate`, which output GC/unlink uses to drop a deleted
    file's payload).

    Subclasses implement one seam, :meth:`_pick_victim`, and may override
    the access/admission hooks. Two event ledgers exist by design:
    ``self.stats`` is the cache's own lifetime view (survives
    ``FanStoreCluster.reset_clocks``), while the cluster mirrors the same
    events onto the reading node's ``NodeClock`` (per-benchmark-run
    timeline). The cluster's ``read_many``/``prefetch_window`` are the call
    sites responsible for keeping the mirror in step — identically for
    every policy."""

    def __init__(self, capacity_bytes: int):
        if capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        self.capacity_bytes = capacity_bytes
        self._entries: "OrderedDict[str, CachedEntry]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.stats = CacheStats()

    @property
    def enabled(self) -> bool:
        return self.capacity_bytes > 0

    @property
    def used_bytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, path: str) -> bool:
        with self._lock:
            return path in self._entries

    # -- policy hooks (subclass seam) ---------------------------------------
    def _on_hit(self, path: str) -> None:
        """Access bookkeeping on a hit (default: MRU promotion)."""
        self._entries.move_to_end(path)

    def _on_miss(self, path: str) -> None:
        """Access bookkeeping on a demand miss (default: none)."""

    def _admit(self, path: str, nbytes: int) -> bool:
        """Whether to insert this payload at all (default: always)."""
        return True

    def _note_insert(self, path: str, nbytes: int, *,
                     replaced: bool) -> None:
        """Pre-insert bookkeeping hook, called under the lock after
        admission (2Q routes the key into its queues here)."""

    def _pick_victim(self) -> str:
        """Return the resident path to evict (called under the lock while
        over budget). Default: LRU order."""
        return next(iter(self._entries))

    # -- shared machinery ---------------------------------------------------
    def get(self, path: str, *,
            require_data: bool = False) -> Optional[CachedEntry]:
        """Return the cached entry (marking the access) or None on miss.

        ``require_data=True`` treats size-only entries as misses (no hit
        stats, no access promotion): a materializing read cannot be served
        by a modeling placeholder and will refetch-and-replace it.
        """
        if not self.enabled:
            return None
        with self._lock:
            entry = self._entries.get(path)
            if entry is None or (require_data and entry.data is None):
                self.stats.misses += 1
                self._on_miss(path)
                return None
            self._on_hit(path)
            self.stats.hits += 1
            self.stats.hit_bytes += entry.size
            return entry

    def put(self, path: str, data: Optional[bytes], *,
            size: Optional[int] = None) -> int:
        """Insert a payload, evicting policy-chosen entries past the byte
        budget.

        ``data=None`` requires an explicit ``size`` (size-only modeling
        entry). Returns the number of evictions this insert caused.
        Payloads larger than the whole budget are not cached (they would
        evict everything for a single-use entry), and a policy may refuse
        admission outright (Belady does when the payload's next use is
        farther than every resident's).
        """
        nbytes = len(data) if data is not None else size
        if nbytes is None:
            raise ValueError("size is required for size-only entries")
        if not self.enabled or nbytes > self.capacity_bytes:
            return 0
        evicted = 0
        with self._lock:
            if not self._admit(path, nbytes):
                self.stats.rejections += 1
                return 0
            old = self._entries.pop(path, None)
            if old is not None:
                self._bytes -= old.size
            self._note_insert(path, nbytes, replaced=old is not None)
            self._entries[path] = CachedEntry(data=data, size=nbytes)
            self._bytes += nbytes
            self.stats.insertions += 1
            while self._bytes > self.capacity_bytes:
                victim = self._pick_victim()
                entry = self._entries.pop(victim)
                self._bytes -= entry.size
                self._evicted(victim, entry)
                self.stats.evictions += 1
                self.stats.evicted_bytes += entry.size
                evicted += 1
        return evicted

    def _evicted(self, path: str, entry: CachedEntry) -> None:
        """Post-eviction hook (2Q moves the key to its ghost list)."""

    def _forget(self, path: str) -> None:
        """Post-invalidation hook: drop any per-path policy state (2Q/ARC
        remove the key from their probation/ghost queues, the predictor
        drops its reuse history). Unlike ``_evicted``, the entry must
        leave no trace — the file is gone (PR-4 unlink invalidation), and
        a rewrite of the freed name must start from a clean slate."""

    def invalidate(self, path: str) -> bool:
        """Drop a path outright (output GC/unlink): NOT an eviction — no
        victim policy, no eviction counters, no ghost history. Inputs are
        immutable so only unlinked outputs ever need this. Returns True
        when the path was resident.

        ``_forget`` runs even for a NON-resident path: ghost lists (2Q,
        ARC) and the predictor's reuse history outlive residency, and an
        unlinked name must vanish from those too — otherwise rewriting
        the freed path replays the dead file's ghost credit/period."""
        with self._lock:
            entry = self._entries.pop(path, None)
            if entry is not None:
                self._bytes -= entry.size
            self._forget(path)
            return entry is not None

    def _on_clear(self) -> None:
        """Post-clear hook: reset ALL policy state (queues, ghost lists,
        frequency counters, predictor history) — a cleared cache must be
        indistinguishable from a freshly built one."""

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0
            self._on_clear()


class ByteLRUCache(ByteCache):
    """Byte-budgeted LRU — the PR 1 policy, unchanged behavior."""


class BeladyCache(ByteCache):
    """Clairvoyant MIN/OPT eviction from a known future access trace.

    :meth:`set_future` installs the epoch's demand-access sequence (e.g.
    ``EpochSchedule.future_paths(requester)``). Every demand access (a
    ``get``, hit or miss) consumes that path's current occurrence; the
    front of each path's remaining-occurrence queue is its *next* use.
    Eviction removes the resident with the farthest next use; admission is
    refused when the incoming payload itself has the farthest next use
    (inserting it would be strictly worse than not caching it — the step
    LRU-family policies cannot take). Paths absent from the trace (or past
    their last use) have next use = infinity and are evicted first.

    ``put`` does NOT consume occurrences, so prefetch inserts ahead of the
    demand stream leave reuse distances exact.
    """

    _NEVER = float("inf")

    def __init__(self, capacity_bytes: int,
                 future: Optional[Sequence[str]] = None):
        super().__init__(capacity_bytes)
        self._future: Dict[str, Deque[int]] = {}
        if future is not None:
            self.set_future(future)

    def set_future(self, trace: Sequence[str]) -> None:
        """Install the future demand-access sequence (replaces any prior)."""
        with self._lock:
            fut: Dict[str, Deque[int]] = {}
            for t, path in enumerate(trace):
                fut.setdefault(path, deque()).append(t)
            self._future = fut

    def extend_future(self, trace: Sequence[str]) -> None:
        """Append another epoch's trace after the current one."""
        with self._lock:
            base = max((q[-1] for q in self._future.values() if q),
                       default=-1) + 1
            for t, path in enumerate(trace):
                self._future.setdefault(path, deque()).append(base + t)

    def _next_use(self, path: str) -> float:
        q = self._future.get(path)
        return q[0] if q else self._NEVER

    def _consume(self, path: str) -> None:
        q = self._future.get(path)
        if q:
            q.popleft()

    def _on_hit(self, path: str) -> None:
        self._consume(path)

    def _on_miss(self, path: str) -> None:
        self._consume(path)

    def _admit(self, path: str, nbytes: int) -> bool:
        # a resident entry being replaced (e.g. a size-only placeholder
        # upgraded by a materializing read) frees its own bytes first and
        # must not compete against itself in the farthest-use comparison
        old = self._entries.get(path)
        occupied = self._bytes - (old.size if old is not None else 0)
        if occupied + nbytes <= self.capacity_bytes:
            return True      # fits in spare capacity: caching is free
        nu = self._next_use(path)
        if nu == self._NEVER:
            return False     # would evict useful bytes for a dead entry
        # admit only if some resident is reused later than the newcomer —
        # otherwise evicting for it is strictly worse than bypassing
        farthest = max((self._next_use(p) for p in self._entries
                        if p != path), default=self._NEVER)
        return nu < farthest

    def _pick_victim(self) -> str:
        return max(self._entries, key=self._next_use)

    def _forget(self, path: str) -> None:
        # the file is gone (unlink): a rewrite of the freed name is a NEW
        # file — the old trace's occurrences must not make it look hot
        self._future.pop(path, None)

    # NOTE: clear() deliberately keeps the installed future — clearing is
    # an entries reset (benchmark epoch restart), not an oracle reset.


class TwoQCache(ByteCache):
    """2Q: FIFO probation (A1in) + ghost history (A1out) + protected LRU
    main queue (Am).

    First-touch payloads enter A1in and, if never re-referenced, FIFO out
    through the A1out ghost list (keys only, no bytes) without ever
    touching Am — a one-shot scan cannot pollute the protected set. A hit
    while the key is in A1out proves reuse beyond the probation horizon, so
    the refetched payload is admitted straight into Am. ``kin`` is the
    byte-budget fraction reserved for probation, ``kout`` the ghost-list
    size as a fraction of the budget (counting remembered *bytes* — the
    entries hold no payload, so a generous horizon costs only keys).

    ``kout`` defaults to 2.0: the ghost must remember evicted keys for
    longer than the working set's typical reuse distance or promotion
    never fires — the old 0.5 default forgot a key well before its mean
    reuse under DL-style access, leaving the protected queue starved and
    2Q *below* LRU on the uniform BENCH trace (0.262 vs 0.277).
    """

    def __init__(self, capacity_bytes: int, *, kin: float = 0.25,
                 kout: float = 2.0):
        super().__init__(capacity_bytes)
        if not 0.0 < kin < 1.0:
            raise ValueError("kin must be in (0, 1)")
        if kout <= 0.0:
            raise ValueError("kout must be > 0")
        self.kin_bytes = max(1, int(capacity_bytes * kin))
        self.kout_bytes = max(1, int(capacity_bytes * kout))
        self._a1in: "OrderedDict[str, int]" = OrderedDict()   # path -> size
        self._ghost: "OrderedDict[str, int]" = OrderedDict()  # path -> size
        self._ghost_bytes = 0
        self._a1in_bytes = 0

    def _on_hit(self, path: str) -> None:
        # hits in Am refresh recency; hits in probation do NOT promote —
        # promotion requires surviving into the ghost list first (classic
        # full 2Q), which is exactly what filters one-shot scans
        if path not in self._a1in:
            self._entries.move_to_end(path)

    def _remember_ghost(self, path: str, size: int) -> None:
        old = self._ghost.pop(path, None)
        if old is not None:
            self._ghost_bytes -= old
        self._ghost[path] = size
        self._ghost_bytes += size
        while self._ghost_bytes > self.kout_bytes and len(self._ghost) > 1:
            _, s = self._ghost.popitem(last=False)
            self._ghost_bytes -= s

    def _note_insert(self, path: str, nbytes: int, *,
                     replaced: bool) -> None:
        if replaced:
            if path in self._a1in:
                # refreshed while on probation (e.g. size-only upgrade):
                # stays on probation at its old queue position
                self._a1in_bytes += nbytes - self._a1in[path]
                self._a1in[path] = nbytes
        elif path in self._ghost:
            # reuse beyond the probation horizon: straight to the
            # protected main queue
            self._ghost_bytes -= self._ghost.pop(path)
        else:
            self._a1in[path] = nbytes           # first touch -> probation
            self._a1in_bytes += nbytes

    def _pick_victim(self) -> str:
        # drain probation first while it is over its share (or the main
        # queue is empty); otherwise evict the LRU of the protected queue
        if self._a1in and (self._a1in_bytes > self.kin_bytes
                           or len(self._a1in) == len(self._entries)):
            return next(iter(self._a1in))
        for path in self._entries:              # LRU order, skip probation
            if path not in self._a1in:
                return path
        return next(iter(self._entries))

    def _evicted(self, path: str, entry: CachedEntry) -> None:
        if path in self._a1in:
            self._a1in_bytes -= self._a1in.pop(path)
            self._remember_ghost(path, entry.size)

    def _forget(self, path: str) -> None:
        if path in self._a1in:
            self._a1in_bytes -= self._a1in.pop(path)
        if path in self._ghost:
            self._ghost_bytes -= self._ghost.pop(path)

    def _on_clear(self) -> None:
        self._a1in.clear()
        self._ghost.clear()
        self._a1in_bytes = self._ghost_bytes = 0


class LFUCache(ByteCache):
    """Least-frequently-used with periodic aging.

    Each resident entry carries an access count; eviction removes the
    lowest count, breaking ties toward least-recent (the shared
    ``OrderedDict`` keeps LRU order, and ``min`` keeps the first — i.e.
    oldest — of equals). Every ``aging_interval`` accesses all counts are
    halved, so a file that was hot a thousand accesses ago cannot pin its
    slot forever on stale credit — the failure mode that makes plain LFU
    worse than LRU on drifting working sets.
    """

    def __init__(self, capacity_bytes: int, *, aging_interval: int = 1024):
        super().__init__(capacity_bytes)
        if aging_interval < 1:
            raise ValueError("aging_interval must be >= 1")
        self.aging_interval = aging_interval
        self._freq: Dict[str, int] = {}
        self._accesses = 0

    def _tick(self) -> None:
        self._accesses += 1
        if self._accesses >= self.aging_interval:
            self._accesses = 0
            for p in self._freq:
                self._freq[p] //= 2

    def _on_hit(self, path: str) -> None:
        self._entries.move_to_end(path)          # LRU order = tie-break
        self._freq[path] = self._freq.get(path, 0) + 1
        self._tick()

    def _on_miss(self, path: str) -> None:
        self._tick()

    def _note_insert(self, path: str, nbytes: int, *,
                     replaced: bool) -> None:
        self._freq[path] = self._freq.get(path, 0) + 1

    def _pick_victim(self) -> str:
        return min(self._entries, key=lambda p: self._freq.get(p, 0))

    def _evicted(self, path: str, entry: CachedEntry) -> None:
        self._freq.pop(path, None)

    def _forget(self, path: str) -> None:
        self._freq.pop(path, None)

    def _on_clear(self) -> None:
        self._freq.clear()
        self._accesses = 0


class ArcCache(ByteCache):
    """ARC (Megiddo & Modha '03) adapted to a byte budget.

    Residents live on two lists — T1 (seen exactly once since entering)
    and T2 (seen again while resident, or readmitted after a ghost hit) —
    with ghost lists B1/B2 remembering the keys (and sizes) most recently
    evicted from each. A self-tuning target ``p`` says how many bytes T1
    deserves: a hit in B1 ("we evicted a recent entry too soon") grows
    ``p``, a hit in B2 ("we evicted a frequent entry too soon") shrinks
    it, each step weighted by the opposing ghost's byte mass so the
    smaller signal moves the needle faster — byte-weighted exactly as the
    original is entry-weighted. Eviction drains T1's LRU while T1 exceeds
    ``p``, else T2's LRU.

    Ghost hits are detected at insert time (``_note_insert``): the tier's
    read path is get-then-put, so the refetch after a ghost hit is the
    moment the key returns.
    """

    def __init__(self, capacity_bytes: int):
        super().__init__(capacity_bytes)
        self._t1: "OrderedDict[str, int]" = OrderedDict()   # path -> size
        self._t2: "OrderedDict[str, int]" = OrderedDict()
        self._b1: "OrderedDict[str, int]" = OrderedDict()   # ghosts
        self._b2: "OrderedDict[str, int]" = OrderedDict()
        self._t1_bytes = self._t2_bytes = 0
        self._b1_bytes = self._b2_bytes = 0
        self._p = 0.0                      # target byte share for T1

    def _on_hit(self, path: str) -> None:
        self._entries.move_to_end(path)
        if path in self._t1:               # second touch: promote
            self._t2[path] = self._t1.pop(path)
            self._t1_bytes -= self._t2[path]
            self._t2_bytes += self._t2[path]
        elif path in self._t2:
            self._t2.move_to_end(path)

    def _ghost_trim(self) -> None:
        # classic ARC bounds |B1|<=c and |L1|+|L2|<=2c; byte-weighted here
        while self._b1_bytes > self.capacity_bytes and len(self._b1) > 1:
            _, s = self._b1.popitem(last=False)
            self._b1_bytes -= s
        while self._b2_bytes > self.capacity_bytes and len(self._b2) > 1:
            _, s = self._b2.popitem(last=False)
            self._b2_bytes -= s

    def _note_insert(self, path: str, nbytes: int, *,
                     replaced: bool) -> None:
        if replaced:                       # resident refresh: keep list,
            for lst, attr in ((self._t1, "_t1_bytes"),
                              (self._t2, "_t2_bytes")):
                if path in lst:            # update the byte count
                    setattr(self, attr,
                            getattr(self, attr) + nbytes - lst[path])
                    lst[path] = nbytes
                    return
            self._t1[path] = nbytes        # untracked resident (defensive)
            self._t1_bytes += nbytes
            return
        if path in self._b1:
            # recency ghost hit: T1 was too small — grow p, weighted by
            # how lopsided the ghosts are (rarer signal => bigger step)
            ratio = max(1.0, self._b2_bytes / max(self._b1_bytes, 1))
            self._p = min(self._p + ratio * nbytes,
                          float(self.capacity_bytes))
            self._b1_bytes -= self._b1.pop(path)
            self._t2[path] = nbytes        # proven reuse -> frequent list
            self._t2_bytes += nbytes
        elif path in self._b2:
            ratio = max(1.0, self._b1_bytes / max(self._b2_bytes, 1))
            self._p = max(self._p - ratio * nbytes, 0.0)
            self._b2_bytes -= self._b2.pop(path)
            self._t2[path] = nbytes
            self._t2_bytes += nbytes
        else:                              # brand new: recency list
            self._t1[path] = nbytes
            self._t1_bytes += nbytes

    def _pick_victim(self) -> str:
        if self._t1 and (self._t1_bytes > self._p or not self._t2):
            return next(iter(self._t1))
        if self._t2:
            return next(iter(self._t2))
        return next(iter(self._entries))   # unreachable if lists are sound

    def _evicted(self, path: str, entry: CachedEntry) -> None:
        if path in self._t1:
            self._t1_bytes -= self._t1.pop(path)
            self._b1[path] = entry.size
            self._b1_bytes += entry.size
        elif path in self._t2:
            self._t2_bytes -= self._t2.pop(path)
            self._b2[path] = entry.size
            self._b2_bytes += entry.size
        self._ghost_trim()

    def _forget(self, path: str) -> None:
        # unlink: the name must vanish from resident AND ghost history —
        # a rewrite of the freed path is a new file, not a ghost hit
        if path in self._t1:
            self._t1_bytes -= self._t1.pop(path)
        if path in self._t2:
            self._t2_bytes -= self._t2.pop(path)
        if path in self._b1:
            self._b1_bytes -= self._b1.pop(path)
        if path in self._b2:
            self._b2_bytes -= self._b2.pop(path)

    def _on_clear(self) -> None:
        for lst in (self._t1, self._t2, self._b1, self._b2):
            lst.clear()
        self._t1_bytes = self._t2_bytes = 0
        self._b1_bytes = self._b2_bytes = 0
        self._p = 0.0


class GdsfCache(ByteCache):
    """Greedy-Dual-Size-Frequency (Cherkasova '98).

    Each resident entry has priority ``H = L + freq * cost / size`` with
    uniform cost (every miss is one remote fetch); ``L`` is the global
    inflation value, raised to the evicted entry's priority on each
    eviction so long-resident entries must keep earning hits to stay
    above newcomers. Eviction removes the smallest ``H`` — small hot
    files beat a huge once-read blob at equal frequency, the right shape
    for mixed file sizes. ``cost_bytes`` scales the cost term (priority =
    L + freq * cost_bytes / size) so byte-valued sizes don't drown the
    frequency signal; it defaults to a typical payload size.
    """

    def __init__(self, capacity_bytes: int, *, cost_bytes: float = 4096.0):
        super().__init__(capacity_bytes)
        if cost_bytes <= 0:
            raise ValueError("cost_bytes must be > 0")
        self.cost_bytes = cost_bytes
        self._L = 0.0
        self._freq: Dict[str, int] = {}
        self._H: Dict[str, float] = {}

    def _priority(self, path: str, nbytes: int) -> float:
        return self._L + (self._freq.get(path, 1)
                          * self.cost_bytes / max(nbytes, 1))

    def _on_hit(self, path: str) -> None:
        self._entries.move_to_end(path)          # stable LRU tie-break
        self._freq[path] = self._freq.get(path, 0) + 1
        self._H[path] = self._priority(path, self._entries[path].size)

    def _note_insert(self, path: str, nbytes: int, *,
                     replaced: bool) -> None:
        self._freq[path] = self._freq.get(path, 0) + 1
        self._H[path] = self._priority(path, nbytes)

    def _pick_victim(self) -> str:
        return min(self._entries, key=lambda p: self._H.get(p, 0.0))

    def _evicted(self, path: str, entry: CachedEntry) -> None:
        # inflation: everything that stays must now beat this bar
        self._L = max(self._L, self._H.pop(path, self._L))
        self._freq.pop(path, None)

    def _forget(self, path: str) -> None:
        # unlink (NOT an eviction): no inflation — deleting a cold output
        # must not raise the bar for the survivors
        self._H.pop(path, None)
        self._freq.pop(path, None)

    def _on_clear(self) -> None:
        self._L = 0.0
        self._freq.clear()
        self._H.clear()


class PredictiveCache(ByteCache):
    """Online Belady approximation from observed reuse distances.

    A virtual clock ticks on every demand access (``get`` — hit or miss;
    prefetch ``put`` does not tick, so inserts ahead of the demand stream
    leave distances exact, mirroring :class:`BeladyCache`). Each path
    keeps an EWMA of its observed reuse distances; its predicted next use
    is ``last_access + ewma``. Eviction removes the resident with the
    farthest predicted next use — exactly Belady's rule, with the oracle
    replaced by history.

    Two edge rules make it behave:

    * **Overdue flip** — an entry past its predicted reuse
      (``last + ewma < now``) is increasingly likely dead, so its score
      is reflected forward: ``now + (now - (last + ewma))``. The longer
      overdue, the farther predicted, the sooner evicted.
    * **Cold fallback** — a path with no observed reuse yet borrows the
      global mean reuse distance, scaled down by its lifetime access
      count (frequency rank: historically popular paths are predicted to
      return sooner). With every path cold this degenerates to LRU order,
      so the predictor never does worse than the baseline it upgrades.

    History (``last``, ``ewma``, frequency) deliberately survives
    eviction — relearning a path's period on every readmission would
    forget exactly the information the predictor exists to keep. It does
    NOT survive :meth:`invalidate` (the file is gone) or :meth:`clear`.
    """

    def __init__(self, capacity_bytes: int, *, alpha: float = 0.3):
        super().__init__(capacity_bytes)
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = alpha
        self._now = 0
        self._last: Dict[str, int] = {}
        self._ewma: Dict[str, float] = {}
        self._freq: Dict[str, int] = {}
        self._gsum = 0.0                   # global reuse-distance mean
        self._gcount = 0

    def _observe(self, path: str) -> None:
        self._now += 1
        last = self._last.get(path)
        if last is not None:
            d = float(self._now - last)
            prev = self._ewma.get(path)
            self._ewma[path] = (d if prev is None
                                else self.alpha * d
                                + (1.0 - self.alpha) * prev)
            self._gsum += d
            self._gcount += 1
        self._last[path] = self._now
        self._freq[path] = self._freq.get(path, 0) + 1

    def _on_hit(self, path: str) -> None:
        self._entries.move_to_end(path)          # LRU order = cold order
        self._observe(path)

    def _on_miss(self, path: str) -> None:
        self._observe(path)

    def _predicted_next_use(self, path: str) -> float:
        last = self._last.get(path, 0)
        ewma = self._ewma.get(path)
        if ewma is None:
            gmean = (self._gsum / self._gcount) if self._gcount else 1.0
            ewma = gmean / max(self._freq.get(path, 1), 1)
        pred = last + ewma
        if pred < self._now:               # overdue: reflect forward
            pred = self._now + (self._now - pred)
        return pred

    def _pick_victim(self) -> str:
        return max(self._entries, key=self._predicted_next_use)

    def _forget(self, path: str) -> None:
        self._last.pop(path, None)
        self._ewma.pop(path, None)
        self._freq.pop(path, None)

    def _on_clear(self) -> None:
        self._now = 0
        self._last.clear()
        self._ewma.clear()
        self._freq.clear()
        self._gsum = 0.0
        self._gcount = 0


class NodeCacheTier:
    """One node's cache tier, shared by every co-located worker.

    The tier owns the node's whole byte budget and the policy choice; the
    cluster owns one tier per node (replacing the old per-node
    ``Dict[int, ByteCache]`` whose single cache was private to whoever
    constructed the cluster). Two scopes:

    * ``scope="node"`` — ONE policy cache: a payload fetched by any
      worker is a RAM hit for all of them, and the budget pools (the
      Hoard shared-tier win, pinned by benchmarks against the private
      baseline at equal total bytes).
    * ``scope="worker"`` — private per-worker caches at
      ``capacity_bytes // workers`` each: same total budget, no sharing.
      This is the comparison baseline, and also an isolation mode for
      workers with disjoint working sets.

    Per-worker (and per-job) ATTRIBUTION rides beside the member caches'
    own stats: every ``get`` books its hit/miss (and hit bytes) onto that
    worker's :class:`CacheStats` — and, when the caller names a ``job``,
    onto that job's ledger too — under the tier lock, so "which worker's
    (or which job's) reads hit" is answerable while the node totals stay
    the tier truth — the sums match the member-cache totals by
    construction (pinned in tests; the same discipline as the serving
    plane's tenant ledger). The lock matters: transport-pool workers and
    socket serving threads hit one tier concurrently.
    """

    #: ledger key for reads that never named a job — keeps the per-job
    #: sums equal to the tier totals by construction
    DEFAULT_JOB = "default"

    def __init__(self, node_id: int, policy: Union[str, Callable[[int], ByteCache]],
                 capacity_bytes: int, *, workers: int = 1,
                 scope: str = "node",
                 policy_options: Optional[Dict[str, object]] = None):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if scope not in ("node", "worker"):
            raise ValueError(f"unknown cache scope {scope!r}; "
                             f"known: ['node', 'worker']")
        self.node_id = node_id
        self.policy = policy
        self.scope = scope
        self.capacity_bytes = capacity_bytes
        self.policy_options = dict(policy_options or {})
        self.worker_ids = tuple(range(workers))
        if scope == "node":
            shared = make_cache(policy, capacity_bytes,
                                **self.policy_options)
            self._members: Dict[int, ByteCache] = {
                w: shared for w in self.worker_ids}
        else:
            per = capacity_bytes // workers
            self._members = {w: make_cache(policy, per,
                                           **self.policy_options)
                             for w in self.worker_ids}
        self._lock = threading.Lock()
        self.worker_stats: Dict[int, CacheStats] = {
            w: CacheStats() for w in self.worker_ids}
        self.job_stats: Dict[str, CacheStats] = {}

    # ---- views -------------------------------------------------------------
    def cache_for(self, worker_id: int = 0) -> ByteCache:
        """The member cache serving ``worker_id`` (the shared cache under
        ``scope="node"``; that worker's private split otherwise)."""
        try:
            return self._members[worker_id]
        except KeyError:
            raise ValueError(
                f"worker_id {worker_id} outside this tier's "
                f"{len(self.worker_ids)} workers") from None

    def member_caches(self) -> List[ByteCache]:
        """Distinct member caches (one under ``scope="node"``)."""
        seen: List[ByteCache] = []
        for c in self._members.values():
            if all(c is not s for s in seen):
                seen.append(c)
        return seen

    @property
    def enabled(self) -> bool:
        return any(c.enabled for c in self._members.values())

    @property
    def used_bytes(self) -> int:
        return sum(c.used_bytes for c in self.member_caches())

    @property
    def stats(self) -> CacheStats:
        """Tier totals: the member caches' stats summed (identical to the
        single cache's stats under ``scope="node"``)."""
        total = CacheStats()
        for c in self.member_caches():
            for f in ("hits", "misses", "evictions", "insertions",
                      "rejections", "hit_bytes", "evicted_bytes"):
                setattr(total, f, getattr(total, f) + getattr(c.stats, f))
        return total

    def contains(self, path: str, worker_id: int = 0) -> bool:
        return path in self.cache_for(worker_id)

    def __contains__(self, path: str) -> bool:
        return any(path in c for c in self.member_caches())

    # ---- the attributed read/insert surface --------------------------------
    def _job_ledger(self, job: Optional[str]) -> CacheStats:
        """The (lazily created) ledger for ``job`` — ``None`` books onto
        :attr:`DEFAULT_JOB` so job sums always equal tier totals."""
        key = job if job is not None else self.DEFAULT_JOB
        st = self.job_stats.get(key)
        if st is None:
            st = self.job_stats[key] = CacheStats()
        return st

    def get(self, path: str, *, worker_id: int = 0,
            require_data: bool = False,
            job: Optional[str] = None) -> Optional[CachedEntry]:
        """Member-cache ``get`` plus per-worker and per-job attribution
        (a disabled tier attributes nothing, mirroring
        ``ByteCache.get``)."""
        cache = self.cache_for(worker_id)
        entry = cache.get(path, require_data=require_data)
        if cache.enabled:
            with self._lock:
                st = self.worker_stats[worker_id]
                jt = self._job_ledger(job)
                if entry is None:
                    st.misses += 1
                    jt.misses += 1
                else:
                    st.hits += 1
                    st.hit_bytes += entry.size
                    jt.hits += 1
                    jt.hit_bytes += entry.size
        return entry

    def put(self, path: str, data: Optional[bytes], *,
            size: Optional[int] = None, worker_id: int = 0,
            job: Optional[str] = None) -> int:
        """Insert through the worker's member cache; returns evictions.
        Insert/eviction attribution lands on the inserting worker (and
        its job)."""
        cache = self.cache_for(worker_id)
        evicted = cache.put(path, data, size=size)
        if cache.enabled:
            with self._lock:
                st = self.worker_stats[worker_id]
                st.insertions += 1
                st.evictions += evicted
                jt = self._job_ledger(job)
                jt.insertions += 1
                jt.evictions += evicted
        return evicted

    # ---- maintenance -------------------------------------------------------
    def invalidate(self, path: str) -> bool:
        hit = False
        for c in self.member_caches():
            hit = c.invalidate(path) or hit
        return hit

    def clear(self) -> None:
        for c in self.member_caches():
            c.clear()

    def reset_stats(self) -> None:
        """Reset the per-worker and per-job attribution ledgers
        (member-cache lifetime stats are theirs to keep; benchmarks
        compare fresh tiers)."""
        with self._lock:
            for w in self.worker_ids:
                self.worker_stats[w] = CacheStats()
            self.job_stats.clear()

    # ---- clairvoyant futures (Belady) --------------------------------------
    def set_future(self, trace: Sequence[str]) -> bool:
        """Install a node-merged future demand trace on every member cache
        that supports one (Belady). Under ``scope="node"`` the shared
        cache sees all co-located workers' interleaved accesses, so the
        trace must be the node-merged sequence
        (:meth:`repro.fanstore.prefetch.EpochSchedule.node_future`).
        Returns True when at least one member took it."""
        fed = False
        for c in self.member_caches():
            if hasattr(c, "set_future"):
                c.set_future(trace)
                fed = True
        return fed

    def set_worker_future(self, worker_id: int,
                          trace: Sequence[str]) -> bool:
        """Install one worker's own future trace on ITS member cache
        (meaningful under ``scope="worker"``; under ``scope="node"`` this
        would clobber the shared oracle — use :meth:`set_future`)."""
        cache = self.cache_for(worker_id)
        if hasattr(cache, "set_future"):
            cache.set_future(trace)
            return True
        return False

    def extend_future(self, trace: Sequence[str]) -> bool:
        """Append another epoch's node-merged trace after the installed
        one (cross-epoch stitching: clairvoyant eviction stays exact at
        the epoch seam instead of seeing next-use = infinity for every
        path once the current epoch's occurrences drain)."""
        fed = False
        for c in self.member_caches():
            if hasattr(c, "extend_future"):
                c.extend_future(trace)
                fed = True
        return fed

    def extend_worker_future(self, worker_id: int,
                             trace: Sequence[str]) -> bool:
        """Append one worker's next-epoch trace on ITS member cache
        (the ``scope="worker"`` counterpart of :meth:`extend_future`)."""
        cache = self.cache_for(worker_id)
        if hasattr(cache, "extend_future"):
            cache.extend_future(trace)
            return True
        return False


CACHE_POLICIES: Dict[str, Callable[..., ByteCache]] = {
    "lru": ByteLRUCache,
    "belady": BeladyCache,
    "2q": TwoQCache,
    "lfu": LFUCache,
    "arc": ArcCache,
    "gdsf": GdsfCache,
    "predictive": PredictiveCache,
}


def make_cache(policy: Union[str, Callable[..., ByteCache]],
               capacity_bytes: int, **options: object) -> ByteCache:
    """Build a cache for ``policy`` — a registry name (see
    ``CACHE_POLICIES``) or any callable ``capacity_bytes -> ByteCache``.
    ``options`` are forwarded to the constructor (per-policy knobs, e.g.
    ``kin``/``kout`` for 2Q or ``alpha`` for the predictor) — the
    transport for ``ClusterSpec.cache_policy_options``."""
    if callable(policy):
        return policy(capacity_bytes, **options)
    try:
        ctor = CACHE_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown cache policy {policy!r}; "
            f"known: {sorted(CACHE_POLICIES)}") from None
    return ctor(capacity_bytes, **options)
