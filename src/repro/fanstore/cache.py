"""Client-side read cache layer (beyond-paper; Hoard-style).

The paper evicts a file's decompressed bytes the moment its refcount hits
zero (uniform random access defeats LRU *within one epoch over a dataset
larger than RAM*). But at cluster scale the dominant win — per Hoard
(Pinto et al., 2018) — is a client-side cache absorbing repeated remote
reads: hot validation files, small shared metadata, and any skewed access
pattern. This module is that tier: per-node, byte-budgeted caches that sit
in front of the transport. Hits, misses, and evictions are reported through
the node's ``NodeClock`` (see :mod:`repro.fanstore.accounting`) so
benchmarks can plot hit rate against the byte budget.

Three eviction policies behind one interface (``ByteCache``):

* ``ByteLRUCache``   — classic least-recently-used. Uniform random access
  defeats it within an epoch; it is the baseline the others beat.
* ``BeladyCache``    — clairvoyant MIN/OPT: given the epoch's future access
  trace (from :class:`repro.fanstore.prefetch.EpochSchedule`), evict the
  resident whose next use is farthest away, and refuse admission when the
  incoming payload is itself the farthest. This is the optimal offline
  policy and the natural partner of the clairvoyant prefetch scheduler.
* ``TwoQCache``      — 2Q (Johnson & Shasha '94): a FIFO probation queue
  absorbs one-shot scans, a ghost list remembers recently-evicted keys, and
  only re-referenced files are promoted to the protected LRU main queue.
  Scan-resistant without needing the future.

``FanStoreCluster(cache_policy=...)`` selects the policy via
:func:`make_cache`. Caches are OFF by default (``capacity_bytes=0``
disabled) so the paper-faithful read path is unchanged unless a deployment
opts in.

Ownership sits one level up, in :class:`NodeCacheTier`: the paper's
deployment runs SEVERAL training workers per node (§3), and per Hoard the
node-local cache should be one shared tier across all of them — a payload
fetched by any co-located worker serves every other. The tier owns the
node's byte budget (``cache_scope="node"`` = one shared policy cache;
``"worker"`` = private per-worker splits of the same total, the baseline
the shared tier beats) and keeps a per-worker hit/miss attribution ledger
beside the cache's own totals, locked so the transport pool and socket
serving threads can hit it concurrently.
"""
from __future__ import annotations

import threading
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple, Union


@dataclass(frozen=True)
class CachedEntry:
    """One cache slot. ``data is None`` marks a size-only entry: benchmarks
    running with ``materialize=False`` model cache behavior without holding
    payload copies, so only the byte budget and timeline are exercised."""
    data: Optional[bytes]
    size: int


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    insertions: int = 0
    rejections: int = 0       # admission refused (Belady: farthest next use)
    hit_bytes: int = 0
    evicted_bytes: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class ByteCache:
    """Byte-budgeted cache over immutable payloads (input files never
    change, so entries are only evicted for space; the one exception is
    :meth:`invalidate`, which output GC/unlink uses to drop a deleted
    file's payload).

    Subclasses implement one seam, :meth:`_pick_victim`, and may override
    the access/admission hooks. Two event ledgers exist by design:
    ``self.stats`` is the cache's own lifetime view (survives
    ``FanStoreCluster.reset_clocks``), while the cluster mirrors the same
    events onto the reading node's ``NodeClock`` (per-benchmark-run
    timeline). The cluster's ``read_many``/``prefetch_window`` are the call
    sites responsible for keeping the mirror in step — identically for
    every policy."""

    def __init__(self, capacity_bytes: int):
        if capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        self.capacity_bytes = capacity_bytes
        self._entries: "OrderedDict[str, CachedEntry]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.stats = CacheStats()

    @property
    def enabled(self) -> bool:
        return self.capacity_bytes > 0

    @property
    def used_bytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, path: str) -> bool:
        with self._lock:
            return path in self._entries

    # -- policy hooks (subclass seam) ---------------------------------------
    def _on_hit(self, path: str) -> None:
        """Access bookkeeping on a hit (default: MRU promotion)."""
        self._entries.move_to_end(path)

    def _on_miss(self, path: str) -> None:
        """Access bookkeeping on a demand miss (default: none)."""

    def _admit(self, path: str, nbytes: int) -> bool:
        """Whether to insert this payload at all (default: always)."""
        return True

    def _note_insert(self, path: str, nbytes: int, *,
                     replaced: bool) -> None:
        """Pre-insert bookkeeping hook, called under the lock after
        admission (2Q routes the key into its queues here)."""

    def _pick_victim(self) -> str:
        """Return the resident path to evict (called under the lock while
        over budget). Default: LRU order."""
        return next(iter(self._entries))

    # -- shared machinery ---------------------------------------------------
    def get(self, path: str, *,
            require_data: bool = False) -> Optional[CachedEntry]:
        """Return the cached entry (marking the access) or None on miss.

        ``require_data=True`` treats size-only entries as misses (no hit
        stats, no access promotion): a materializing read cannot be served
        by a modeling placeholder and will refetch-and-replace it.
        """
        if not self.enabled:
            return None
        with self._lock:
            entry = self._entries.get(path)
            if entry is None or (require_data and entry.data is None):
                self.stats.misses += 1
                self._on_miss(path)
                return None
            self._on_hit(path)
            self.stats.hits += 1
            self.stats.hit_bytes += entry.size
            return entry

    def put(self, path: str, data: Optional[bytes], *,
            size: Optional[int] = None) -> int:
        """Insert a payload, evicting policy-chosen entries past the byte
        budget.

        ``data=None`` requires an explicit ``size`` (size-only modeling
        entry). Returns the number of evictions this insert caused.
        Payloads larger than the whole budget are not cached (they would
        evict everything for a single-use entry), and a policy may refuse
        admission outright (Belady does when the payload's next use is
        farther than every resident's).
        """
        nbytes = len(data) if data is not None else size
        if nbytes is None:
            raise ValueError("size is required for size-only entries")
        if not self.enabled or nbytes > self.capacity_bytes:
            return 0
        evicted = 0
        with self._lock:
            if not self._admit(path, nbytes):
                self.stats.rejections += 1
                return 0
            old = self._entries.pop(path, None)
            if old is not None:
                self._bytes -= old.size
            self._note_insert(path, nbytes, replaced=old is not None)
            self._entries[path] = CachedEntry(data=data, size=nbytes)
            self._bytes += nbytes
            self.stats.insertions += 1
            while self._bytes > self.capacity_bytes:
                victim = self._pick_victim()
                entry = self._entries.pop(victim)
                self._bytes -= entry.size
                self._evicted(victim, entry)
                self.stats.evictions += 1
                self.stats.evicted_bytes += entry.size
                evicted += 1
        return evicted

    def _evicted(self, path: str, entry: CachedEntry) -> None:
        """Post-eviction hook (2Q moves the key to its ghost list)."""

    def _forget(self, path: str) -> None:
        """Post-invalidation hook: drop any per-path policy state (2Q
        removes the key from its probation/ghost queues). Unlike
        ``_evicted``, the entry must leave no trace — the file is gone."""

    def invalidate(self, path: str) -> bool:
        """Drop a path outright (output GC/unlink): NOT an eviction — no
        victim policy, no eviction counters, no ghost history. Inputs are
        immutable so only unlinked outputs ever need this. Returns True
        when the path was resident."""
        with self._lock:
            entry = self._entries.pop(path, None)
            if entry is None:
                return False
            self._bytes -= entry.size
            self._forget(path)
            return True

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0


class ByteLRUCache(ByteCache):
    """Byte-budgeted LRU — the PR 1 policy, unchanged behavior."""


class BeladyCache(ByteCache):
    """Clairvoyant MIN/OPT eviction from a known future access trace.

    :meth:`set_future` installs the epoch's demand-access sequence (e.g.
    ``EpochSchedule.future_paths(requester)``). Every demand access (a
    ``get``, hit or miss) consumes that path's current occurrence; the
    front of each path's remaining-occurrence queue is its *next* use.
    Eviction removes the resident with the farthest next use; admission is
    refused when the incoming payload itself has the farthest next use
    (inserting it would be strictly worse than not caching it — the step
    LRU-family policies cannot take). Paths absent from the trace (or past
    their last use) have next use = infinity and are evicted first.

    ``put`` does NOT consume occurrences, so prefetch inserts ahead of the
    demand stream leave reuse distances exact.
    """

    _NEVER = float("inf")

    def __init__(self, capacity_bytes: int,
                 future: Optional[Sequence[str]] = None):
        super().__init__(capacity_bytes)
        self._future: Dict[str, Deque[int]] = {}
        if future is not None:
            self.set_future(future)

    def set_future(self, trace: Sequence[str]) -> None:
        """Install the future demand-access sequence (replaces any prior)."""
        with self._lock:
            fut: Dict[str, Deque[int]] = {}
            for t, path in enumerate(trace):
                fut.setdefault(path, deque()).append(t)
            self._future = fut

    def extend_future(self, trace: Sequence[str]) -> None:
        """Append another epoch's trace after the current one."""
        with self._lock:
            base = max((q[-1] for q in self._future.values() if q),
                       default=-1) + 1
            for t, path in enumerate(trace):
                self._future.setdefault(path, deque()).append(base + t)

    def _next_use(self, path: str) -> float:
        q = self._future.get(path)
        return q[0] if q else self._NEVER

    def _consume(self, path: str) -> None:
        q = self._future.get(path)
        if q:
            q.popleft()

    def _on_hit(self, path: str) -> None:
        self._consume(path)

    def _on_miss(self, path: str) -> None:
        self._consume(path)

    def _admit(self, path: str, nbytes: int) -> bool:
        # a resident entry being replaced (e.g. a size-only placeholder
        # upgraded by a materializing read) frees its own bytes first and
        # must not compete against itself in the farthest-use comparison
        old = self._entries.get(path)
        occupied = self._bytes - (old.size if old is not None else 0)
        if occupied + nbytes <= self.capacity_bytes:
            return True      # fits in spare capacity: caching is free
        nu = self._next_use(path)
        if nu == self._NEVER:
            return False     # would evict useful bytes for a dead entry
        # admit only if some resident is reused later than the newcomer —
        # otherwise evicting for it is strictly worse than bypassing
        farthest = max((self._next_use(p) for p in self._entries
                        if p != path), default=self._NEVER)
        return nu < farthest

    def _pick_victim(self) -> str:
        return max(self._entries, key=self._next_use)


class TwoQCache(ByteCache):
    """2Q: FIFO probation (A1in) + ghost history (A1out) + protected LRU
    main queue (Am).

    First-touch payloads enter A1in and, if never re-referenced, FIFO out
    through the A1out ghost list (keys only, no bytes) without ever
    touching Am — a one-shot scan cannot pollute the protected set. A hit
    while the key is in A1out proves reuse beyond the probation horizon, so
    the refetched payload is admitted straight into Am. ``kin`` is the
    byte-budget fraction reserved for probation, ``kout`` the ghost-list
    size as a fraction of the budget (counting remembered *bytes*).
    """

    def __init__(self, capacity_bytes: int, *, kin: float = 0.25,
                 kout: float = 0.5):
        super().__init__(capacity_bytes)
        if not 0.0 < kin < 1.0:
            raise ValueError("kin must be in (0, 1)")
        self.kin_bytes = max(1, int(capacity_bytes * kin))
        self.kout_bytes = max(1, int(capacity_bytes * kout))
        self._a1in: "OrderedDict[str, int]" = OrderedDict()   # path -> size
        self._ghost: "OrderedDict[str, int]" = OrderedDict()  # path -> size
        self._ghost_bytes = 0
        self._a1in_bytes = 0

    def _on_hit(self, path: str) -> None:
        # hits in Am refresh recency; hits in probation do NOT promote —
        # promotion requires surviving into the ghost list first (classic
        # full 2Q), which is exactly what filters one-shot scans
        if path not in self._a1in:
            self._entries.move_to_end(path)

    def _remember_ghost(self, path: str, size: int) -> None:
        old = self._ghost.pop(path, None)
        if old is not None:
            self._ghost_bytes -= old
        self._ghost[path] = size
        self._ghost_bytes += size
        while self._ghost_bytes > self.kout_bytes and len(self._ghost) > 1:
            _, s = self._ghost.popitem(last=False)
            self._ghost_bytes -= s

    def _note_insert(self, path: str, nbytes: int, *,
                     replaced: bool) -> None:
        if replaced:
            if path in self._a1in:
                # refreshed while on probation (e.g. size-only upgrade):
                # stays on probation at its old queue position
                self._a1in_bytes += nbytes - self._a1in[path]
                self._a1in[path] = nbytes
        elif path in self._ghost:
            # reuse beyond the probation horizon: straight to the
            # protected main queue
            self._ghost_bytes -= self._ghost.pop(path)
        else:
            self._a1in[path] = nbytes           # first touch -> probation
            self._a1in_bytes += nbytes

    def _pick_victim(self) -> str:
        # drain probation first while it is over its share (or the main
        # queue is empty); otherwise evict the LRU of the protected queue
        if self._a1in and (self._a1in_bytes > self.kin_bytes
                           or len(self._a1in) == len(self._entries)):
            return next(iter(self._a1in))
        for path in self._entries:              # LRU order, skip probation
            if path not in self._a1in:
                return path
        return next(iter(self._entries))

    def _evicted(self, path: str, entry: CachedEntry) -> None:
        if path in self._a1in:
            self._a1in_bytes -= self._a1in.pop(path)
            self._remember_ghost(path, entry.size)

    def _forget(self, path: str) -> None:
        if path in self._a1in:
            self._a1in_bytes -= self._a1in.pop(path)
        if path in self._ghost:
            self._ghost_bytes -= self._ghost.pop(path)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._a1in.clear()
            self._ghost.clear()
            self._bytes = self._a1in_bytes = self._ghost_bytes = 0


class NodeCacheTier:
    """One node's cache tier, shared by every co-located worker.

    The tier owns the node's whole byte budget and the policy choice; the
    cluster owns one tier per node (replacing the old per-node
    ``Dict[int, ByteCache]`` whose single cache was private to whoever
    constructed the cluster). Two scopes:

    * ``scope="node"`` — ONE policy cache: a payload fetched by any
      worker is a RAM hit for all of them, and the budget pools (the
      Hoard shared-tier win, pinned by benchmarks against the private
      baseline at equal total bytes).
    * ``scope="worker"`` — private per-worker caches at
      ``capacity_bytes // workers`` each: same total budget, no sharing.
      This is the comparison baseline, and also an isolation mode for
      workers with disjoint working sets.

    Per-worker ATTRIBUTION rides beside the member caches' own stats:
    every ``get`` books its hit/miss (and hit bytes) onto that worker's
    :class:`CacheStats` under the tier lock, so "which worker's reads
    hit" is answerable while the node totals stay the tier truth — the
    sums match the member-cache totals by construction (pinned in
    tests). The lock matters: transport-pool workers and socket serving
    threads hit one tier concurrently.
    """

    def __init__(self, node_id: int, policy: Union[str, Callable[[int], ByteCache]],
                 capacity_bytes: int, *, workers: int = 1,
                 scope: str = "node"):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if scope not in ("node", "worker"):
            raise ValueError(f"unknown cache scope {scope!r}; "
                             f"known: ['node', 'worker']")
        self.node_id = node_id
        self.policy = policy
        self.scope = scope
        self.capacity_bytes = capacity_bytes
        self.worker_ids = tuple(range(workers))
        if scope == "node":
            shared = make_cache(policy, capacity_bytes)
            self._members: Dict[int, ByteCache] = {
                w: shared for w in self.worker_ids}
        else:
            per = capacity_bytes // workers
            self._members = {w: make_cache(policy, per)
                             for w in self.worker_ids}
        self._lock = threading.Lock()
        self.worker_stats: Dict[int, CacheStats] = {
            w: CacheStats() for w in self.worker_ids}

    # ---- views -------------------------------------------------------------
    def cache_for(self, worker_id: int = 0) -> ByteCache:
        """The member cache serving ``worker_id`` (the shared cache under
        ``scope="node"``; that worker's private split otherwise)."""
        try:
            return self._members[worker_id]
        except KeyError:
            raise ValueError(
                f"worker_id {worker_id} outside this tier's "
                f"{len(self.worker_ids)} workers") from None

    def member_caches(self) -> List[ByteCache]:
        """Distinct member caches (one under ``scope="node"``)."""
        seen: List[ByteCache] = []
        for c in self._members.values():
            if all(c is not s for s in seen):
                seen.append(c)
        return seen

    @property
    def enabled(self) -> bool:
        return any(c.enabled for c in self._members.values())

    @property
    def used_bytes(self) -> int:
        return sum(c.used_bytes for c in self.member_caches())

    @property
    def stats(self) -> CacheStats:
        """Tier totals: the member caches' stats summed (identical to the
        single cache's stats under ``scope="node"``)."""
        total = CacheStats()
        for c in self.member_caches():
            for f in ("hits", "misses", "evictions", "insertions",
                      "rejections", "hit_bytes", "evicted_bytes"):
                setattr(total, f, getattr(total, f) + getattr(c.stats, f))
        return total

    def contains(self, path: str, worker_id: int = 0) -> bool:
        return path in self.cache_for(worker_id)

    def __contains__(self, path: str) -> bool:
        return any(path in c for c in self.member_caches())

    # ---- the attributed read/insert surface --------------------------------
    def get(self, path: str, *, worker_id: int = 0,
            require_data: bool = False) -> Optional[CachedEntry]:
        """Member-cache ``get`` plus per-worker attribution (a disabled
        tier attributes nothing, mirroring ``ByteCache.get``)."""
        cache = self.cache_for(worker_id)
        entry = cache.get(path, require_data=require_data)
        if cache.enabled:
            with self._lock:
                st = self.worker_stats[worker_id]
                if entry is None:
                    st.misses += 1
                else:
                    st.hits += 1
                    st.hit_bytes += entry.size
        return entry

    def put(self, path: str, data: Optional[bytes], *,
            size: Optional[int] = None, worker_id: int = 0) -> int:
        """Insert through the worker's member cache; returns evictions.
        Insert/eviction attribution lands on the inserting worker."""
        cache = self.cache_for(worker_id)
        evicted = cache.put(path, data, size=size)
        if cache.enabled:
            with self._lock:
                st = self.worker_stats[worker_id]
                st.insertions += 1
                st.evictions += evicted
        return evicted

    # ---- maintenance -------------------------------------------------------
    def invalidate(self, path: str) -> bool:
        hit = False
        for c in self.member_caches():
            hit = c.invalidate(path) or hit
        return hit

    def clear(self) -> None:
        for c in self.member_caches():
            c.clear()

    def reset_stats(self) -> None:
        """Reset the per-worker attribution ledger (member-cache lifetime
        stats are theirs to keep; benchmarks compare fresh tiers)."""
        with self._lock:
            for w in self.worker_ids:
                self.worker_stats[w] = CacheStats()

    # ---- clairvoyant futures (Belady) --------------------------------------
    def set_future(self, trace: Sequence[str]) -> bool:
        """Install a node-merged future demand trace on every member cache
        that supports one (Belady). Under ``scope="node"`` the shared
        cache sees all co-located workers' interleaved accesses, so the
        trace must be the node-merged sequence
        (:meth:`repro.fanstore.prefetch.EpochSchedule.node_future`).
        Returns True when at least one member took it."""
        fed = False
        for c in self.member_caches():
            if hasattr(c, "set_future"):
                c.set_future(trace)
                fed = True
        return fed

    def set_worker_future(self, worker_id: int,
                          trace: Sequence[str]) -> bool:
        """Install one worker's own future trace on ITS member cache
        (meaningful under ``scope="worker"``; under ``scope="node"`` this
        would clobber the shared oracle — use :meth:`set_future`)."""
        cache = self.cache_for(worker_id)
        if hasattr(cache, "set_future"):
            cache.set_future(trace)
            return True
        return False


CACHE_POLICIES: Dict[str, Callable[[int], ByteCache]] = {
    "lru": ByteLRUCache,
    "belady": BeladyCache,
    "2q": TwoQCache,
}


def make_cache(policy: Union[str, Callable[[int], ByteCache]],
               capacity_bytes: int) -> ByteCache:
    """Build a cache for ``policy`` — a registry name ("lru" / "belady" /
    "2q") or any callable ``capacity_bytes -> ByteCache``."""
    if callable(policy):
        return policy(capacity_bytes)
    try:
        return CACHE_POLICIES[policy](capacity_bytes)
    except KeyError:
        raise ValueError(
            f"unknown cache policy {policy!r}; "
            f"known: {sorted(CACHE_POLICIES)}") from None
