"""Client-side read cache layer (beyond-paper; Hoard-style).

The paper evicts a file's decompressed bytes the moment its refcount hits
zero (uniform random access defeats LRU *within one epoch over a dataset
larger than RAM*). But at cluster scale the dominant win — per Hoard
(Pinto et al., 2018) — is a client-side cache absorbing repeated remote
reads: hot validation files, small shared metadata, and any skewed access
pattern. ``ByteLRUCache`` is that tier: a per-node, byte-budgeted LRU that
sits in front of the transport. Hits, misses, and evictions are reported
through the node's ``NodeClock`` (see :mod:`repro.fanstore.accounting`) so
benchmarks can plot hit rate against the byte budget.

The cache is OFF by default (``capacity_bytes=0`` disabled) so the paper-
faithful read path is unchanged unless a deployment opts in.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class CachedEntry:
    """One cache slot. ``data is None`` marks a size-only entry: benchmarks
    running with ``materialize=False`` model cache behavior without holding
    payload copies, so only the byte budget and timeline are exercised."""
    data: Optional[bytes]
    size: int


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    insertions: int = 0
    hit_bytes: int = 0
    evicted_bytes: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class ByteLRUCache:
    """Byte-budgeted LRU over immutable payloads (input files never change,
    so entries are never invalidated — only evicted for space).

    Two event ledgers exist by design: ``self.stats`` is the cache's own
    lifetime view (survives ``FanStoreCluster.reset_clocks``), while the
    cluster mirrors the same events onto the reading node's ``NodeClock``
    (per-benchmark-run timeline). The cluster's ``read_many`` is the single
    call site responsible for keeping the mirror in step."""

    def __init__(self, capacity_bytes: int):
        if capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        self.capacity_bytes = capacity_bytes
        self._entries: "OrderedDict[str, CachedEntry]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.stats = CacheStats()

    @property
    def enabled(self) -> bool:
        return self.capacity_bytes > 0

    @property
    def used_bytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, path: str) -> bool:
        with self._lock:
            return path in self._entries

    def get(self, path: str, *,
            require_data: bool = False) -> Optional[CachedEntry]:
        """Return the cached entry (marking it most-recent) or None on miss.

        ``require_data=True`` treats size-only entries as misses (no hit
        stats, no MRU promotion): a materializing read cannot be served by
        a modeling placeholder and will refetch-and-replace it.
        """
        if not self.enabled:
            return None
        with self._lock:
            entry = self._entries.get(path)
            if entry is None or (require_data and entry.data is None):
                self.stats.misses += 1
                return None
            self._entries.move_to_end(path)
            self.stats.hits += 1
            self.stats.hit_bytes += entry.size
            return entry

    def put(self, path: str, data: Optional[bytes], *,
            size: Optional[int] = None) -> int:
        """Insert a payload, evicting LRU entries past the byte budget.

        ``data=None`` requires an explicit ``size`` (size-only modeling
        entry). Returns the number of evictions this insert caused.
        Payloads larger than the whole budget are not cached (they would
        evict everything for a single-use entry).
        """
        nbytes = len(data) if data is not None else size
        if nbytes is None:
            raise ValueError("size is required for size-only entries")
        if not self.enabled or nbytes > self.capacity_bytes:
            return 0
        evicted = 0
        with self._lock:
            old = self._entries.pop(path, None)
            if old is not None:
                self._bytes -= old.size
            self._entries[path] = CachedEntry(data=data, size=nbytes)
            self._bytes += nbytes
            self.stats.insertions += 1
            while self._bytes > self.capacity_bytes:
                _, victim = self._entries.popitem(last=False)
                self._bytes -= victim.size
                self.stats.evictions += 1
                self.stats.evicted_bytes += victim.size
                evicted += 1
        return evicted

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0
