"""Multi-node FanStore deployment with a modeled interconnect (paper §5.1/§6).

The container has one host, so multi-node behaviour is *simulated*: N
``NodeStore`` instances plus an :class:`InterconnectModel` that accounts the
cost of every remote round trip (latency + bytes/bandwidth) the way the
paper's MPI transport would incur it. Benchmarks read the accounted
timelines to produce the aggregate-bandwidth / scaling-efficiency curves of
Figs 5-6; correctness tests exercise the same code paths with accounting
ignored.

Also implemented here, beyond the paper's §5.6 (which punts resilience to
checkpoints):
  * replica failover — with replication factor R>1, reads retry surviving
    owners when a node is marked failed,
  * straggler mitigation — replica choice uses least-loaded-of-owners
    (power-of-two-choices degenerates to this with full knowledge),
  * elastic membership — add/remove nodes and compute a minimal rebalance
    plan (see repro.train.elastic for the planner).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.fanstore.layout import iter_partition, pack_partition
from repro.fanstore.metadata import (FileLocation, MetadataTable, StatRecord,
                                     modulo_placement, path_hash)
from repro.fanstore.store import NodeStore


@dataclass
class InterconnectModel:
    """First-order fabric model: per-message latency + per-byte cost.

    Defaults approximate the paper's CPU cluster (100 Gb/s OPA, ~1.5 us):
    latency_s per round trip, bandwidth_Bps per NIC direction. Local tier
    is modeled with disk_bw_Bps (SSD) and a per-open syscall overhead.
    """
    latency_s: float = 1.5e-6
    bandwidth_Bps: float = 100e9 / 8
    disk_bw_Bps: float = 2.0e9
    open_overhead_s: float = 3e-6
    decompress_Bps: float = 1.5e9     # LZSS-class decode rate per core

    def remote_cost(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_Bps

    def local_cost(self, nbytes: int, *, compressed: bool = False) -> float:
        t = self.open_overhead_s + nbytes / self.disk_bw_Bps
        if compressed:
            t += nbytes / self.decompress_Bps
        return t


@dataclass
class NodeClock:
    """Per-node accounted timeline: what the node spent consuming vs serving."""
    consume_s: float = 0.0
    serve_s: float = 0.0
    bytes_in: int = 0
    bytes_out: int = 0
    local_bytes: int = 0

    @property
    def busy_s(self) -> float:
        # consumption and service contend for the same NIC/cores; a node's
        # makespan is at least each and at most the sum — use max (full overlap)
        # as the optimistic bound the paper's threaded workers approach.
        return max(self.consume_s, self.serve_s)


class FanStoreCluster:
    """N-node transient store with replicated input metadata."""

    def __init__(self, num_nodes: int, *, codec: str = "lzss",
                 interconnect: Optional[InterconnectModel] = None) -> None:
        if num_nodes < 1:
            raise ValueError("need at least one node")
        self.codec = codec
        self.net = interconnect or InterconnectModel()
        self.nodes: Dict[int, NodeStore] = {
            i: NodeStore(i, codec=codec) for i in range(num_nodes)}
        self.metadata = MetadataTable()        # replicated input metadata
        self.output_meta: Dict[int, Dict[str, StatRecord]] = {
            i: {} for i in range(num_nodes)}   # distributed output metadata
        self.output_data: Dict[str, Tuple[int, bytes]] = {}
        self.clocks: Dict[int, NodeClock] = {i: NodeClock() for i in range(num_nodes)}
        self.failed: set = set()
        self._lock = threading.Lock()
        self._next_partition = 0

    # ---- loading -----------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    def live_nodes(self) -> List[int]:
        return [i for i in self.nodes if i not in self.failed]

    def load_partitions(self, partitions: Sequence[bytes], *,
                        replication: int = 1) -> None:
        """Round-robin partitions over nodes with replication factor R.

        Replica r of partition p goes to node (p + r*stride) so replicas never
        co-locate; the input metadata (path -> owner set) is then replicated
        to every node (here: stored once in the shared table — all nodes see
        the identical copy by construction).
        """
        n = self.num_nodes
        if replication > n:
            raise ValueError("replication factor exceeds node count")
        stride = max(1, n // replication)
        for blob in partitions:
            pid = self._next_partition
            self._next_partition += 1
            owners = [(pid + r * stride) % n for r in range(replication)]
            owners = sorted(set(owners))
            for o in owners:
                self.nodes[o].load_partition(pid, blob)
            primary = owners[0]
            rest = tuple(o for o in owners if o != primary)
            for idx, rec in enumerate(iter_partition(blob, codec=self.codec)):
                self.metadata.insert(
                    rec.path, rec.stat,
                    FileLocation(node_id=primary, partition_id=pid,
                                 record_index=idx, replicas=rest))

    def broadcast_directory(self, prefix: str) -> int:
        """Replicate every file under ``prefix`` to all nodes (paper §5.4:
        user-specified directory, e.g. the test set). Returns files copied."""
        prefix = prefix.strip("/")
        copied = 0
        for path in list(self.metadata.paths()):
            if not path.startswith(prefix):
                continue
            st, loc = self.metadata.lookup(path)
            data = self.nodes[loc.node_id].serve_remote(path)
            blob = pack_partition([(path, data)], compress=False)
            pid = self._next_partition
            self._next_partition += 1
            new_replicas = []
            for nid, node in self.nodes.items():
                if nid not in loc.all_owners:
                    node.load_partition(pid, blob)
                    new_replicas.append(nid)
            self.metadata.insert(path, st, FileLocation(
                node_id=loc.node_id, partition_id=loc.partition_id,
                record_index=loc.record_index,
                replicas=tuple(sorted(set(loc.replicas) | set(new_replicas)))))
            copied += 1
        return copied

    # ---- failure / elasticity ----------------------------------------------
    def fail_node(self, node_id: int) -> None:
        self.failed.add(node_id)

    def recover_node(self, node_id: int) -> None:
        self.failed.discard(node_id)

    def unreachable_paths(self) -> List[str]:
        """Input files whose every owner is failed (data loss without R>=2)."""
        lost = []
        for path in self.metadata.paths():
            _, loc = self.metadata.lookup(path)
            if all(o in self.failed for o in loc.all_owners):
                lost.append(path)
        return lost

    # ---- reads ---------------------------------------------------------------
    def _pick_owner(self, loc: FileLocation) -> int:
        owners = [o for o in loc.all_owners if o not in self.failed]
        if not owners:
            raise IOError("all replicas failed")
        # least-loaded replica (straggler mitigation)
        return min(owners, key=lambda o: self.clocks[o].serve_s)

    def read(self, requester: int, path: str, *, materialize: bool = True
             ) -> bytes:
        """Whole-file read as the training process sees it (paper §3.4).

        ``materialize=False`` runs the identical placement + timeline
        accounting but skips the payload copies — used by the scaling
        benchmarks, where 512 nodes x thousands of multi-MB reads would
        spend their wall time in host memcpy instead of the modeled fabric.
        """
        if requester in self.failed:
            raise IOError(f"node {requester} is failed")
        path = path.strip("/")
        hit = self.metadata.lookup(path)
        clock = self.clocks[requester]
        if hit is None:
            # visible-until-finish: check distributed output metadata
            owner = modulo_placement(path, self.num_nodes)
            st = self.output_meta[owner].get(path)
            if st is None:
                raise FileNotFoundError(path)
            _, data = self.output_data[path]
            clock.consume_s += self.net.remote_cost(len(data))
            return data
        st, loc = hit
        compressed = False
        rec = None
        if self.nodes[loc.node_id].has(path):
            rec = self.nodes[loc.node_id].record_for(path)
            compressed = bool(rec and rec.compressed_size)
        size = st.st_size
        stored = rec.stored_size if rec else size
        if self.nodes[requester].has(path):
            if materialize:
                data = self.nodes[requester].open_local(path)
                self.nodes[requester].release(path)
            else:
                data = b""
            clock.consume_s += self.net.local_cost(size, compressed=compressed)
            clock.local_bytes += size
            return data
        owner = self._pick_owner(loc)
        if materialize:
            data = self.nodes[owner].serve_remote(path)
        else:
            data = b""
        clock.consume_s += self.net.remote_cost(stored)
        if compressed:
            clock.consume_s += size / self.net.decompress_Bps
        clock.bytes_in += stored
        oc = self.clocks[owner]
        oc.serve_s += self.net.local_cost(stored) + stored / self.net.bandwidth_Bps
        oc.bytes_out += stored
        return data

    def stat(self, path: str) -> StatRecord:
        st = self.metadata.stat(path)
        if st is not None:
            return st
        owner = modulo_placement(path.strip("/"), self.num_nodes)
        st = self.output_meta[owner].get(path.strip("/"))
        if st is None:
            raise FileNotFoundError(path)
        return st

    def readdir(self, path: str) -> List[str]:
        kids = self.metadata.readdir(path)
        if kids is None:
            raise FileNotFoundError(path)
        return kids

    # ---- writes ---------------------------------------------------------------
    def write_file(self, writer: int, path: str, data: bytes) -> None:
        """open-for-write + write + close, with visible-on-close semantics."""
        path = path.strip("/")
        node = self.nodes[writer]
        node.write_begin(path)
        node.write_append(path, data)
        st, payload = node.write_finish(path)
        owner = modulo_placement(path, self.num_nodes)
        with self._lock:
            if path in self.output_data:
                raise PermissionError(f"{path}: single-write violated")
            self.output_data[path] = (writer, payload)
            self.output_meta[owner][path] = st
        clock = self.clocks[writer]
        if owner != writer:
            clock.consume_s += self.net.remote_cost(200)  # metadata forward
        clock.consume_s += len(payload) / self.net.disk_bw_Bps

    # ---- accounting -----------------------------------------------------------
    def reset_clocks(self) -> None:
        self.clocks = {i: NodeClock() for i in self.nodes}

    def makespan_s(self) -> float:
        return max((c.busy_s for c in self.clocks.values()), default=0.0)

    def aggregate_bandwidth(self) -> float:
        total = sum(c.local_bytes + c.bytes_in for c in self.clocks.values())
        t = self.makespan_s()
        return total / t if t > 0 else 0.0

    def local_hit_rate(self) -> float:
        local = sum(c.local_bytes for c in self.clocks.values())
        total = local + sum(c.bytes_in for c in self.clocks.values())
        return local / total if total else 1.0
