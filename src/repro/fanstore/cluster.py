"""Multi-node FanStore deployment composed from the layered I/O engine.

The container has one host, so multi-node behaviour is *simulated*: N
``NodeStore`` instances wired together by four layers, each independently
pluggable (paper §5.1/§6 plus the beyond-paper scaling seams):

  placement   which node owns a path (ModuloPlacement = paper-faithful
              ``hash % N``; RingPlacement = consistent hashing for
              elasticity) and which replica serves a read
              (least-loaded / power-of-two-choices)
  transport   a pluggable backend behind one verb seam
              (``backend="modeled"|"socket"|"shm"``): the modeled
              in-process wire (InterconnectModel cost accounting), a real
              framed-TCP wire with one serving loop per node, or the
              zero-copy shared-memory fast path for co-located workers —
              all with the batched ``fetch_remote_batch`` that coalesces
              requests per (requester, owner) pair into one round trip
              and a thread-pool future API for async fetch
  cache       optional per-node byte-budget LRU read cache in front of
              both tiers (off by default; Hoard-style client caching)
  accounting  per-node NodeClock (modeled) + WallClock (measured)
              timelines and the cluster aggregates the scaling
              benchmarks plot

The real-wire backends spawn serving loops and keep connections, so a
cluster is a resource: use it as a context manager (or call ``close()``)
to tear the transport down deterministically.

``FanStoreCluster`` composes them behind the same public surface the seed
monolith had (``read``/``stat``/``write_file``/...), plus the batched
``read_many``/``write_many`` APIs the data pipeline, checkpoint writer,
and benchmarks use. Most callers should sit one level higher, on the
descriptor-based :class:`repro.fanstore.api.FanStoreSession`.

Output files are first-class citizens of the namespace: committed payloads
live on the placement owner (``RingPlacement``-routable), reads of them ride
the same local/remote/batched read machinery as inputs, and ``readdir``
merges both namespaces.

Also implemented here, beyond the paper's §5.6 (which punts resilience to
checkpoints): replica failover, straggler mitigation via replica selection,
and elastic membership hooks (see repro.train.elastic for the planner).
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Tuple

from repro.fanstore.accounting import ClusterAccounting, NodeClock
from repro.fanstore.cache import ByteCache, NodeCacheTier
from repro.fanstore.layout import iter_partition, pack_partition
from repro.fanstore.metadata import (FileLocation, MetadataTable, StatRecord,
                                     modulo_placement, path_hash)
from repro.fanstore.placement import Placement, ReplicaSelector
from repro.fanstore.backends import make_backend
from repro.fanstore.backends.modeled import InterconnectModel
from repro.fanstore.spec import ClusterSpec, WorkerContext
from repro.fanstore.store import NodeStore
from repro.fanstore.wire import FetchItem

__all__ = ["FanStoreCluster", "ClusterSpec", "InterconnectModel",
           "NodeClock", "WorkerContext"]


class FanStoreCluster:
    """N-node transient store with replicated input metadata.

    Canonical construction is topology-first::

        spec = ClusterSpec(num_nodes=8, workers_per_node=2,
                           backend="shm", cache_policy="belady",
                           cache_bytes=256 << 20)
        with FanStoreCluster.from_spec(spec) as cluster:
            session = cluster.connect(node_id=3, worker_id=1)

    The legacy ``FanStoreCluster(num_nodes, **kwargs)`` surface is a
    DEPRECATED shim: it builds the same :class:`ClusterSpec` internally
    (so every name is validated up front, with did-you-mean suggestions
    for unknown kwargs) and will be removed once no caller constructs a
    cluster without a spec.
    """

    def __init__(self, num_nodes: Optional[int] = None, *,
                 spec: Optional[ClusterSpec] = None,
                 interconnect: Optional[InterconnectModel] = None,
                 placement: Optional[Placement] = None,
                 selector: Optional[ReplicaSelector] = None,
                 **legacy_kwargs) -> None:
        if spec is not None:
            if legacy_kwargs:
                raise TypeError(
                    "pass either spec= or the legacy kwargs, not both "
                    f"(got {sorted(legacy_kwargs)})")
            if num_nodes is not None and num_nodes != spec.num_nodes:
                raise ValueError(
                    f"num_nodes={num_nodes} disagrees with "
                    f"spec.num_nodes={spec.num_nodes}")
        else:
            if num_nodes is None:
                raise TypeError("num_nodes (or spec=) is required")
            # deprecated kwargs path: capture the soup into a validated
            # spec — unknown names raise with suggestions, registry-backed
            # strings (backend/cache_policy/placement/...) fail HERE
            spec = ClusterSpec.from_kwargs(
                num_nodes, interconnect=interconnect, placement=placement,
                selector=selector, **legacy_kwargs)
        self.spec = spec
        self.codec = spec.codec
        # runtime-object overrides beat the spec's serializable names so
        # custom placements/selectors/interconnects remain first-class
        self.net = interconnect if interconnect is not None \
            else spec.make_interconnect()
        self.nodes: Dict[int, NodeStore] = {
            i: NodeStore(i, codec=spec.codec)
            for i in range(spec.num_nodes)}
        self.metadata = MetadataTable()        # replicated input metadata
        self.output_meta: Dict[int, Dict[str, StatRecord]] = {
            i: {} for i in range(spec.num_nodes)}  # per-owner output shards
        # replicated view of committed outputs (path -> stat + owning node);
        # payloads live on the placement owner's NodeStore output tier, NOT
        # on the writer — placement is routed end-to-end through the ring
        self.output_ns = MetadataTable()
        self.accounting = ClusterAccounting(range(spec.num_nodes))
        self.placement: Placement = placement or spec.make_placement()
        self.selector: ReplicaSelector = selector or spec.make_selector()
        self.backend = spec.backend
        # wire tuning declared on the spec reaches every backend; explicit
        # backend_options still win (they are the per-experiment override)
        backend_options = dict(spec.backend_options)
        backend_options.setdefault("stripes", spec.wire_stripes)
        backend_options.setdefault("wire_codec", spec.wire_codec)
        # the backend accrues clocks under the accounting lock, so
        # snapshot/reset/flush never race a half-applied accrual
        backend_options.setdefault("lock", self.accounting.lock)
        self.transport = make_backend(spec.backend, self.net, self.nodes,
                                      self.accounting.clocks,
                                      wall=self.accounting.wall,
                                      num_threads=spec.io_threads,
                                      **backend_options)
        # observability plane: one thread-safe collector per cluster. It
        # carries app-level series (record_metric) under its OWN lock and
        # bridges every accounting ledger via ClusterAccounting.snapshot()
        # at flush time — recording never contends the clock lock.
        from repro.fanstore.metrics import MetricsCollector
        self.metrics = MetricsCollector(accounting=self.accounting,
                                        cluster=self)
        self.cache_policy = spec.cache_policy
        self.workers_per_node = spec.workers_per_node
        # ONE cache tier per node, shared by its co-located workers (the
        # old per-node private ByteCache dict lives on underneath, as the
        # tier's members; see the legacy `caches` view below)
        self.cache_tiers: Dict[int, NodeCacheTier] = {
            i: NodeCacheTier(i, spec.cache_policy, spec.cache_bytes,
                             workers=spec.workers_per_node,
                             scope=spec.cache_scope,
                             policy_options=spec.cache_policy_options)
            for i in range(spec.num_nodes)}
        self.failed: set = set()
        self._lock = threading.Lock()
        self._next_partition = 0
        # fault tolerance: the injector (None unless spec.faults is set)
        # rides the transport seam; strikes count consecutive transport
        # failures per owner, and at spec.fault_threshold the owner is
        # marked failed cluster-wide (routing, prefetch, and the socket
        # backend's connections all drop it)
        self.faults = None
        policy = spec.make_fault_policy()
        if policy is not None:
            from repro.fanstore.faults import FaultInjector
            self.faults = FaultInjector(policy)
        self.transport.set_faults(self.faults)
        self.fault_threshold = spec.fault_threshold
        self._owner_strikes: Dict[int, int] = {}

    @classmethod
    def from_spec(cls, spec: ClusterSpec, *,
                  interconnect: Optional[InterconnectModel] = None,
                  placement: Optional[Placement] = None,
                  selector: Optional[ReplicaSelector] = None
                  ) -> "FanStoreCluster":
        """The canonical constructor: declared topology in, cluster out.
        The override kwargs accept runtime OBJECTS (custom placement /
        selector / interconnect) that have no serializable spec name."""
        return cls(spec=spec, interconnect=interconnect,
                   placement=placement, selector=selector)

    # ---- sessions (topology-first client surface) --------------------------
    def connect(self, node_id: int, worker_id: int = 0, **session_kwargs):
        """Open a per-worker session: the one client surface co-located
        workers share a node cache tier through. ``session_kwargs`` pass
        to :class:`repro.fanstore.api.FanStoreSession` (``mount=``,
        ``lane=``, the serving plane's ``read_lane=``/``tenant=``, and
        the multi-job seam's ``job=`` — two jobs, e.g. train + eval,
        attach to one namespace/tier with per-job cache attribution)."""
        ctx = WorkerContext(node_id, worker_id)
        if ctx.node_id not in self.nodes:
            raise ValueError(f"node_id {node_id} outside the "
                             f"{self.num_nodes}-node topology")
        if ctx.worker_id >= self.workers_per_node:
            raise ValueError(
                f"worker_id {worker_id} outside workers_per_node="
                f"{self.workers_per_node} (declare more workers in the "
                f"ClusterSpec)")
        from repro.fanstore.api import FanStoreSession
        return FanStoreSession(self, node_id, worker_id=worker_id,
                               **session_kwargs)

    # ---- composition plumbing ----------------------------------------------
    @property
    def clocks(self) -> Dict[int, NodeClock]:
        return self.accounting.clocks

    @property
    def caches(self) -> Dict[int, ByteCache]:
        """DEPRECATED single-worker view: worker 0's member cache per node
        (the shared cache itself under ``cache_scope="node"``). Kept for
        pre-topology callers; new code addresses ``cache_tiers``."""
        return {i: t.cache_for(0) for i, t in self.cache_tiers.items()}

    def clear_caches(self) -> None:
        """Drop every tier's entries (benchmark epoch resets)."""
        for tier in self.cache_tiers.values():
            tier.clear()

    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    def live_nodes(self) -> List[int]:
        return [i for i in self.nodes if i not in self.failed]

    # ---- loading -----------------------------------------------------------
    def load_partitions(self, partitions: Sequence[bytes], *,
                        replication: Optional[int] = None,
                        by_placement: bool = False) -> None:
        """Distribute partitions over nodes with replication factor R
        (default: the topology's declared ``spec.replication``).

        Default placement is round-robin: replica r of partition p goes to
        node (p + r*stride) so replicas never co-locate. With
        ``by_placement=True`` the cluster's ``Placement`` policy assigns
        owners instead (``replica_set(f"partition:{pid}", R)``): under
        ``RingPlacement`` this makes input placement elastic — adding a
        node remaps only ~1/N partitions, with no metadata reshuffle for
        the rest (the ROADMAP's elastic-membership seam).

        Either way the input metadata (path -> owner set) is replicated to
        every node (here: stored once in the shared table — all nodes see
        the identical copy by construction).
        """
        n = self.num_nodes
        if replication is None:
            replication = self.spec.replication
        if replication > n:
            raise ValueError("replication factor exceeds node count")
        stride = max(1, n // replication)
        for blob in partitions:
            pid = self._next_partition
            self._next_partition += 1
            if by_placement:
                # replica_set order matters: its head is the placement's
                # primary (under RingPlacement, the ring successor — the
                # node that keeps the partition when membership changes)
                owners = list(dict.fromkeys(self.placement.replica_set(
                    f"partition:{pid:08d}", replication)))
            else:
                owners = sorted(set(
                    (pid + r * stride) % n for r in range(replication)))
            for o in owners:
                self.nodes[o].load_partition(pid, blob)
            primary = owners[0]
            rest = tuple(o for o in owners if o != primary)
            for idx, rec in enumerate(iter_partition(blob, codec=self.codec)):
                self.metadata.insert(
                    rec.path, rec.stat,
                    FileLocation(node_id=primary, partition_id=pid,
                                 record_index=idx, replicas=rest))

    def broadcast_directory(self, prefix: str) -> int:
        """Replicate every file under ``prefix`` to all nodes (paper §5.4:
        user-specified directory, e.g. the test set). Returns files copied."""
        prefix = prefix.strip("/")
        copied = 0
        for path in list(self.metadata.paths()):
            if not path.startswith(prefix):
                continue
            st, loc = self.metadata.lookup(path)
            data = self.nodes[loc.node_id].serve_remote(path)
            blob = pack_partition([(path, data)], compress=False)
            pid = self._next_partition
            self._next_partition += 1
            new_replicas = []
            for nid, node in self.nodes.items():
                if nid not in loc.all_owners:
                    node.load_partition(pid, blob)
                    new_replicas.append(nid)
            self.metadata.insert(path, st, FileLocation(
                node_id=loc.node_id, partition_id=loc.partition_id,
                record_index=loc.record_index,
                replicas=tuple(sorted(set(loc.replicas) | set(new_replicas)))))
            copied += 1
        return copied

    # ---- failure / elasticity ----------------------------------------------
    def mark_failed(self, node_id: int) -> None:
        """Take ``node_id`` out of the membership: routing skips it
        (``_choose_owner`` / prefetch schedules), and the transport drops
        its per-peer state (the socket backend closes the dead peer's
        serving loop and every stripe dialed to or from it) so stale
        connections fail fast instead of hanging. Idempotent. Reached
        organically by the strike counter on the failover read path, or
        called directly by a membership service / test / benchmark."""
        first = node_id not in self.failed
        self.failed.add(node_id)
        if first:
            self.transport.drop_node(node_id)

    def mark_joined(self, node_id: int) -> None:
        """Admit ``node_id`` to the membership — a recovered node or a
        brand-new id (elastic scale-out). New ids get an empty
        ``NodeStore``, clocks, a cache tier, and (under ``RingPlacement``)
        a seat on the ring; recovered ids keep their stores. Either way
        the strike ledger is cleared and the transport (re)opens the
        peer. Data movement is NOT implicit — call :meth:`heal` to
        restore replication onto the new member."""
        if node_id not in self.nodes:
            self.nodes[node_id] = NodeStore(node_id, codec=self.codec)
            self.accounting.add_node(node_id)
            self.output_meta.setdefault(node_id, {})
            self.cache_tiers[node_id] = NodeCacheTier(
                node_id, self.spec.cache_policy, self.spec.cache_bytes,
                workers=self.spec.workers_per_node,
                scope=self.spec.cache_scope,
                policy_options=self.spec.cache_policy_options)
            if hasattr(self.placement, "add_node"):
                self.placement.add_node(node_id)
        self.failed.discard(node_id)
        with self._lock:
            self._owner_strikes.pop(node_id, None)
        self.transport.ensure_node(node_id)

    # legacy names (pre-churn API); same transitions
    def fail_node(self, node_id: int) -> None:
        self.mark_failed(node_id)

    def recover_node(self, node_id: int) -> None:
        self.mark_joined(node_id)

    def replicate_partition(self, pid: int, src: int, dst: int, *,
                            lane: str = "write") -> int:
        """Copy partition ``pid`` from ``src`` onto ``dst`` through the
        write path (real wire cost on the concurrent write lane), then
        extend every affected file's replica set so failover reads see
        the restored copy immediately. Returns bytes shipped."""
        blob = self.nodes[src].partition_blob(pid)
        name = f".rebalance/partition_{pid:08d}"
        item = FetchItem(path=name, size=len(blob), stored=len(blob))
        if src == dst:
            return 0
        self.transport.put_remote_batch(src, dst, [(item, blob)],
                                        lane=lane, round_trips=1)
        # the shipment paid the wire; the staged copy is install-only
        self.nodes[dst].drop_staging(src, name)
        self.nodes[dst].load_partition(pid, blob)
        with self._lock:
            for path in list(self.metadata.paths()):
                st, loc = self.metadata.lookup(path)
                if loc.partition_id != pid or dst in loc.all_owners:
                    continue
                self.metadata.insert(path, st, FileLocation(
                    node_id=loc.node_id, partition_id=pid,
                    record_index=loc.record_index,
                    replicas=tuple(loc.replicas) + (dst,)))
        return len(blob)

    def replicate_output(self, path: str, src: int, dst: int, *,
                         lane: str = "write") -> int:
        """Copy a committed output's payload from ``src`` onto ``dst``
        through the write path (real wire cost on the concurrent write
        lane), then extend its replica set so failover reads see the
        restored copy immediately — the output-tier mirror of
        :meth:`replicate_partition` (PR-7 left outputs single-owner).
        Returns bytes shipped."""
        path = path.strip("/")
        hit = self.output_ns.lookup(path)
        if hit is None:
            raise FileNotFoundError(path)
        st, loc = hit
        if src == dst or dst in loc.all_owners:
            return 0
        payload = self.nodes[src].serve_remote(path)
        item = FetchItem(path=path, size=len(payload), stored=len(payload))
        self.transport.put_remote_batch(src, dst, [(item, payload)],
                                        lane=lane, round_trips=1)
        # the shipment staged the chunk under (src, path); installing it
        # into dst's committed output tier is the local half of the copy
        self.nodes[dst].commit_output(src, path)
        with self._lock:
            cur = self.output_ns.lookup(path)
            if cur is None:          # unlinked while the copy was in flight
                self.nodes[dst].drop_output(path)
                return 0
            st, loc = cur
            if dst not in loc.all_owners:
                self.output_ns.insert(path, st, FileLocation(
                    node_id=loc.node_id, partition_id=loc.partition_id,
                    record_index=loc.record_index,
                    replicas=tuple(loc.replicas) + (dst,)))
            self.output_meta[dst][path] = st
        return len(payload)

    def heal(self, target_replication: Optional[int] = None) -> int:
        """Plan + execute one re-replication pass: restore every
        under-replicated partition AND committed output onto live nodes
        through the write path (see
        :func:`repro.train.elastic.execute_rebalance`). Returns the
        number of copies made."""
        from repro.train.elastic import execute_rebalance, plan_rebalance
        if target_replication is None:
            target_replication = self.spec.replication
        plan = plan_rebalance(self, target_replication=target_replication)
        return execute_rebalance(self, plan)

    def heal_async(self, target_replication: Optional[int] = None
                   ) -> "Future[int]":
        """Background re-replication on the transport's I/O pool — the
        churn story's 'keep serving while healing' half: demand reads
        keep failing over to surviving replicas while this future
        restores R in the background."""
        return self.transport.submit(self.heal, target_replication)

    def tick_step(self, step: int) -> None:
        """Advance the fault injector's training-step clock (drives
        ``FaultPolicy.kill_at_step``). No-op without an injector."""
        if self.faults is not None:
            self.faults.on_step(step)

    def fault_stats(self) -> Dict[str, int]:
        """The injector's counters plus the cluster retry ledger (empty
        injector counters when no FaultPolicy is configured)."""
        stats = self.faults.stats() if self.faults is not None else {
            "ops": 0, "injected": 0, "dropped": 0, "errored": 0,
            "delayed": 0, "killed": False, "step": -1}
        stats["retries"] = self.accounting.retries()
        stats["failed_nodes"] = sorted(self.failed)
        return stats

    def unreachable_paths(self) -> List[str]:
        """Input files whose every owner is failed (data loss without R>=2)."""
        lost = []
        for path in self.metadata.paths():
            _, loc = self.metadata.lookup(path)
            if all(o in self.failed for o in loc.all_owners):
                lost.append(path)
        return lost

    # ---- reads -------------------------------------------------------------
    def _fetch_item(self, path: str, st: StatRecord,
                    loc: FileLocation) -> FetchItem:
        """Resolve the sizes the transport cost model needs for one file."""
        rec = None
        if self.nodes[loc.node_id].has(path):
            rec = self.nodes[loc.node_id].record_for(path)
        compressed = bool(rec and rec.compressed_size)
        return FetchItem(path=path, size=st.st_size,
                         stored=rec.stored_size if rec else st.st_size,
                         compressed=compressed)

    def _lookup(self, path: str) -> Tuple[StatRecord, FileLocation]:
        """Resolve a path against the replicated input metadata, falling
        back to the committed-output namespace (visible-until-finish).
        Output locations point at the placement owner holding the payload,
        so output reads ride the same local/remote/batched machinery as
        input reads."""
        hit = self.metadata.lookup(path)
        if hit is None:
            hit = self.output_ns.lookup(path)
        if hit is None:
            raise FileNotFoundError(path)
        return hit

    def _choose_owner(self, loc: FileLocation, item: FetchItem,
                      pending_serve: Dict[int, float], *,
                      avoid: Optional[int] = None) -> Optional[int]:
        """Pick the live replica that serves this fetch, propagating the
        in-batch load (``pending_serve``) so one batch spreads across
        replicas. Returns None when every owner is failed — demand paths
        raise, the prefetch path skips. Shared by ``read_many`` and
        ``prefetch_window`` so selection policy cannot drift between them.

        ``avoid`` names an owner that just failed this read: the failover
        loop prefers any OTHER live replica, falling back to the avoided
        owner itself when it is the only live one (so a transient fault
        at R=1 still gets its retries before the strike counter marks the
        node failed for good).
        """
        owners = [o for o in loc.all_owners if o not in self.failed]
        if avoid is not None and len(owners) > 1:
            owners = [o for o in owners if o != avoid]
        if not owners:
            return None
        load = {o: self.clocks[o].serve_s + pending_serve.get(o, 0.0)
                for o in owners}
        owner = self.selector.choose(owners, load)
        pending_serve[owner] = pending_serve.get(owner, 0.0) + (
            self.net.local_cost(item.stored)
            + item.stored / self.net.bandwidth_Bps)
        return owner

    # ---- failover plumbing -------------------------------------------------
    def _note_owner_failure(self, owner: int, exc: BaseException) -> None:
        """One transport failure against ``owner``: bump its strike count
        and, at ``fault_threshold`` consecutive strikes, mark it failed
        cluster-wide — organic failure detection, no oracle required."""
        with self._lock:
            strikes = self._owner_strikes.get(owner, 0) + 1
            self._owner_strikes[owner] = strikes
            threshold_hit = strikes >= self.fault_threshold
        if threshold_hit and owner not in self.failed:
            self.mark_failed(owner)

    def _note_owner_ok(self, owner: int) -> None:
        """A successful fetch resets the owner's consecutive-strike count
        (only sustained failure takes a node out of rotation)."""
        if self._owner_strikes.get(owner):
            with self._lock:
                self._owner_strikes[owner] = 0

    def _retry_backoff(self, requester: int, attempt: int, *,
                       count: int = 1) -> None:
        """Capped exponential backoff for failover attempt ``attempt``
        (1-based), booked on the requester's retry ledger."""
        delay = min(self.spec.retry_backoff_cap_s,
                    self.spec.retry_backoff_s * (2 ** (attempt - 1)))
        self.transport.account_retry(requester, delay, count=count)

    def _fetch_with_failover(self, requester: int, groups: Dict[
            int, List[Tuple[int, FetchItem, FileLocation]]], *,
            materialize: bool, batched: bool, window: bool,
            on_data, lost_ok: bool, lane: str = "consume",
            tenant: Optional[str] = None) -> None:
        """Drain an (owner -> [(slot, item, loc)]) worklist, classifying
        owner errors and retrying on the next live replica.

        One round fetches every group; a group whose owner raised a
        transport failure (ConnectionError / timeout / ERR frame /
        injected fault — see :func:`repro.fanstore.faults
        .is_transport_failure`) strikes that owner, pays ONE retry tick of
        capped exponential backoff, and is re-routed via
        :meth:`_choose_owner` (``avoid=`` the owner that just failed).
        Entries with no live replica left raise
        :class:`~repro.fanstore.faults.NodeLostError` naming the lost
        partitions — or are silently dropped when ``lost_ok`` (the
        best-effort prefetch path; demand reads surface the loss).
        Non-transport errors re-raise unclassified: a genuine
        ``FileNotFoundError`` must never burn replicas. Successful
        payloads are delivered through ``on_data(slot, item, data)``.

        Termination: every retry either removes a group (success), or
        strikes its owner — and at ``fault_threshold`` strikes the owner
        is marked failed and drops out of ``_choose_owner`` for good, so
        the live-owner set is strictly shrinking along any failure path.
        ``max_attempts`` is a belt-and-suspenders valve on top.
        """
        from repro.fanstore.faults import NodeLostError, is_transport_failure
        attempt = 0
        max_attempts = (self.fault_threshold + 1) * max(2, len(self.nodes))
        while groups:
            attempt += 1
            failed: List[Tuple[
                int, List[Tuple[int, FetchItem, FileLocation]],
                BaseException]] = []
            for owner, entries in list(groups.items()):
                items = [it for _, it, _ in entries]
                try:
                    if window:
                        datas = self.transport.fetch_window(
                            requester, owner, items, materialize=materialize)
                    elif batched:
                        datas = self.transport.fetch_remote_batch(
                            requester, owner, items, materialize=materialize,
                            lane=lane, tenant=tenant)
                    else:
                        datas = [self.transport.fetch_remote(
                            requester, owner, it, materialize=materialize,
                            lane=lane, tenant=tenant)
                            for it in items]
                except Exception as exc:
                    if not is_transport_failure(exc):
                        raise
                    self._note_owner_failure(owner, exc)
                    failed.append((owner, entries, exc))
                    continue
                self._note_owner_ok(owner)
                del groups[owner]
                for (slot, item, _), data in zip(entries, datas):
                    on_data(slot, item, data)
            if not failed:
                continue
            # one retry tick per failed group, one shared backoff level
            self._retry_backoff(requester, min(attempt, 16),
                                count=len(failed))
            last_exc = failed[-1][2]
            regroup: Dict[int, List[
                Tuple[int, FetchItem, FileLocation]]] = {}
            pending_serve: Dict[int, float] = {}
            lost: List[Tuple[str, int]] = []
            exhausted = attempt >= max_attempts
            for owner, entries, _ in failed:
                for slot, item, loc in entries:
                    new_owner = None if exhausted else self._choose_owner(
                        loc, item, pending_serve, avoid=owner)
                    if new_owner is None:
                        lost.append((item.path, loc.partition_id))
                    else:
                        regroup.setdefault(new_owner, []).append(
                            (slot, item, loc))
            if lost and not lost_ok:
                raise NodeLostError.for_items(lost) from last_exc
            groups = regroup

    def read(self, requester: int, path: str, *, worker_id: int = 0,
             materialize: bool = True, lane: str = "consume",
             tenant: Optional[str] = None,
             job: Optional[str] = None) -> bytes:
        """Whole-file read as the training process sees it (paper §3.4).

        ``materialize=False`` runs the identical placement + timeline
        accounting but skips the payload copies — used by the scaling
        benchmarks, where 512 nodes x thousands of multi-MB reads would
        spend their wall time in host memcpy instead of the modeled fabric.
        """
        return self.read_many(requester, [path], worker_id=worker_id,
                              materialize=materialize, batched=False,
                              lane=lane, tenant=tenant, job=job)[0]

    def read_many(self, requester: int, paths: Sequence[str], *,
                  worker_id: int = 0, materialize: bool = True,
                  batched: bool = True, lane: str = "consume",
                  tenant: Optional[str] = None,
                  job: Optional[str] = None) -> List[bytes]:
        """Batched read: all remote requests for one owner ride ONE round trip.

        ``batched=False`` degrades to per-file round trips (the paper's
        synchronous client), byte-for-byte identical to the seed ``read``
        accounting — benchmarks compare the two to show the coalescing win.
        Results are returned in input order. ``worker_id`` names which of
        the requester node's co-located workers is reading: the node's
        shared cache tier serves them all, with per-worker hit/miss
        attribution (modeled costs are worker-independent by contract).

        ``lane="serve_app"`` is the tenant-aware read verb the serving
        plane (:mod:`repro.fanstore.serving`) drives: every cost lands on
        the concurrent ``NodeClock.serve_app_s`` timeline attributed to
        ``tenant``, so hundreds of read-mostly serving tenants overlap —
        rather than serialize into — the trainer's demand lane.

        ``job`` names which attached job (e.g. ``"train"`` vs ``"eval"``)
        issued the read: every cache hit/miss is additionally booked onto
        that job's attribution row on BOTH the tier ledger and the
        ``NodeClock``, so two jobs sharing one node tier tie out exactly
        against the tier totals (tenant-ledger discipline).
        """
        if requester in self.failed:
            raise IOError(f"node {requester} is failed")
        from repro.fanstore.faults import NodeLostError
        out: List[Optional[bytes]] = [None] * len(paths)
        tier = self.cache_tiers[requester]
        # (owner -> [(output slot, item, location)]) for the remote leg;
        # the location rides along so a failed fetch can re-route to the
        # next live replica without a second metadata pass
        groups: Dict[int, List[Tuple[int, FetchItem, FileLocation]]] = {}
        pending_serve: Dict[int, float] = {}
        for i, raw in enumerate(paths):
            path = raw.strip("/")
            st, loc = self._lookup(path)
            item = self._fetch_item(path, st, loc)
            if tier.enabled:
                entry = tier.get(path, worker_id=worker_id,
                                 require_data=materialize, job=job)
                if entry is not None:
                    self.transport.account_cache_hit(requester, item,
                                                     worker_id=worker_id,
                                                     lane=lane, tenant=tenant,
                                                     job=job)
                    out[i] = entry.data if materialize else b""
                    continue
                self.transport.account_cache_miss(requester,
                                                  worker_id=worker_id,
                                                  job=job)
            if self.nodes[requester].has(path) or \
                    self.nodes[requester].has_output(path):
                data = self.transport.fetch_local(requester, item,
                                                  materialize=materialize,
                                                  lane=lane, tenant=tenant)
                out[i] = data
                if tier.enabled:
                    ev = tier.put(path, data if materialize else None,
                                  size=item.size, worker_id=worker_id,
                                  job=job)
                    self.transport.account_cache_eviction(requester, ev)
                continue
            owner = self._choose_owner(loc, item, pending_serve)
            if owner is None:
                raise NodeLostError.for_items([(path, loc.partition_id)])
            groups.setdefault(owner, []).append((i, item, loc))

        def deliver(slot: int, item: FetchItem, data: bytes) -> None:
            out[slot] = data
            if tier.enabled:
                ev = tier.put(item.path, data if materialize else None,
                              size=item.size, worker_id=worker_id,
                              job=job)
                self.transport.account_cache_eviction(requester, ev)

        self._fetch_with_failover(requester, groups,
                                  materialize=materialize, batched=batched,
                                  window=False, on_data=deliver,
                                  lost_ok=False, lane=lane, tenant=tenant)
        return out  # type: ignore[return-value]

    def read_many_async(self, requester: int, paths: Sequence[str], *,
                        worker_id: int = 0, materialize: bool = True,
                        lane: str = "consume", tenant: Optional[str] = None,
                        job: Optional[str] = None
                        ) -> "Future[List[bytes]]":
        """Batched read on the transport's I/O pool; returns a Future."""
        return self.transport.submit(self.read_many, requester, list(paths),
                                     worker_id=worker_id,
                                     materialize=materialize,
                                     lane=lane, tenant=tenant, job=job)

    # ---- scheduled prefetch (repro.fanstore.prefetch drives this) ----------
    def prefetch_window(self, requester: int, paths: Sequence[str], *,
                        worker_id: int = 0, materialize: bool = True) -> int:
        """Stage one lookahead window into the requester's client cache.

        The window may span many training batches: every remote file is
        grouped by its serving owner and fetched with ONE
        ``Transport.fetch_window`` round trip per (requester, owner,
        window); requester-local files are staged from the SSD tier.
        All cost lands on the ``NodeClock.prefetch_s`` lane (concurrent
        with the demand timeline), payloads land in the client cache so
        the demand-path ``read_many`` hits at RAM speed, and evictions are
        mirrored onto the clock exactly like demand inserts. Files already
        cached, unknown (output files), or wholly unreachable are skipped.
        Returns the number of bytes staged.
        """
        if requester in self.failed:
            raise IOError(f"node {requester} is failed")
        tier = self.cache_tiers[requester]
        if not tier.enabled:
            raise ValueError("prefetch_window requires an enabled client "
                             "cache (cache_bytes > 0)")
        local_items: List[FetchItem] = []
        groups: Dict[int, List[Tuple[int, FetchItem, FileLocation]]] = {}
        pending_serve: Dict[int, float] = {}
        for raw in paths:
            path = raw.strip("/")
            if tier.contains(path, worker_id):
                continue
            hit = self.metadata.lookup(path)
            if hit is None:
                continue                      # output file: demand-only
            st, loc = hit
            item = self._fetch_item(path, st, loc)
            if self.nodes[requester].has(path):
                local_items.append(item)
                continue
            owner = self._choose_owner(loc, item, pending_serve)
            if owner is None:
                continue                      # unreachable: surfaces on demand
            groups.setdefault(owner, []).append((0, item, loc))
        staged = 0
        evictions = 0

        def insert(item: FetchItem, data: bytes) -> None:
            nonlocal staged, evictions
            evictions += tier.put(item.path, data if materialize else None,
                                  size=item.size, worker_id=worker_id)
            if tier.contains(item.path, worker_id):
                staged += item.size   # count only accepted entries (Belady
                                      # admission / oversize may refuse)

        if local_items:
            datas = self.transport.prefetch_local(requester, local_items,
                                                  materialize=materialize)
            for item, data in zip(local_items, datas):
                insert(item, data)
        # remote windows ride the same failover loop as demand reads —
        # but best-effort (lost_ok): an unreachable file is skipped here
        # and the demand read surfaces the NodeLostError
        self._fetch_with_failover(
            requester, groups, materialize=materialize, batched=True,
            window=True, on_data=lambda _slot, item, data:
            insert(item, data), lost_ok=True)
        if evictions:
            self.transport.account_cache_eviction(requester, evictions)
        return staged

    def prefetch_window_async(self, requester: int, paths: Sequence[str], *,
                              worker_id: int = 0, materialize: bool = True
                              ) -> "Future[int]":
        """``prefetch_window`` on the transport's I/O pool."""
        return self.transport.submit(self.prefetch_window, requester,
                                     list(paths), worker_id=worker_id,
                                     materialize=materialize)

    # ---- lifecycle ---------------------------------------------------------
    def start(self) -> "FanStoreCluster":
        """Bring the transport up (socket backend: bind + spawn the
        per-node serving loops). Idempotent; remote verbs also start the
        wire lazily, so this is only needed to pin startup cost."""
        self.transport.start()
        return self

    def close(self) -> None:
        """Deterministic teardown: stop serving loops, drop connections,
        and join the transport's I/O pool (spawned lazily by async reads).
        Safe to call twice; a closed cluster may be restarted."""
        self.transport.close()

    # legacy name (pre-lifecycle API); same full teardown
    shutdown = close

    def __enter__(self) -> "FanStoreCluster":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def stat(self, path: str) -> StatRecord:
        st = self.metadata.stat(path)
        if st is None:
            st = self.output_ns.stat(path)     # committed outputs + their dirs
        if st is None:
            raise FileNotFoundError(path)
        return st

    def readdir(self, path: str) -> List[str]:
        """Directory listing over BOTH namespaces: immutable inputs and
        committed output files (a written file lists as soon as its close
        publishes the metadata; its parent dirs materialize with it)."""
        kids = self.metadata.readdir(path)
        okids = self.output_ns.readdir(path)
        if kids is None and okids is None:
            raise FileNotFoundError(path)
        return sorted(set(kids or []) | set(okids or []))

    def is_dir(self, path: str) -> bool:
        return self.metadata.is_dir(path) or self.output_ns.is_dir(path)

    # ---- writes ------------------------------------------------------------
    def write_file(self, writer: int, path: str, data: bytes) -> None:
        """Deprecated shim (use :class:`repro.fanstore.api.FanStoreSession`
        ``open``/``write``/``close`` or the batched :meth:`write_many`):
        open-for-write + write + close with visible-on-close semantics, one
        per-file round trip on the serialized demand lane — the seed's
        synchronous writer."""
        path = path.strip("/")
        node = self.nodes[writer]
        node.write_begin(path)
        node.write_append(path, data)
        self.commit_write(writer, path)

    def write_begin(self, writer: int, path: str) -> None:
        """Open a new output file for append on the writer node. A path
        someone already committed is only rejected at close/flush time
        (visible-until-finish: opens are local, commits are global)."""
        if writer in self.failed:
            raise IOError(f"node {writer} is failed")
        self.nodes[writer].write_begin(path.strip("/"))

    def write_append(self, writer: int, path: str, data: bytes) -> int:
        return self.nodes[writer].write_append(path.strip("/"), data)

    def abort_write(self, writer: int, path: str) -> None:
        """Discard an open write: drop the writer-side buffer AND any
        chunks already streamed to the placement owner's staging — a
        later writer of the same path must commit exactly its own bytes."""
        path = path.strip("/")
        self.nodes[writer].write_abort(path)
        self.nodes[self.placement.owner(path)].drop_staging(writer, path)

    def flush_write(self, writer: int, path: str, *,
                    lane: str = "write") -> int:
        """Stream the open write's buffered bytes to the placement owner
        (fsync semantics minus the visibility: metadata publishes on close).
        This is what lets :class:`repro.fanstore.api.CheckpointWriter`
        overlap a shard's fabric shipment with producing the next chunk —
        cost accrues on the concurrent ``write_s`` lane. Returns bytes
        shipped."""
        path = path.strip("/")
        with self._lock:
            if self.output_ns.lookup(path) is not None:
                raise PermissionError(f"{path}: single-write violated")
        chunk = self.nodes[writer].write_take(path)
        if not chunk:
            return 0
        owner = self.placement.owner(path)
        item = FetchItem(path=path, size=len(chunk), stored=len(chunk))
        if owner == writer:
            self.transport.put_local(writer, [(item, chunk)], lane=lane)
        else:
            self.transport.put_remote_batch(writer, owner, [(item, chunk)],
                                            lane=lane, round_trips=1)
        return len(chunk)

    def commit_write(self, writer: int, path: str, *,
                     lane: str = "consume") -> StatRecord:
        """Close an open write: finish the buffer, ship the remainder to
        the placement owner (payload AND metadata ride one message — the
        payload is no longer stranded on the writer), enforce single-write,
        and publish. Shared by ``write_file``, the FS layer's ``close()``
        (both on the legacy serialized ``consume`` lane), and the session
        fd path (concurrent ``write`` lane)."""
        path = path.strip("/")
        st, payload = self.nodes[writer].write_finish(path)
        owner = self.placement.owner(path)
        item = FetchItem(path=path, size=len(payload), stored=len(payload))
        if owner == writer:
            self.transport.put_local(writer, [(item, payload)], lane=lane)
        else:
            self.transport.put_remote_batch(writer, owner, [(item, payload)],
                                            lane=lane, round_trips=1)
        return self._publish(writer, owner, path, st)

    def _publish(self, writer: int, owner: int, path: str,
                 st: StatRecord) -> StatRecord:
        """Atomically commit the owner's staged chunks and publish the
        output metadata; the losing writer of a race gets PermissionError
        and its staged bytes are dropped (the committed payload survives)."""
        with self._lock:
            if self.output_ns.lookup(path) is not None:
                self.nodes[owner].drop_staging(writer, path)
                raise PermissionError(f"{path}: single-write violated")
            self.nodes[owner].commit_output(writer, path)
            self.output_ns.insert(path, st, FileLocation(
                node_id=owner, partition_id=-1, record_index=-1))
            self.output_meta[owner][path] = st
        return st

    def write_many(self, writer: int, entries: Sequence[Tuple[str, bytes]],
                   *, batched: bool = True, lane: str = "write"
                   ) -> List[StatRecord]:
        """Batched write: all payloads bound for one placement owner ride
        ONE round trip — the write-side mirror of ``read_many``. Entries
        are (path, payload) pairs; results are returned in input order.

        ``batched=False`` degrades to per-file round trips (what a loop of
        ``write_file`` calls pays) for benchmarking the fan-in win.
        ``lane`` defaults to the concurrent write timeline so bulk output
        flushes overlap demand reads and prefetch.
        """
        if writer in self.failed:
            raise IOError(f"node {writer} is failed")
        norm: List[Tuple[str, bytes]] = []
        seen = set()
        for raw, data in entries:
            path = raw.strip("/")
            if path in seen:
                raise ValueError(f"{path}: duplicated in one write_many batch")
            seen.add(path)
            norm.append((path, bytes(data)))
        with self._lock:       # fail the whole batch before shipping anything
            for path, _ in norm:
                if self.output_ns.lookup(path) is not None:
                    raise PermissionError(f"{path}: single-write violated")
        node = self.nodes[writer]
        finished: List[Tuple[str, StatRecord, bytes, int]] = []
        try:
            for path, data in norm:
                self.write_begin(writer, path)
                node.write_append(path, data)
            for path, _ in norm:
                st, payload = node.write_finish(path)
                finished.append((path, st, payload,
                                 self.placement.owner(path)))
        except BaseException:
            for path, _ in norm:
                self.abort_write(writer, path)
            raise
        groups: Dict[int, List[Tuple[FetchItem, bytes]]] = {}
        for path, st, payload, owner in finished:
            item = FetchItem(path=path, size=len(payload), stored=len(payload))
            groups.setdefault(owner, []).append((item, payload))
        for owner, pairs in groups.items():
            if owner == writer:
                self.transport.put_local(writer, pairs, lane=lane)
            elif batched:
                self.transport.put_remote_batch(writer, owner, pairs,
                                                lane=lane, round_trips=1)
            else:
                for pair in pairs:
                    self.transport.put_remote_batch(writer, owner, [pair],
                                                    lane=lane, round_trips=1)
        # publish the WHOLE batch under one lock: a concurrent conflicting
        # commit fails every entry (staging dropped), never a half-batch
        with self._lock:
            for path, st, _, owner in finished:
                if self.output_ns.lookup(path) is not None:
                    for p, _, _, o in finished:
                        self.nodes[o].drop_staging(writer, p)
                    raise PermissionError(f"{path}: single-write violated")
            out = []
            for path, st, _, owner in finished:
                self.nodes[owner].commit_output(writer, path)
                self.output_ns.insert(path, st, FileLocation(
                    node_id=owner, partition_id=-1, record_index=-1))
                self.output_meta[owner][path] = st
                out.append(st)
        return out

    def unlink(self, requester: int, path: str) -> StatRecord:
        """Delete a committed output file (output GC).

        Drops the owner-side payload AND the replicated metadata record in
        one atomic step, so the name is immediately reusable by a new
        writer (single-write applies per-lifetime of a name, not forever).
        Input files are immutable for the training lifetime — unlinking
        one raises ``PermissionError``; a missing path raises
        ``FileNotFoundError``. Returns the stat of the removed file.
        """
        if requester in self.failed:
            raise IOError(f"node {requester} is failed")
        path = path.strip("/")
        if self.metadata.lookup(path) is not None:
            raise PermissionError(
                f"{path}: input files are immutable (cannot unlink)")
        with self._lock:
            hit = self.output_ns.lookup(path)
            if hit is None:
                raise FileNotFoundError(path)
            st, loc = hit
            # replicated outputs (heal / hot promotion) hold the payload
            # on every owner — the unlink must reclaim all of them, or a
            # rewrite of the freed name could read a stale replica
            for owner in loc.all_owners:
                if owner in self.nodes:
                    self.nodes[owner].drop_output(path)
                    self.output_meta[owner].pop(path, None)
            self.output_ns.remove(path)
            # a reader may hold the dead payload in its client cache; a
            # rewrite of the freed name must never serve the old bytes
            for tier in self.cache_tiers.values():
                if tier.enabled:
                    tier.invalidate(path)
            # transports with per-path state (rdma registration tables)
            # must likewise never serve the dead payload
            self.transport.invalidate_path(path)
        return st

    def write_many_async(self, writer: int,
                         entries: Sequence[Tuple[str, bytes]], *,
                         batched: bool = True, lane: str = "write"
                         ) -> "Future[List[StatRecord]]":
        """Batched write on the transport's I/O pool; returns a Future."""
        return self.transport.submit(self.write_many, writer, list(entries),
                                     batched=batched, lane=lane)

    # ---- accounting --------------------------------------------------------
    def reset_clocks(self) -> None:
        self.accounting.reset()

    def makespan_s(self) -> float:
        return self.accounting.makespan_s()

    def measured_makespan_s(self) -> float:
        """Measured (wall-clock) counterpart of :meth:`makespan_s` — only
        nonzero after a real-wire backend (socket/shm) moved bytes."""
        return self.accounting.measured_makespan_s()

    def aggregate_bandwidth(self) -> float:
        return self.accounting.aggregate_bandwidth()

    def local_hit_rate(self) -> float:
        return self.accounting.local_hit_rate()

    def cache_hit_rate(self) -> float:
        return self.accounting.cache_hit_rate()
