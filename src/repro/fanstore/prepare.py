"""Dataset preparation (paper §5.2): files -> partitions.

The user passes a list of files (or an in-memory dataset); the preparer
splits it into K partitions, each an exclusive subset, and packs each with
:func:`repro.fanstore.layout.pack_partition`. Splitting is by round-robin
over a deterministic shuffle so partition sizes stay balanced even when the
input is sorted by class directory (as ImageNet is).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fanstore.layout import pack_partition


@dataclass
class PrepareReport:
    num_files: int
    num_partitions: int
    input_bytes: int
    output_bytes: int
    seconds: float

    @property
    def compression_ratio(self) -> float:
        return self.input_bytes / self.output_bytes if self.output_bytes else 1.0


def split_round_robin(paths: Sequence[str], k: int, *, seed: int = 0
                      ) -> List[List[str]]:
    order = np.random.default_rng(seed).permutation(len(paths))
    groups: List[List[str]] = [[] for _ in range(k)]
    for i, idx in enumerate(order):
        groups[i % k].append(paths[int(idx)])
    return groups


def prepare_dataset(files: Dict[str, bytes], num_partitions: int, *,
                    compress: bool = False, codec: str = "lzss",
                    seed: int = 0,
                    out_dir: Optional[str] = None
                    ) -> Tuple[List[bytes], PrepareReport]:
    """Pack ``{path: data}`` into ``num_partitions`` partition blobs."""
    t0 = time.perf_counter()
    paths = sorted(files)
    groups = split_round_robin(paths, num_partitions, seed=seed)
    blobs: List[bytes] = []
    for g in groups:
        blobs.append(pack_partition([(p, files[p]) for p in g],
                                    compress=compress, codec=codec))
    if out_dir is not None:
        os.makedirs(out_dir, exist_ok=True)
        for i, blob in enumerate(blobs):
            with open(os.path.join(out_dir, f"part_{i:06d}.fst"), "wb") as f:
                f.write(blob)
    report = PrepareReport(
        num_files=len(paths), num_partitions=num_partitions,
        input_bytes=sum(len(v) for v in files.values()),
        output_bytes=sum(len(b) for b in blobs),
        seconds=time.perf_counter() - t0)
    return blobs, report


def prepare_from_dir(root: str, num_partitions: int, **kw
                     ) -> Tuple[List[bytes], PrepareReport]:
    """Walk a real directory tree (the paper's CLI mode)."""
    files: Dict[str, bytes] = {}
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in filenames:
            full = os.path.join(dirpath, fn)
            rel = os.path.relpath(full, root)
            with open(full, "rb") as f:
                files[rel] = f.read()
    return prepare_dataset(files, num_partitions, **kw)
