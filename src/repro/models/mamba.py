"""Mamba-1 block (falcon-mamba / the SSM half of hymba).

x -> in_proj -> (u, z); u -> causal depthwise conv -> silu -> selective scan
-> y; out = out_proj(y * silu(z)).

Selective scan: h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * u_t,
y_t = C_t . h_t + D * u_t, with diagonal A (d_inner, d_state), input-dependent
dt/B/C. Training uses a chunked scan: lax.scan over time chunks with an
associative scan inside each chunk — O(chunk * d_inner * d_state) peak
memory. The Pallas kernel (repro.kernels.ssm_scan) implements the same
chunking with explicit VMEM tiles; this module is the lowering-friendly
reference used by dry-runs and CPU tests.

Decode is the O(1) recurrence on a carried (h, conv window) state — this is
why falcon-mamba/hymba run the long_500k shape while full-attention archs
cannot.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init


def mamba_params(key, cfg: ModelConfig, d_inner: Optional[int] = None,
                 dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    di = d_inner or cfg.d_inner
    st = cfg.ssm_state
    dtr = cfg.dt_rank or max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 7)
    a_init = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": _dense_init(ks[1], (cfg.ssm_conv, di),
                              scale=1.0 / math.sqrt(cfg.ssm_conv), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], (di, dtr + 2 * st), dtype=dtype),
        "dt_proj": _dense_init(ks[3], (dtr, di), scale=dtr ** -0.5, dtype=dtype),
        "dt_bias": (jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[4], (di,)) *
                             (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)),
                     1e-4, None)))).astype(dtype),
        "a_log": jnp.log(a_init).astype(dtype),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": _dense_init(ks[5], (di, d), dtype=dtype),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d. u: (B, T, di); w: (K, di).

    ``state``: (B, K-1, di) carried context (decode); returns (out, new_state).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)           # (B, K-1+T, di)
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + ext[:, i: i + u.shape[1]] * w[i].astype(u.dtype)
    new_state = ext[:, -(k - 1):] if k > 1 else state
    return out + b.astype(u.dtype), new_state


def _ssm_chunk(a_bar, bu, h0):
    """Associative scan within a chunk. a_bar/bu: (B, Q, di, st)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    a_cum, h = lax.associative_scan(combine, (a_bar, bu), axis=1)
    h = h + a_cum * h0[:, None]
    return h


def selective_scan(u, dt, b_in, c_in, a_log, d_skip, h0=None, *,
                   chunk: int = 256, unroll: bool = False):
    """u: (B, T, di); dt: (B, T, di); b_in/c_in: (B, T, st).

    Returns (y (B, T, di), h_final (B, di, st)). fp32 state math.
    """
    bsz, t, di = u.shape
    st = b_in.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))             # (di, st)
    if h0 is None:
        h0 = jnp.zeros((bsz, di, st), jnp.float32)
    if unroll:          # cost-exact mode: single-trip chunk loop
        chunk = t
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    nt = u.shape[1] // chunk

    def to_chunks(x):
        return x.reshape(bsz, nt, chunk, *x.shape[2:]).swapaxes(0, 1)

    uc, dtc, bc, cc = map(to_chunks, (u, dt, b_in, c_in))

    def step(h, xs):
        uq, dtq, bq, cq = xs                            # (B, Q, ...)
        dtf = dtq.astype(jnp.float32)
        a_bar = jnp.exp(dtf[..., None] * a)             # (B,Q,di,st)
        bu = (dtf * uq.astype(jnp.float32))[..., None] * bq.astype(jnp.float32)[:, :, None, :]
        hseq = _ssm_chunk(a_bar, bu, h)                 # (B,Q,di,st)
        y = jnp.einsum("bqds,bqs->bqd", hseq, cq.astype(jnp.float32))
        return hseq[:, -1], y

    h_final, yc = lax.scan(step, h0, (uc, dtc, bc, cc))
    y = yc.swapaxes(0, 1).reshape(bsz, nt * chunk, di)[:, :t]
    y = y + u.astype(jnp.float32)[:, :y.shape[1]][:, :t] * d_skip.astype(jnp.float32)
    return y, h_final


def apply_mamba(p, x, cfg: ModelConfig, *, ssm_impl: str = "lax"
                ) -> jnp.ndarray:
    """Full-sequence mamba block. x: (B, T, d) -> (B, T, d)."""
    di = p["in_proj"].shape[1] // 2
    uz = x @ p["in_proj"].astype(x.dtype)
    u, z = jnp.split(uz, 2, axis=-1)
    u, _ = _causal_conv(u, p["conv_w"], p["conv_b"])
    u = jax.nn.silu(u)
    dtr = p["dt_proj"].shape[0]
    proj = u @ p["x_proj"].astype(u.dtype)
    dt_lowrank, b_in, c_in = jnp.split(proj, [dtr, dtr + cfg.ssm_state], axis=-1)
    dt = jax.nn.softplus(dt_lowrank @ p["dt_proj"].astype(u.dtype)
                         + p["dt_bias"].astype(u.dtype))
    if ssm_impl == "kernel":
        from repro.kernels import ops as kops
        y, _ = kops.ssm_scan(u, dt, b_in, c_in, p["a_log"], p["d_skip"])
    else:
        y, _ = selective_scan(u, dt, b_in, c_in, p["a_log"], p["d_skip"],
                              unroll=cfg.unroll)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype)


def mamba_decode_state(cfg: ModelConfig, batch: int, d_inner: Optional[int] = None,
                       dtype=jnp.float32) -> Dict:
    di = d_inner or cfg.d_inner
    return {"h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype)}


def apply_mamba_decode(p, x, state: Dict, cfg: ModelConfig
                       ) -> Tuple[jnp.ndarray, Dict]:
    """One-token step. x: (B, 1, d); state: {h, conv}."""
    uz = x @ p["in_proj"].astype(x.dtype)
    u, z = jnp.split(uz, 2, axis=-1)
    u, conv_new = _causal_conv(u, p["conv_w"], p["conv_b"], state["conv"])
    u = jax.nn.silu(u)
    dtr = p["dt_proj"].shape[0]
    proj = u @ p["x_proj"].astype(u.dtype)
    dt_lowrank, b_in, c_in = jnp.split(proj, [dtr, dtr + cfg.ssm_state], axis=-1)
    dt = jax.nn.softplus(dt_lowrank @ p["dt_proj"].astype(u.dtype)
                         + p["dt_bias"].astype(u.dtype))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                  # (B, di)
    a_bar = jnp.exp(dtf[..., None] * a)                 # (B, di, st)
    bu = (dtf * u[:, 0].astype(jnp.float32))[..., None] * \
        b_in[:, 0].astype(jnp.float32)[:, None, :]
    h = a_bar * state["h"] + bu
    y = jnp.einsum("bds,bs->bd", h, c_in[:, 0].astype(jnp.float32))
    y = y + u[:, 0].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype), {"h": h, "conv": conv_new}
