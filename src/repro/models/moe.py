"""Mixture-of-Experts layer: token-choice top-k with capacity, index dispatch.

Dispatch is index-based (gather/scatter), not one-hot-einsum: for a token
block of n tokens with E experts, capacity C per expert,

  1. router logits -> top-k experts + gate weights per token,
  2. position-in-expert by cumulative count over the flattened (n*k)
     assignments (tokens beyond capacity C are dropped, as in Switch/GShard;
     capacity_factor sizes C),
  3. gather tokens into (E, C, d), run the expert FFN batched over E,
  4. scatter-add weighted outputs back to token order.

The token dimension is processed in blocks (cfg.moe_block_tokens) under
lax.scan so peak memory stays O(block) — the same blocking MaxText uses.

Sharding: expert-stacked weights (E, d, f). deepseek (160 experts) shards E
over the model axis (EP); granite (40 experts, 16-way mesh) shards f (TP
inside expert) — rules in repro.dist.sharding pick by divisibility.
Aux losses: load-balance (Switch) loss + router z-loss, returned for logging.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init


def moe_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, e), scale=0.02, dtype=dtype),
        "wi": _dense_init(ks[1], (e, d, f), dtype=dtype),
        "wg": _dense_init(ks[2], (e, d, f), dtype=dtype),
        "wo": _dense_init(ks[3], (e, f, d), dtype=dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {"wi": _dense_init(kss[0], (d, fs), dtype=dtype),
                       "wg": _dense_init(kss[1], (d, fs), dtype=dtype),
                       "wo": _dense_init(kss[2], (fs, d), dtype=dtype)}
    return p


def _expert_ffn(wi, wg, wo, x):
    # x: (E, C, d); weights (E, d, f) / (E, f, d)
    h = jnp.einsum("ecd,edf->ecf", x, wi)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", x, wg)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_block(p, x, cfg: ModelConfig, ep_act=None
               ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One token block: x (n, d) -> (out (n, d), lb_loss, z_loss)."""
    n, d = x.shape
    e, k = cfg.num_experts, cfg.experts_top_k
    cap = max(1, int(math.ceil(cfg.moe_capacity_factor * n * k / e)))
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)   # (n, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = lax.top_k(probs, k)                               # (n, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each assignment within its expert, via stable sort (the
    # cumsum-of-one-hot formulation lowers to an O(N*window) reduce-window —
    # both slow and absurdly costed; sort is O(N log N) and TPU-friendly)
    flat_e = expert.reshape(-1)                                      # (n*k,)
    nk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = jnp.take(flat_e, order)
    starts = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=flat_e.dtype),
                              side="left")
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - \
        jnp.take(starts, sorted_e).astype(jnp.int32)
    my_pos = jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted)
    keep = my_pos < cap
    slot_e = jnp.where(keep, flat_e, e)            # drop -> expert id e
    slot_c = jnp.where(keep, my_pos, 0)

    # gather_idx[e, c] = flattened token index (n*k space), n*k = sentinel
    gather = jnp.full((e + 1, cap), n, jnp.int32)  # sentinel token id n
    tok_of_flat = jnp.arange(n * k, dtype=jnp.int32) // k
    gather = gather.at[slot_e, slot_c].set(tok_of_flat, mode="drop")
    gather = gather[:e]                            # (e, cap)

    xpad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xin = xpad[gather]                             # (e, cap, d)
    if ep_act is not None:                         # pin EP sharding (see
        xin = ep_act(xin)                          # ShardingRules.expert_constraint)
    y = _expert_ffn(p["wi"].astype(x.dtype), p["wg"].astype(x.dtype),
                    p["wo"].astype(x.dtype), xin)  # (e, cap, d)
    if ep_act is not None:
        y = ep_act(y)

    # scatter back with gate weights
    flat_gate = gate.reshape(-1)
    out = jnp.zeros((n + 1, d), x.dtype)
    w = jnp.zeros((e + 1, cap), x.dtype)
    w = w.at[slot_e, slot_c].set(flat_gate.astype(x.dtype), mode="drop")
    w = w[:e]
    out = out.at[gather.reshape(-1)].add((y * w[..., None]).reshape(-1, d),
                                         mode="drop")
    out = out[:n]

    if cfg.num_shared_experts:
        sp = p["shared"]
        h = jax.nn.silu(x @ sp["wi"].astype(x.dtype)) * (x @ sp["wg"].astype(x.dtype))
        out = out + h @ sp["wo"].astype(x.dtype)

    # aux losses (Switch load-balance + z-loss)
    frac_tokens = jax.nn.one_hot(expert, e, dtype=jnp.float32).sum((0, 1)) / (n * k)
    frac_prob = probs.mean(0)
    lb = e * jnp.sum(frac_tokens * frac_prob)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, lb, z


def apply_moe(p, x, cfg: ModelConfig, ep_act=None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out, aux_loss). Blocked over tokens via lax.scan."""
    b, t, d = x.shape
    n = b * t
    block = n if cfg.unroll else min(cfg.moe_block_tokens, n)
    flat = x.reshape(n, d)
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    blocks = flat.reshape(-1, block, d)

    def step(carry, xb):
        yb, lb, z = _moe_block(p, xb, cfg, ep_act)
        return (carry[0] + lb, carry[1] + z), yb

    (lb, z), ys = lax.scan(step, (jnp.zeros(()), jnp.zeros(())), blocks)
    nb = blocks.shape[0]
    out = ys.reshape(-1, d)[:n].reshape(b, t, d)
    aux = cfg.router_aux_coef * (lb / nb) + 1e-4 * (z / nb)
    return out, aux
