"""Hymba-style hybrid block: parallel attention + SSM heads.

Both mixers read the same normalized input; their outputs are re-normalized
(branch-specific scales) and averaged before the residual add — the fusion
Hymba reports as better than interleaving. Most layers use sliding-window
attention; cfg.global_layers (first / middle / last) keep full attention,
which is what keeps the arch sub-quadratic at 500k context.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (apply_norm, attention_out, attention_params,
                                 decode_attention, flash_attention_lax,
                                 norm_init, qkv_project)
from repro.models.mamba import (apply_mamba, apply_mamba_decode,
                                mamba_decode_state, mamba_params)


def hybrid_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    k1, k2 = jax.random.split(key)
    return {"attn": attention_params(k1, cfg, dtype=dtype),
            "mixer": mamba_params(k2, cfg, dtype=dtype),
            "norm_a": norm_init(cfg), "norm_s": norm_init(cfg)}


def apply_hybrid(p, h, cfg: ModelConfig, positions,
                 window: Optional[int]) -> jnp.ndarray:
    """h: already-normalized input (B, T, d) -> mixer output (B, T, d)."""
    q, k, v = qkv_project(p["attn"], h, cfg, positions)
    attn = flash_attention_lax(q, k, v, causal=True, window=window,
                               unroll=cfg.unroll,
                               scale_in_q=cfg.attn_scale_in_q,
                               probs_bf16=cfg.attn_probs_bf16)
    a = attention_out(p["attn"], attn, h.dtype)
    s = apply_mamba(p["mixer"], h, cfg)
    return 0.5 * (apply_norm(p["norm_a"], a, cfg)
                  + apply_norm(p["norm_s"], s, cfg))


def apply_hybrid_decode(p, h, cfg: ModelConfig, cache: Dict, cache_len,
                        window: Optional[int]) -> Tuple[jnp.ndarray, Dict]:
    """h: (B, 1, d). cache: {k, v, h, conv}; SWA caches are ring buffers."""
    pos = jnp.full((h.shape[0], 1), cache_len, jnp.int32)
    q, k, v = qkv_project(p["attn"], h, cfg, pos)
    size = cache["k"].shape[1]
    slot = cache_len % size if window is not None else cache_len
    kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    n_valid = jnp.minimum(cache_len + 1, size)
    attn = decode_attention(q, kc, vc, n_valid)   # ring: window by construction
    a = attention_out(p["attn"], attn, h.dtype)
    s, state = apply_mamba_decode(p["mixer"], h, {"h": cache["h"],
                                                  "conv": cache["conv"]}, cfg)
    out = 0.5 * (apply_norm(p["norm_a"], a, cfg)
                 + apply_norm(p["norm_s"], s, cfg))
    return out, {"k": kc, "v": vc, "h": state["h"], "conv": state["conv"]}
