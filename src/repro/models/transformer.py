"""Decoder-LM assembly for all six architecture families.

The layer stack is grouped into *segments* of consecutive layers with
identical static structure (kind, window); each segment's params are stacked
on a leading layer axis and applied with ``lax.scan`` (+ optional remat), so
HLO size and compile time are depth-independent — an 80-layer qwen2 compiles
like a 1-layer model plus the scan body.

Batch contract (all int32/bf16 arrays):
  dense/moe/ssm/hybrid: {"tokens": (B, T)}                     next-token LM
  audio (musicgen):     {"tokens": (B, T, C)}   C codebooks, per-codebook CE
  vlm (internvl):       {"tokens": (B, T_text), "patches": (B, P, d_model)}
                        patches are STUB frontend outputs (DESIGN.md §6)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import hybrid as hybrid_mod
from repro.models import mamba as mamba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import (apply_mlp, apply_norm, attention_out,
                                 attention_params, chunked_cross_entropy,
                                 decode_attention, embed_init,
                                 flash_attention_lax, mlp_params, norm_init,
                                 qkv_project)

# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Segment:
    kind: str                  # dense | moe | mla_dense | mla_moe | mamba | hybrid
    n_layers: int
    window: Optional[int]      # None => global attention

    @property
    def has_ffn(self) -> bool:
        return self.kind != "mamba"

    @property
    def is_moe(self) -> bool:
        return self.kind in ("moe", "mla_moe")


def _layer_spec(cfg: ModelConfig, i: int) -> Tuple[str, Optional[int]]:
    if cfg.family == "ssm":
        return "mamba", None
    if cfg.family == "hybrid":
        win = None if i in cfg.global_layers else cfg.window
        return "hybrid", win
    win = None if (cfg.window is None or i in cfg.global_layers) else cfg.window
    if cfg.uses_moe and cfg.mla:
        return ("mla_dense" if i < cfg.first_dense_layers else "mla_moe"), win
    if cfg.uses_moe:
        return ("dense" if i < cfg.first_dense_layers else "moe"), win
    return "dense", win


def build_segments(cfg: ModelConfig) -> List[Segment]:
    segs: List[Segment] = []
    for i in range(cfg.num_layers):
        kind, win = _layer_spec(cfg, i)
        if segs and segs[-1].kind == kind and segs[-1].window == win:
            segs[-1] = dataclasses.replace(segs[-1], n_layers=segs[-1].n_layers + 1)
        else:
            segs.append(Segment(kind, 1, win))
    return segs


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str) -> Dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": norm_init(cfg)}
    if kind in ("dense", "moe"):
        p["attn"] = attention_params(ks[0], cfg)
    elif kind in ("mla_dense", "mla_moe"):
        p["attn"] = mla_mod.mla_params(ks[0], cfg)
    elif kind == "mamba":
        p["mixer"] = mamba_mod.mamba_params(ks[0], cfg)
        return p
    elif kind == "hybrid":
        p.update(hybrid_mod.hybrid_params(ks[0], cfg))
    else:
        raise ValueError(kind)
    p["norm2"] = norm_init(cfg)
    if kind in ("moe", "mla_moe"):
        p["moe"] = moe_mod.moe_params(ks[1], cfg)
    else:
        p["mlp"] = mlp_params(ks[1], cfg)
    return p


def _block_apply(p, x, cfg: ModelConfig, seg: Segment, positions,
                 act: Callable, ep_act=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg)
    if seg.kind == "mamba":
        return act(x + mamba_mod.apply_mamba(p["mixer"], h, cfg)), aux
    if seg.kind == "hybrid":
        x = x + hybrid_mod.apply_hybrid(p, h, cfg, positions, seg.window)
    elif seg.kind in ("mla_dense", "mla_moe"):
        x = x + mla_mod.apply_mla(p["attn"], h, cfg, positions)
    else:
        q, k, v = qkv_project(p["attn"], h, cfg, positions)
        a = flash_attention_lax(q, k, v, causal=True, window=seg.window,
                                unroll=cfg.unroll,
                                scale_in_q=cfg.attn_scale_in_q,
                                probs_bf16=cfg.attn_probs_bf16)
        x = x + attention_out(p["attn"], a, x.dtype)
    x = act(x)
    h2 = apply_norm(p["norm2"], x, cfg)
    if seg.is_moe:
        y, aux = moe_mod.apply_moe(p["moe"], h2, cfg, ep_act)
    else:
        y = apply_mlp(p["mlp"], h2, cfg)
    return act(x + y), aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _segment_cache(cfg: ModelConfig, seg: Segment, batch: int, max_len: int,
                   dtype) -> Dict:
    """Zero cache for one segment (leading layer axis L)."""
    L = seg.n_layers
    size = max_len if seg.window is None else min(max_len, seg.window)
    c: Dict[str, jnp.ndarray] = {}
    if seg.kind in ("dense", "moe"):
        kv, dh = cfg.num_kv_heads, cfg.head_dim
        c["k"] = jnp.zeros((L, batch, size, kv, dh), dtype)
        c["v"] = jnp.zeros((L, batch, size, kv, dh), dtype)
    elif seg.kind in ("mla_dense", "mla_moe"):
        c["c_kv"] = jnp.zeros((L, batch, size, cfg.kv_lora_rank), dtype)
        c["k_rope"] = jnp.zeros((L, batch, size, cfg.qk_rope_dim), dtype)
    elif seg.kind == "mamba":
        c["h"] = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
        c["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)
    elif seg.kind == "hybrid":
        kv, dh = cfg.num_kv_heads, cfg.head_dim
        c["k"] = jnp.zeros((L, batch, size, kv, dh), dtype)
        c["v"] = jnp.zeros((L, batch, size, kv, dh), dtype)
        c["h"] = jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
        c["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)
    return c


def _block_prefill(p, x, cfg: ModelConfig, seg: Segment, positions, max_len,
                   act: Callable, ep_act=None) -> Tuple[jnp.ndarray, Dict]:
    """Full-seq forward that also emits this layer's cache entry (no aux)."""
    h = apply_norm(p["norm1"], x, cfg)
    t = x.shape[1]
    size = max_len if seg.window is None else min(max_len, seg.window)
    cache: Dict[str, jnp.ndarray] = {}
    if seg.kind == "mamba":
        x2, cache = _mamba_prefill(p["mixer"], h, cfg)
        cache.pop("y")
        return act(x + x2), cache
    if seg.kind == "hybrid":
        q, k, v = qkv_project(p["attn"], h, cfg, positions)
        a = flash_attention_lax(q, k, v, causal=True, window=seg.window,
                                unroll=cfg.unroll,
                                scale_in_q=cfg.attn_scale_in_q,
                                probs_bf16=cfg.attn_probs_bf16)
        a = attention_out(p["attn"], a, x.dtype)
        s, mcache = _mamba_prefill(p["mixer"], h, cfg)
        mcache.pop("y")
        x = x + 0.5 * (apply_norm(p["norm_a"], a, cfg)
                       + apply_norm(p["norm_s"], s, cfg))
        cache.update(_ring_fill(k, v, size, x.dtype))
        cache.update(mcache)
    elif seg.kind in ("mla_dense", "mla_moe"):
        out, mc = mla_mod.apply_mla_prefill(p["attn"], h, cfg, positions, size)
        x = x + out
        cache.update(mc)
    else:
        q, k, v = qkv_project(p["attn"], h, cfg, positions)
        a = flash_attention_lax(q, k, v, causal=True, window=seg.window,
                                unroll=cfg.unroll,
                                scale_in_q=cfg.attn_scale_in_q,
                                probs_bf16=cfg.attn_probs_bf16)
        x = x + attention_out(p["attn"], a, x.dtype)
        cache.update(_ring_fill(k, v, size, x.dtype))
    x = act(x)
    h2 = apply_norm(p["norm2"], x, cfg)
    if seg.is_moe:
        y, _ = moe_mod.apply_moe(p["moe"], h2, cfg, ep_act)
    else:
        y = apply_mlp(p["mlp"], h2, cfg)
    return act(x + y), cache


def _ring_fill(k, v, size: int, dtype) -> Dict:
    """Write the last ``size`` positions of prefilled K/V into a cache."""
    t = k.shape[1]
    if t >= size:
        kc, vc = k[:, t - size:], v[:, t - size:]
        # ring alignment: position p sits at slot p % size
        shift = (t - size) % size
        kc = jnp.roll(kc, shift=shift, axis=1)
        vc = jnp.roll(vc, shift=shift, axis=1)
    else:
        pad = ((0, 0), (0, size - t), (0, 0), (0, 0))
        kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
    return {"k": kc.astype(dtype), "v": vc.astype(dtype)}


def _mamba_prefill(p, h, cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    """Run the mamba mixer over the prompt, keeping final (h, conv) state."""
    di = p["in_proj"].shape[1] // 2
    uz = h @ p["in_proj"].astype(h.dtype)
    u, z = jnp.split(uz, 2, axis=-1)
    conv_tail = u[:, -(cfg.ssm_conv - 1):]                      # pre-activation
    u, _ = mamba_mod._causal_conv(u, p["conv_w"], p["conv_b"])
    u = jax.nn.silu(u)
    dtr = p["dt_proj"].shape[0]
    proj = u @ p["x_proj"].astype(u.dtype)
    dt_lr, b_in, c_in = jnp.split(proj, [dtr, dtr + cfg.ssm_state], axis=-1)
    dt = jax.nn.softplus(dt_lr @ p["dt_proj"].astype(u.dtype)
                         + p["dt_bias"].astype(u.dtype))
    y, h_fin = mamba_mod.selective_scan(u, dt, b_in, c_in, p["a_log"],
                                        p["d_skip"], unroll=cfg.unroll)
    y = y.astype(h.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(h.dtype)
    t = h.shape[1]
    if t < cfg.ssm_conv - 1:
        conv_tail = jnp.pad(conv_tail, ((0, 0), (cfg.ssm_conv - 1 - t, 0), (0, 0)))
    return out, {"h": h_fin, "conv": conv_tail, "y": out}


def _block_decode(p, x, cfg: ModelConfig, seg: Segment, cache: Dict,
                  cache_len, ep_act=None) -> Tuple[jnp.ndarray, Dict]:
    h = apply_norm(p["norm1"], x, cfg)
    new_cache = dict(cache)
    if seg.kind == "mamba":
        y, st = mamba_mod.apply_mamba_decode(p["mixer"], h, cache, cfg)
        return x + y, st
    if seg.kind == "hybrid":
        y, new_cache = hybrid_mod.apply_hybrid_decode(p, h, cfg, cache,
                                                      cache_len, seg.window)
        x = x + y
    elif seg.kind in ("mla_dense", "mla_moe"):
        y, mc = mla_mod.apply_mla_decode(p["attn"], h, cfg, cache, cache_len)
        x = x + y
        new_cache = mc
    else:
        pos = jnp.full((x.shape[0], 1), cache_len, jnp.int32)
        q, k, v = qkv_project(p["attn"], h, cfg, pos)
        size = cache["k"].shape[1]
        slot = cache_len % size if seg.window is not None else cache_len
        kc = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
        vc = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
        n_valid = jnp.minimum(cache_len + 1, size)
        a = decode_attention(q, kc, vc, n_valid)
        x = x + attention_out(p["attn"], a, x.dtype)
        new_cache = {"k": kc, "v": vc}
    h2 = apply_norm(p["norm2"], x, cfg)
    if seg.is_moe:
        y, _ = moe_mod.apply_moe(p["moe"], h2, cfg, ep_act)
    else:
        y = apply_mlp(p["mlp"], h2, cfg)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class Model:
    """Config-driven LM with train / prefill / decode entry points."""

    def __init__(self, cfg: ModelConfig,
                 act_constraint: Optional[Callable] = None,
                 rules: Optional[Any] = None):
        self.cfg = cfg
        self.segments = build_segments(cfg)
        if rules is not None and act_constraint is None:
            act_constraint = rules.act_constraint
        self.act = act_constraint or (lambda x: x)
        self.ep_act = rules.expert_constraint if rules is not None else None
        self.dtype = jnp.dtype(cfg.dtype)

    # -- init ----------------------------------------------------------------
    def init(self, key) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.segments) + 4)
        params: Dict[str, Any] = {}
        if cfg.family == "audio":
            params["embed"] = embed_init(keys[0], cfg.num_codebooks * cfg.vocab_size,
                                         cfg.d_model)
            params["out_embed"] = embed_init(keys[1], cfg.num_codebooks * cfg.vocab_size,
                                             cfg.d_model)
        else:
            params["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model)
            if not cfg.tie_embeddings:
                params["out_embed"] = embed_init(keys[1], cfg.vocab_size,
                                                 cfg.d_model)
        if cfg.family == "vlm":
            params["connector"] = (jax.random.normal(
                keys[2], (cfg.d_model, cfg.d_model)) / math.sqrt(cfg.d_model)
            ).astype(jnp.float32)
        params["final_norm"] = norm_init(cfg)
        segs = []
        for si, seg in enumerate(self.segments):
            lkeys = jax.random.split(keys[3 + si], seg.n_layers)
            segs.append(jax.vmap(lambda k: _block_init(k, cfg, seg.kind))(lkeys))
        params["segments"] = segs
        return params

    # -- embedding helpers ------------------------------------------------------
    def _embed_tokens(self, params, tokens) -> jnp.ndarray:
        cfg = self.cfg
        emb = params["embed"].astype(self.dtype)
        if cfg.family == "audio":
            # tokens (B, T, C); codebook c uses rows [c*V, (c+1)*V)
            offs = (jnp.arange(cfg.num_codebooks, dtype=jnp.int32)
                    * cfg.vocab_size)
            x = jnp.take(emb, tokens + offs[None, None, :], axis=0).sum(axis=2)
            return x
        return jnp.take(emb, tokens, axis=0)

    def _unembed(self, params) -> jnp.ndarray:
        if self.cfg.tie_embeddings or "out_embed" not in params:
            return params["embed"]
        return params["out_embed"]

    def _stack(self, params, x, positions, mode: str, caches=None,
               cache_len=None, max_len: int = 0):
        """Run all segments; returns (x, aux) or (x, caches)."""
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for si, seg in enumerate(self.segments):
            sp = params["segments"][si]
            if mode == "train":
                def body(carry, lp, seg=seg):
                    xx, aux = carry
                    xx, a = _block_apply(lp, xx, cfg, seg, positions,
                                         self.act, self.ep_act)
                    return (xx, aux + a), None
                if cfg.remat:
                    body = jax.checkpoint(body,
                                          policy=jax.checkpoint_policies.nothing_saveable)
                (x, aux_total), _ = lax.scan(
                    body, (x, aux_total), sp,
                    unroll=seg.n_layers if cfg.unroll else 1)
            elif mode == "prefill":
                def body_p(xx, lp, seg=seg):
                    xx, cache = _block_prefill(lp, xx, cfg, seg, positions,
                                               max_len, self.act, self.ep_act)
                    return xx, cache
                if cfg.remat:
                    body_p = jax.checkpoint(body_p,
                                            policy=jax.checkpoint_policies.nothing_saveable)
                x, cache = lax.scan(body_p, x, sp,
                                    unroll=seg.n_layers if cfg.unroll else 1)
                new_caches.append(cache)
            else:  # decode
                def body_d(xx, inp, seg=seg):
                    lp, cl = inp
                    xx, nc = _block_decode(lp, xx, cfg, seg, cl, cache_len,
                                           self.ep_act)
                    return xx, nc
                x, nc = lax.scan(body_d, x, (sp, caches[si]),
                                 unroll=seg.n_layers if cfg.unroll else 1)
                new_caches.append(nc)
        if mode == "train":
            return x, aux_total
        return x, new_caches

    # -- entry points ------------------------------------------------------------
    def _forward_hidden(self, params, batch: Dict
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
        """Shared train-mode trunk: returns (hidden, aux, n_prefix)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = self._embed_tokens(params, tokens)
        n_prefix = 0
        if cfg.family == "vlm":
            patches = batch["patches"].astype(self.dtype)
            patches = patches @ params["connector"].astype(self.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            n_prefix = patches.shape[1]
        x = self.act(x)
        t = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
        x, aux = self._stack(params, x, positions, "train")
        x = apply_norm(params["final_norm"], x, cfg)
        return x, aux, n_prefix

    def logits_full(self, params, batch: Dict) -> jnp.ndarray:
        """Teacher-forced logits for every position (tests/small shapes)."""
        x, _, n_prefix = self._forward_hidden(params, batch)
        return self._logits(params, x[:, n_prefix:])

    def loss(self, params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        """Next-token LM loss. Returns (loss, metrics)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x, aux, n_prefix = self._forward_hidden(params, batch)
        out_emb = self._unembed(params)
        if cfg.family == "audio":
            # per-codebook CE against the shared (C*V, d) output table
            losses = []
            for c in range(cfg.num_codebooks):
                emb_c = lax.dynamic_slice_in_dim(out_emb, c * cfg.vocab_size,
                                                 cfg.vocab_size, axis=0)
                labels = tokens[:, 1:, c]
                mask = jnp.ones_like(labels, jnp.float32)
                losses.append(chunked_cross_entropy(
                    x[:, :-1], emb_c, labels, chunk=cfg.loss_chunk, mask=mask,
                    unroll=cfg.unroll))
            ce = jnp.mean(jnp.stack(losses))
        else:
            if cfg.family == "vlm":
                hid = x[:, n_prefix:]
                lm_tokens = tokens
            else:
                hid = x
                lm_tokens = tokens
            labels = lm_tokens[:, 1:]
            ce = chunked_cross_entropy(hid[:, :-1], out_emb, labels,
                                       chunk=cfg.loss_chunk, unroll=cfg.unroll)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    def init_cache(self, batch: int, max_len: int) -> List[Dict]:
        return [_segment_cache(self.cfg, seg, batch, max_len, self.dtype)
                for seg in self.segments]

    def prefill(self, params, batch: Dict, max_len: int
                ) -> Tuple[jnp.ndarray, List[Dict]]:
        """Returns (last-position logits (B, V[, C]), caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = self._embed_tokens(params, tokens)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(self.dtype)
            patches = patches @ params["connector"].astype(self.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        x = self.act(x)
        t = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
        x, caches = self._stack(params, x, positions, "prefill", max_len=max_len)
        x = apply_norm(params["final_norm"], x[:, -1:], cfg)
        logits = self._logits(params, x)[:, 0]
        return logits, caches

    def decode_step(self, params, tokens, caches: List[Dict], cache_len
                    ) -> Tuple[jnp.ndarray, List[Dict]]:
        """tokens: (B, 1[, C]); cache_len: int32 scalar = cache entries so
        far (for vlm this INCLUDES the patch prefix positions)."""
        cfg = self.cfg
        x = self._embed_tokens(params, tokens)
        x = self.act(x)
        x, new_caches = self._stack(params, x, None, "decode", caches=caches,
                                    cache_len=cache_len)
        x = apply_norm(params["final_norm"], x, cfg)
        logits = self._logits(params, x)[:, 0]
        return logits, new_caches

    def _logits(self, params, x) -> jnp.ndarray:
        cfg = self.cfg
        emb = self._unembed(params).astype(x.dtype)
        logits = jnp.einsum("btd,vd->btv", x, emb)
        if cfg.family == "audio":
            b, t, _ = logits.shape
            return logits.reshape(b, t, cfg.num_codebooks, cfg.vocab_size)
        return logits

    # -- parameter census ----------------------------------------------------
    def param_count(self, params) -> int:
        return sum(int(p.size) for p in jax.tree.leaves(params))

    def active_param_count(self, params) -> int:
        """MoE-aware: routed experts count at top_k/E of their size."""
        cfg = self.cfg
        if not cfg.uses_moe:
            return self.param_count(params)
        total = 0
        frac = cfg.experts_top_k / cfg.num_experts
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
            if "moe" in keys and any(k in ("wi", "wg", "wo") for k in keys):
                total += int(leaf.size * frac)
            else:
                total += int(leaf.size)
        return total


def build_model(cfg: ModelConfig, act_constraint: Optional[Callable] = None,
                rules: Optional[Any] = None) -> Model:
    return Model(cfg, act_constraint, rules=rules)
