"""Multi-head Latent Attention (DeepSeek-V2).

Projections:
  q: d -> q_lora -> norm -> H x (qk_nope + qk_rope)
  kv: d -> (kv_lora latent || shared k_rope) ; latent -> norm -> per-head
      k_nope and v.

Train/prefill expand the latent; decode uses the *absorbed* form, attending
in latent space against a (kv_lora + qk_rope)-wide cache — 576 B-equiv per
token instead of H*(dk+dv), the paper-grade KV-cache compression that makes
deepseek-v2's decode_32k cell memory-light.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (_dense_init, apply_norm, apply_rope,
                                 flash_attention_lax, norm_init)


def mla_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 7)
    p = {
        "kv_down": _dense_init(ks[2], (d, cfg.kv_lora_rank + dr), dtype=dtype),
        "kv_norm": norm_init(cfg, cfg.kv_lora_rank),
        "k_up": _dense_init(ks[3], (cfg.kv_lora_rank, h, dn), dtype=dtype),
        "v_up": _dense_init(ks[4], (cfg.kv_lora_rank, h, dv), dtype=dtype),
        "wo": _dense_init(ks[5], (h, dv, d), scale=1.0 / math.sqrt(h * dv),
                          dtype=dtype),
    }
    if cfg.q_lora_rank:
        p["q_down"] = _dense_init(ks[0], (d, cfg.q_lora_rank), dtype=dtype)
        p["q_norm"] = norm_init(cfg, cfg.q_lora_rank)
        p["q_up"] = _dense_init(ks[1], (cfg.q_lora_rank, h, dn + dr), dtype=dtype)
    else:
        p["q_proj"] = _dense_init(ks[1], (d, h, dn + dr), dtype=dtype)
    return p


def _q_heads(p, x, cfg: ModelConfig, positions):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        ql = apply_norm(p["q_norm"], x @ p["q_down"].astype(x.dtype), cfg)
        q = jnp.einsum("btl,lhk->bthk", ql, p["q_up"].astype(x.dtype))
    else:
        q = jnp.einsum("btd,dhk->bthk", x, p["q_proj"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg)
    return q_nope, q_rope


def _kv_latent(p, x, cfg: ModelConfig, positions):
    dr = cfg.qk_rope_dim
    kv = x @ p["kv_down"].astype(x.dtype)
    c_kv, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = apply_norm(p["kv_norm"], c_kv, cfg)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg)[:, :, 0]  # (B,T,dr)
    return c_kv, k_rope


def apply_mla(p, x, cfg: ModelConfig, positions) -> jnp.ndarray:
    """Full-sequence MLA (training/prefill compute path). x: (B,T,d)."""
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _q_heads(p, x, cfg, positions)
    c_kv, k_rope = _kv_latent(p, x, cfg, positions)
    k_nope = jnp.einsum("btl,lhk->bthk", c_kv, p["k_up"].astype(x.dtype))
    v = jnp.einsum("btl,lhk->bthk", c_kv, p["v_up"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          k_nope.shape[:3] + (dr,))], axis=-1)
    scale = 1.0 / math.sqrt(dn + dr)
    attn = flash_attention_lax(q, k, v, causal=True, scale=scale,
                               unroll=cfg.unroll,
                               scale_in_q=cfg.attn_scale_in_q,
                               probs_bf16=cfg.attn_probs_bf16)
    return jnp.einsum("bthk,hkd->btd", attn, p["wo"].astype(x.dtype))


def mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}


def apply_mla_prefill(p, x, cfg: ModelConfig, positions, max_len: int
                      ) -> Tuple[jnp.ndarray, Dict]:
    out = apply_mla(p, x, cfg, positions)
    c_kv, k_rope = _kv_latent(p, x, cfg, positions)
    t = x.shape[1]
    cache = mla_cache(cfg, x.shape[0], max_len, x.dtype)
    cache["c_kv"] = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, 0, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope,
                                                   (0, 0, 0))
    return out, cache


def apply_mla_decode(p, x, cfg: ModelConfig, cache: Dict, cache_len
                     ) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed single-token decode. x: (B, 1, d); cache_len: int32 scalar."""
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.full((x.shape[0], 1), cache_len, jnp.int32)
    q_nope, q_rope = _q_heads(p, x, cfg, positions)          # (B,1,H,*)
    c_new, r_new = _kv_latent(p, x, cfg, positions)          # (B,1,l),(B,1,dr)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, cache_len, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], r_new.astype(cache["k_rope"].dtype), (0, cache_len, 0))
    # absorb k_up into q: q_lat (B,1,H,kv_lora)
    q_lat = jnp.einsum("bthk,lhk->bthl", q_nope, p["k_up"].astype(x.dtype))
    s = jnp.einsum("bthl,bsl->bths", q_lat, c_kv) \
        + jnp.einsum("bthk,bsk->bths", q_rope, k_rope)
    s = s.astype(jnp.float32) / math.sqrt(dn + dr)
    pos = jnp.arange(c_kv.shape[1])
    valid = pos[None, :] <= cache_len
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bths,bsl->bthl", probs, c_kv)          # latent context
    heads = jnp.einsum("bthl,lhk->bthk", ctx, p["v_up"].astype(x.dtype))
    out = jnp.einsum("bthk,hkd->btd", heads, p["wo"].astype(x.dtype))
    return out, {"c_kv": c_kv, "k_rope": k_rope}
