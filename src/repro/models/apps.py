"""The paper's three applications as runnable JAX minis (§2, Table 1).

These are the workloads FanStore was built for; the benchmark harness
drives them through the data plane for the Fig 4/7/8/9 reproductions and
the tests train them for a few steps:

  ResNetMini — convolutional residual classifier (ResNet-50 stand-in)
  SRGANMini  — super-resolution generator + discriminator (SRGAN stand-in),
               trained with the paper's two stages (init = pixel loss,
               train = pixel + adversarial)
  FRNNMini   — LSTM disruption predictor over diagnostic-signal windows

Pure JAX, same param-pytree conventions as the LM zoo.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _conv_init(key, kh, kw, cin, cout):
    scale = 1.0 / math.sqrt(kh * kw * cin)
    return jax.random.normal(key, (kh, kw, cin, cout)) * scale


def conv2d(x, w, *, stride: int = 1, padding: str = "SAME"):
    return lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# ---------------------------------------------------------------------------
# ResNet mini
# ---------------------------------------------------------------------------

class ResNetMini:
    """[stem] -> n_blocks x [conv-relu-conv + skip] -> pool -> classifier."""

    def __init__(self, *, num_classes: int = 10, width: int = 32,
                 n_blocks: int = 4):
        self.num_classes = num_classes
        self.width = width
        self.n_blocks = n_blocks

    def init(self, key) -> Dict:
        ks = jax.random.split(key, 2 + 2 * self.n_blocks)
        w = self.width
        p = {"stem": _conv_init(ks[0], 3, 3, 3, w), "blocks": []}
        for i in range(self.n_blocks):
            p["blocks"].append({
                "c1": _conv_init(ks[1 + 2 * i], 3, 3, w, w),
                "c2": _conv_init(ks[2 + 2 * i], 3, 3, w, w)})
        p["head"] = jax.random.normal(ks[-1], (w, self.num_classes)) / math.sqrt(w)
        return p

    def apply(self, p, x) -> jnp.ndarray:
        h = jax.nn.relu(conv2d(x, p["stem"]))
        for blk in p["blocks"]:
            r = jax.nn.relu(conv2d(h, blk["c1"]))
            r = conv2d(r, blk["c2"])
            h = jax.nn.relu(h + r)
        h = h.mean(axis=(1, 2))                      # global average pool
        return h @ p["head"]

    def loss(self, p, batch) -> jnp.ndarray:
        logits = self.apply(p, batch["image"])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["label"][:, None], 1)[:, 0]
        return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# SRGAN mini
# ---------------------------------------------------------------------------

class SRGANMini:
    """4x upscaling generator + patch discriminator (paper's SRGAN case)."""

    def __init__(self, *, width: int = 32, n_blocks: int = 3):
        self.width = width
        self.n_blocks = n_blocks

    def init(self, key) -> Dict:
        kg, kd = jax.random.split(key)
        w = self.width
        ks = jax.random.split(kg, 3 + 2 * self.n_blocks)
        gen = {"inp": _conv_init(ks[0], 3, 3, 3, w), "blocks": []}
        for i in range(self.n_blocks):
            gen["blocks"].append({
                "c1": _conv_init(ks[1 + 2 * i], 3, 3, w, w),
                "c2": _conv_init(ks[2 + 2 * i], 3, 3, w, w)})
        gen["up"] = _conv_init(ks[-2], 3, 3, w, 16 * 3)   # pixel-shuffle 4x
        kds = jax.random.split(kd, 3)
        disc = {"c1": _conv_init(kds[0], 3, 3, 3, w),
                "c2": _conv_init(kds[1], 3, 3, w, w),
                "head": jax.random.normal(kds[2], (w, 1)) / math.sqrt(w)}
        return {"gen": gen, "disc": disc}

    def generate(self, g, lr_img) -> jnp.ndarray:
        h = jax.nn.relu(conv2d(lr_img, g["inp"]))
        for blk in g["blocks"]:
            r = jax.nn.relu(conv2d(h, blk["c1"]))
            h = h + conv2d(r, blk["c2"])
        h = conv2d(h, g["up"])                        # (B, H, W, 48)
        b, hh, ww, _ = h.shape
        h = h.reshape(b, hh, ww, 4, 4, 3)
        h = h.transpose(0, 1, 3, 2, 4, 5).reshape(b, hh * 4, ww * 4, 3)
        return jnp.tanh(h)

    def discriminate(self, d, img) -> jnp.ndarray:
        h = jax.nn.leaky_relu(conv2d(img, d["c1"], stride=2))
        h = jax.nn.leaky_relu(conv2d(h, d["c2"], stride=2))
        return h.mean(axis=(1, 2)) @ d["head"]

    def init_stage_loss(self, p, batch) -> jnp.ndarray:
        """Stage 1 (paper's SRGAN-Init): pixel-wise L2 only."""
        sr = self.generate(p["gen"], batch["lr"])
        return jnp.mean((sr - batch["hr"]) ** 2)

    def train_stage_losses(self, p, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Stage 2 (SRGAN-Train): (generator, discriminator) losses."""
        sr = self.generate(p["gen"], batch["lr"])
        pix = jnp.mean((sr - batch["hr"]) ** 2)
        d_fake = self.discriminate(p["disc"], sr)
        d_real = self.discriminate(p["disc"], batch["hr"])
        g_adv = jnp.mean(jax.nn.softplus(-d_fake))
        g_loss = pix + 1e-3 * g_adv
        d_loss = jnp.mean(jax.nn.softplus(-d_real)) + \
            jnp.mean(jax.nn.softplus(d_fake))
        return g_loss, d_loss


# ---------------------------------------------------------------------------
# FRNN mini
# ---------------------------------------------------------------------------

class FRNNMini:
    """Stacked LSTM over diagnostic windows -> per-shot disruption logit."""

    def __init__(self, *, n_signals: int = 14, hidden: int = 64,
                 layers: int = 2):
        self.n_signals = n_signals
        self.hidden = hidden
        self.layers = layers

    def _cell_init(self, key, nin, nh):
        k1, k2 = jax.random.split(key)
        return {"wx": jax.random.normal(k1, (nin, 4 * nh)) / math.sqrt(nin),
                "wh": jax.random.normal(k2, (nh, 4 * nh)) / math.sqrt(nh),
                "b": jnp.zeros((4 * nh,))}

    def init(self, key) -> Dict:
        ks = jax.random.split(key, self.layers + 1)
        cells = [self._cell_init(ks[i],
                                 self.n_signals if i == 0 else self.hidden,
                                 self.hidden)
                 for i in range(self.layers)]
        head = jax.random.normal(ks[-1], (self.hidden, 1)) / math.sqrt(self.hidden)
        return {"cells": cells, "head": head}

    @staticmethod
    def _lstm_step(cell, carry, x):
        h, c = carry
        z = x @ cell["wx"] + h @ cell["wh"] + cell["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    def apply(self, p, signals) -> jnp.ndarray:
        """signals: (B, T, n_signals) -> disruption logits (B,)."""
        b = signals.shape[0]
        h = signals
        for cell in p["cells"]:
            init = (jnp.zeros((b, self.hidden)), jnp.zeros((b, self.hidden)))
            (_, _), hs = lax.scan(
                lambda carry, x: self._lstm_step(cell, carry, x),
                init, h.swapaxes(0, 1))
            h = hs.swapaxes(0, 1)
        return (h[:, -1] @ p["head"])[:, 0]

    def loss(self, p, batch) -> jnp.ndarray:
        logit = self.apply(p, batch["signals"])
        y = batch["disrupted"].astype(jnp.float32)
        return jnp.mean(jax.nn.softplus(logit) - y * logit)
