"""Shared neural building blocks (pure functions, params as pytrees).

Conventions:
  * activations ``x`` are (batch, seq, d_model) in ``cfg.dtype`` (bf16),
  * params are fp32 leaves in nested dicts; scanned stacks add a leading
    layer axis,
  * attention is computed with a blocked online-softmax scan (flash-style,
    pure lax) so the T x T score matrix is never materialized — the Pallas
    kernel in repro.kernels.flash_attn is the TPU-tiled version of the same
    algorithm and is swapped in by ops.attention when enabled.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) <= 2 else math.prod(shape[:-1])
    scale = scale if scale is not None else 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        out = xf * lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, rot_dim: int) -> jnp.ndarray:
    exponent = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim
    return 1.0 / (cfg.rope_theta ** exponent)            # (rot_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig
               ) -> jnp.ndarray:
    """Rotate the first ``rot_dim`` dims of each head.

    cfg.rope == "full": rot_dim = head_dim (llama/qwen style).
    cfg.rope == "half": rot_dim = head_dim // 2 (chatglm's 2d/partial rotary).
    x: (B, T, H, dh); positions: (B, T) int32.
    """
    if cfg.rope == "none":
        return x
    dh = x.shape[-1]
    rot = dh if cfg.rope == "full" else dh // 2
    inv = rope_freqs(cfg, rot)
    theta = positions[..., None].astype(jnp.float32) * inv   # (B,T,rot/2)
    cos = jnp.cos(theta)[:, :, None, :]
    sin = jnp.sin(theta)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (blocked online-softmax; GQA; causal + optional sliding window)
# ---------------------------------------------------------------------------

def attention_params(key, cfg: ModelConfig, dtype=jnp.float32):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h, dh), dtype=dtype),
        "wk": _dense_init(ks[1], (d, kv, dh), dtype=dtype),
        "wv": _dense_init(ks[2], (d, kv, dh), dtype=dtype),
        "wo": _dense_init(ks[3], (h, dh, d), scale=1.0 / math.sqrt(h * dh),
                          dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((kv, dh), dtype)
        p["bv"] = jnp.zeros((kv, dh), dtype)
    return p


def qkv_project(p, x, cfg: ModelConfig, positions):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    return q, k, v


def flash_attention_lax(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        scale: Optional[float] = None,
                        unroll: bool = False,
                        scale_in_q: bool = False,
                        probs_bf16: bool = False) -> jnp.ndarray:
    """Blocked attention with online softmax — O(T) memory, pure lax.

    q: (B, Tq, H, dh); k, v: (B, Tk, KV, dh) with H % KV == 0.
    ``q_offset`` is the absolute position of q[0] (prefill continuation).
    A kv block whose mask is entirely zero is still computed (static grid) —
    the Pallas kernel version skips them; roofline treats this as the
    reference cost.
    """
    b, tq, h, dh = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]                    # may differ from dh (MLA)
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    if unroll:          # cost-exact mode: single-trip kv loop (counted fully)
        block_k = tk
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    nq, nk = -(-tq // block_q), -(-tk // block_k)
    # pad to block multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * block_q - tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * block_k - tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * block_k - tk), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, block_q, kvh, g, dh)
    if scale_in_q:
        qp = (qp.astype(jnp.float32) * scale).astype(q.dtype)
    kp = kp.reshape(b, nk, block_k, kvh, dh)
    vp = vp.reshape(b, nk, block_k, kvh, dv)
    q_pos = q_offset + jnp.arange(nq * block_q).reshape(nq, block_q)
    k_pos = jnp.arange(nk * block_k).reshape(nk, block_k)

    def kv_step(carry, kv_idx):
        m, l, acc = carry          # (b,nq,bq,kvh,g), same, (...,dh)
        kb = kp[:, kv_idx]         # (b, bk, kvh, dh)
        vb = vp[:, kv_idx]
        s = jnp.einsum("bnqhgd,bkhd->bnqhgk", qp, kb).astype(jnp.float32)
        if not scale_in_q:
            s = s * scale
        qpos = q_pos[:, :, None]                       # (nq, bq, 1)
        kpos = k_pos[kv_idx][None, None, :]            # (1, 1, bk)
        mask = (kpos <= qpos) if causal else jnp.ones_like(kpos <= qpos)
        if window is not None:
            mask &= (qpos - kpos) < window
        mask &= kpos < tk                              # exclude kv padding
        s = jnp.where(mask[None, :, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        if probs_bf16:       # keep the (.., bk)-sized probs in bf16; f32 stats
            p_ = jnp.exp((s - m_new[..., None]).astype(jnp.bfloat16))
            l_new = l * alpha + p_.sum(-1, dtype=jnp.float32)
            pv = p_.astype(vb.dtype)
        else:
            p_ = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p_.sum(-1)
            pv = p_.astype(vb.dtype)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bnqhgk,bkhd->bnqhgd", pv, vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, nq, block_q, kvh, g), -1e30, jnp.float32),
            jnp.zeros((b, nq, block_q, kvh, g), jnp.float32),
            jnp.zeros((b, nq, block_q, kvh, g, dv), jnp.float32))
    (m, l, acc), _ = lax.scan(kv_step, init, jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(b, nq * block_q, kvh * g, dv)[:, :tq]
    return out.astype(q.dtype)


def attention_out(p, attn, x_dtype):
    return jnp.einsum("bthk,hkd->btd", attn,
                      p["wo"].astype(attn.dtype)).astype(x_dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Single-position attention against a (B, S, KV, dh) cache.

    ``cache_len``: number of valid positions (int32 scalar or (B,)).
    """
    b, tq, h, dh = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, tq, kvh, g, dh)
    s = jnp.einsum("bthgd,bshd->bthgs", qg, k_cache).astype(jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    if window is not None:
        valid &= pos[None, :] >= (jnp.reshape(cache_len, (-1, 1)) - window)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p_ = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bthgs,bshd->bthgd", p_, v_cache)
    return out.reshape(b, tq, h, dh)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_params(key, cfg: ModelConfig, d_ff: Optional[int] = None,
               dtype=jnp.float32):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {"wi": _dense_init(ks[0], (d, f), dtype=dtype),
                "wg": _dense_init(ks[1], (d, f), dtype=dtype),
                "wo": _dense_init(ks[2], (f, d), dtype=dtype)}
    return {"wi": _dense_init(ks[0], (d, f), dtype=dtype),
            "wo": _dense_init(ks[2], (f, d), dtype=dtype)}


def apply_mlp(p, x, cfg: ModelConfig):
    wi = p["wi"].astype(x.dtype)
    wo = p["wo"].astype(x.dtype)
    h = x @ wi
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(h) * (x @ p["wg"].astype(x.dtype))
    elif cfg.mlp == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.mlp == "sqrelu":
        r = jax.nn.relu(h)
        h = r * r
    else:
        raise ValueError(cfg.mlp)
    return h @ wo


# ---------------------------------------------------------------------------
# logits / loss
# ---------------------------------------------------------------------------

def chunked_cross_entropy(hidden: jnp.ndarray, embed: jnp.ndarray,
                          labels: jnp.ndarray, *, chunk: int = 2048,
                          mask: Optional[jnp.ndarray] = None,
                          unroll: bool = False) -> jnp.ndarray:
    """Mean CE without materializing the full (tokens, vocab) logits.

    hidden: (B, T, d); embed: (V, d); labels: (B, T) int32; mask (B, T) or
    None. Scans over token chunks; each chunk's logits are (chunk, V) only.
    """
    b, t, d = hidden.shape
    n = b * t
    hf = hidden.reshape(n, d)
    lf = labels.reshape(n)
    mf = jnp.ones((n,), jnp.float32) if mask is None else \
        mask.reshape(n).astype(jnp.float32)
    if unroll:          # cost-exact mode: single-trip CE loop
        chunk = n
    chunk = min(chunk, n)
    pad = (-n) % chunk
    if pad:
        hf = jnp.pad(hf, ((0, pad), (0, 0)))
        lf = jnp.pad(lf, (0, pad))
        mf = jnp.pad(mf, (0, pad))
    hc = hf.reshape(-1, chunk, d)
    lc = lf.reshape(-1, chunk)
    mc = mf.reshape(-1, chunk)
    et = embed.astype(hidden.dtype).T           # (d, V)

    def step(carry, xs):
        h, l, m = xs
        logits = (h @ et).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[:, None], axis=-1)[:, 0]
        ce = (logz - gold) * m
        return carry + ce.sum(), None

    # checkpoint: the (chunk, V) logits are recomputed in backward instead of
    # being stored once per chunk (that storage would dominate peak memory).
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc, mc))
    return total / jnp.maximum(mf.sum(), 1.0)
