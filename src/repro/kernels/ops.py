"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; on CPU
(this container) ``interpret=True`` executes the kernel bodies through the
Pallas interpreter so tests validate the real kernel logic, and the
``*_auto`` wrappers fall back to the pure-jnp references for speed-sensitive
paths (dry-run lowering uses the references — see DESIGN.md §7).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant import dequant as dequant_kernel
from repro.kernels.flash_attn import flash_attention as flash_kernel
from repro.kernels.ssm_scan import ssm_scan as ssm_kernel


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def dequant(q, scales, *, qblock: int = 256, out_dtype=jnp.bfloat16,
            impl: Optional[str] = None):
    """impl: 'kernel' | 'interpret' | 'ref' | None (auto)."""
    impl = impl or ("kernel" if on_tpu() else "ref")
    if impl == "ref":
        return ref.dequant_ref(q, scales, block=qblock, out_dtype=out_dtype)
    return dequant_kernel(q, scales, qblock=qblock, out_dtype=out_dtype,
                          interpret=(impl == "interpret"))


def ssm_scan(u, dt, b_in, c_in, a_log, d_skip, *, impl: Optional[str] = None,
             block_d: int = 512, time_chunk: int = 256):
    impl = impl or ("kernel" if on_tpu() else "ref")
    if impl == "ref":
        return ref.ssm_scan_ref(u, dt, b_in, c_in, a_log, d_skip)
    return ssm_kernel(u, dt, b_in, c_in, a_log, d_skip,
                      block_d=min(block_d, u.shape[-1]),
                      time_chunk=min(time_chunk, u.shape[1]),
                      interpret=(impl == "interpret"))


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              scale: Optional[float] = None, impl: Optional[str] = None,
              block_q: int = 128, block_k: int = 128):
    impl = impl or ("kernel" if on_tpu() else "ref")
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 scale=scale)
    return flash_kernel(q, k, v, causal=causal, window=window, scale=scale,
                        block_q=min(block_q, q.shape[1]),
                        block_k=min(block_k, k.shape[1]),
                        interpret=(impl == "interpret"))
