"""Causal GQA flash-attention (forward) Pallas kernel.

Grid: (B, H, Tq/bq, Tk/bk) — kv blocks are the last (sequential) grid dim;
online-softmax stats (m, l) and the output accumulator persist in VMEM
scratch across kv iterations. Causal skipping: kv blocks strictly above the
diagonal are skipped with pl.when (no MXU work issued), which is the
structural win over the lax reference (repro.models.layers.
flash_attention_lax) that must visit every block.

GQA is handled in the index map: query head h reads kv head h // group.
Sliding-window masking composes with causal in-block masks. Head dim goes
to the MXU lane dim — multiples of 128 are the fast path.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window is not None:
        # entire kv block older than (q_start - window) is dead
        live &= k_start + bk - 1 >= q_start - window + 1

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)          # (bk, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "scale",
                                    "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, T, H, dh); k, v: (B, T, KV, dh/dv), H % KV == 0 -> (B, T, H, dv)."""
    b, t, h, dh = q.shape
    kv = k.shape[2]
    dv = v.shape[-1]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    bq = min(block_q, t)
    bk = min(block_k, t)
    if t % bq or t % bk:
        raise ValueError(f"T={t} must tile by block sizes ({bq},{bk})")
    grid = (b, h, t // bq, t // bk)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
            pl.BlockSpec((1, bk, 1, dv),
                         lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dv),
                               lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, h, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
