"""Pallas TPU kernels for the compute hot-spots FanStore touches.

  dequant     the fetch path's "decompression" (block-dequant at HBM bw)
  ssm_scan    chunked selective scan for the mamba/hybrid architectures
  flash_attn  causal GQA attention for the prefill/training path

Each kernel is pl.pallas_call + explicit BlockSpec VMEM tiling, validated on
CPU with interpret=True against the pure-jnp oracles in ref.py.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
