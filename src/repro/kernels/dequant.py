"""Blockwise int8 -> bf16 dequantization kernel (the FanStore decode path).

This is the TPU stand-in for the paper's LZSS decompression (DESIGN.md §2):
fetched sample records arrive as per-block-scaled int8; this kernel widens
them at HBM bandwidth right after the all_to_all, so "decompression" costs
one VPU pass — the same compute-for-bandwidth trade the paper measures in
its Fig 10/11, but with a dense fixed-rate codec that the VPU likes.

Tiling: grid (N/bn, F/bf); each program dequantizes a (bn, bf) VMEM tile of
payload against its (bn, bf/QBLOCK) scale tile. bf is a multiple of QBLOCK
and of 128 lanes; int8 loads use (32, 128) packing on TPU, so bn defaults
to a multiple of 32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256     # elements per quantization scale block


def _dequant_kernel(q_ref, s_ref, o_ref, *, qblock: int):
    q = q_ref[...].astype(jnp.float32)              # (bn, bf)
    s = s_ref[...].astype(jnp.float32)              # (bn, bf//qblock)
    bn, bf = q.shape
    s_wide = jnp.repeat(s, qblock, axis=1)          # (bn, bf)
    o_ref[...] = (q * s_wide).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_f", "qblock",
                                    "out_dtype", "interpret"))
def dequant(q: jnp.ndarray, scales: jnp.ndarray, *, block_n: int = 256,
            block_f: int = 512, qblock: int = QBLOCK,
            out_dtype=jnp.bfloat16, interpret: bool = False) -> jnp.ndarray:
    """q: (N, F) int8, scales: (N, F//qblock) -> (N, F) out_dtype."""
    n, f = q.shape
    if f % qblock:
        raise ValueError(f"F={f} must divide qblock={qblock}")
    bn = min(block_n, n)
    bf = min(block_f, f)
    bf = max(qblock, (bf // qblock) * qblock)
    if n % bn or f % bf:
        raise ValueError(f"shape ({n},{f}) must tile by ({bn},{bf})")
    grid = (n // bn, f // bf)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, qblock=qblock),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bf), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bf // qblock), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), out_dtype),
        interpret=interpret,
    )(q, scales)
