"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately naive — small-shape clarity over performance — and
are what the kernel tests sweep against with assert_allclose.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def dequant_ref(q: jnp.ndarray, scales: jnp.ndarray, *, block: int = 256,
                out_dtype=jnp.bfloat16) -> jnp.ndarray:
    """q: (N, F) int8; scales: (N, F//block) f16/f32 -> (N, F) out_dtype."""
    n, f = q.shape
    xb = q.reshape(n, f // block, block).astype(jnp.float32)
    out = xb * scales.astype(jnp.float32)[..., None]
    return out.reshape(n, f).astype(out_dtype)


def ssm_scan_ref(u, dt, b_in, c_in, a_log, d_skip,
                 h0: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential selective scan (fp32 state).

    u, dt: (B, T, D); b_in, c_in: (B, T, S); a_log: (D, S); d_skip: (D,).
    Returns (y (B, T, D) fp32, h_final (B, D, S) fp32).
    """
    bsz, t, d = u.shape
    s = b_in.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    if h0 is None:
        h0 = jnp.zeros((bsz, d, s), jnp.float32)

    def step(h, xs):
        ut, dtt, bt, ct = xs                     # (B,D),(B,D),(B,S),(B,S)
        a_bar = jnp.exp(dtt.astype(jnp.float32)[..., None] * a)
        bu = (dtt * ut).astype(jnp.float32)[..., None] * \
            bt.astype(jnp.float32)[:, None, :]
        h = a_bar * h + bu
        y = jnp.einsum("bds,bs->bd", h, ct.astype(jnp.float32))
        return h, y

    xs = (u.swapaxes(0, 1), dt.swapaxes(0, 1),
          b_in.swapaxes(0, 1), c_in.swapaxes(0, 1))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + u.astype(jnp.float32) * d_skip.astype(jnp.float32)
    return y, h_fin


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Naive softmax attention. q: (B,Tq,H,dh); k,v: (B,Tk,KV,*)."""
    b, tq, h, dh = q.shape
    tk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, tq, kv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    qpos = jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos + (tk - tq)     # align ends if tq != tk
    if window is not None:
        mask &= (qpos + (tk - tq) - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(b, tq, h, v.shape[-1])
