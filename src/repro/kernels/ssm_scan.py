"""Chunked selective-scan (Mamba-1) Pallas kernel.

Grid: (B, D/bd, T/tc) — the time axis is the *last* (sequential on TPU)
grid dimension, so the (bd, S) recurrent state lives in a VMEM scratch
buffer that persists across time-chunk iterations: zeroed at t_idx == 0,
carried forward otherwise, exactly the chunked recurrence of
repro.models.mamba.selective_scan but with explicit tiles.

Within a chunk the recurrence is a sequential fori_loop over tc steps —
on TPU each step is a (bd, S) VPU op; tc trades VMEM residency (inputs
(tc, bd)) against grid overhead. State math is fp32 regardless of input
dtype (bf16-safe).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, alog_ref, dskip_ref,
                y_ref, hout_ref, h_scratch, *, tc: int):
    t_idx = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t_idx == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    a = -jnp.exp(alog_ref[...].astype(jnp.float32))      # (bd, S)
    u = u_ref[...].astype(jnp.float32)                    # (1, tc, bd)
    dt = dt_ref[...].astype(jnp.float32)
    b_in = b_ref[...].astype(jnp.float32)                 # (1, tc, S)
    c_in = c_ref[...].astype(jnp.float32)
    dskip = dskip_ref[...].astype(jnp.float32)            # (bd,)

    def step(i, carry):
        h, ys = carry
        dti = dt[0, i][:, None]                           # (bd, 1)
        a_bar = jnp.exp(dti * a)                          # (bd, S)
        bu = (dti[:, 0] * u[0, i])[:, None] * b_in[0, i][None, :]
        h = a_bar * h + bu
        y = (h * c_in[0, i][None, :]).sum(axis=1)         # (bd,)
        y = y + u[0, i] * dskip
        ys = jax.lax.dynamic_update_slice(ys, y[None, :], (i, 0))
        return h, ys

    h0 = h_scratch[...]
    ys0 = jnp.zeros((tc, u.shape[2]), jnp.float32)
    h_fin, ys = jax.lax.fori_loop(0, tc, step, (h0, ys0))
    h_scratch[...] = h_fin
    y_ref[...] = ys[None].astype(y_ref.dtype)

    @pl.when(t_idx == nt - 1)
    def _emit_state():
        hout_ref[...] = h_fin[None]


@functools.partial(jax.jit,
                   static_argnames=("block_d", "time_chunk", "interpret"))
def ssm_scan(u, dt, b_in, c_in, a_log, d_skip, *, block_d: int = 512,
             time_chunk: int = 256, interpret: bool = False):
    """u, dt: (B, T, D); b_in, c_in: (B, T, S); a_log: (D, S); d_skip: (D,).

    Returns (y (B, T, D) fp32, h_final (B, D, S) fp32).
    """
    bsz, t, d = u.shape
    s = b_in.shape[-1]
    bd = min(block_d, d)
    tc = min(time_chunk, t)
    if d % bd or t % tc:
        raise ValueError(f"(T={t}, D={d}) must tile by (tc={tc}, bd={bd})")
    grid = (bsz, d // bd, t // tc)
    y, h_fin = pl.pallas_call(
        functools.partial(_ssm_kernel, tc=tc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, bd), lambda b, j, ti: (b, ti, j)),   # u
            pl.BlockSpec((1, tc, bd), lambda b, j, ti: (b, ti, j)),   # dt
            pl.BlockSpec((1, tc, s), lambda b, j, ti: (b, ti, 0)),    # B
            pl.BlockSpec((1, tc, s), lambda b, j, ti: (b, ti, 0)),    # C
            pl.BlockSpec((bd, s), lambda b, j, ti: (j, 0)),           # a_log
            pl.BlockSpec((bd,), lambda b, j, ti: (j,)),               # d_skip
        ],
        out_specs=[
            pl.BlockSpec((1, tc, bd), lambda b, j, ti: (b, ti, j)),   # y
            pl.BlockSpec((1, bd, s), lambda b, j, ti: (b, j, 0)),     # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, d, s), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, s), jnp.float32)],
        interpret=interpret,
    )(u, dt, b_in, c_in, a_log, d_skip)
    return y, h_fin
