"""Config registry + dry-run machinery (small-mesh subprocess checks)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke, \
    shape_applicable
from repro.utils.roofline import parse_collectives

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_registry_complete():
    assert len(ARCH_IDS) == 10
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        smoke = get_smoke(arch)
        assert cfg.name == arch
        assert smoke.family == cfg.family
        assert smoke.num_layers <= 4


def test_shape_applicability_matrix():
    runnable = {(a, s) for a in ARCH_IDS for s in SHAPES
                if shape_applicable(get_config(a), SHAPES[s])[0]}
    # long_500k only for ssm/hybrid
    longs = {a for (a, s) in runnable if s == "long_500k"}
    assert longs == {"falcon-mamba-7b", "hymba-1.5b"}
    # everything else runs everywhere
    assert len(runnable) == 10 * 3 + 2


def test_parse_collectives_counts_payloads():
    hlo = """
      %all-reduce.1 = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={{0,1,2,3}}, to_apply=%sum
      %ag = bf16[4,2048]{1,0} all-gather(bf16[1,2048]{1,0} %y), replica_groups={{0,1,2,3}}, dimensions={0}
      %rs = f32[512]{0} reduce-scatter(f32[2048]{0} %z), replica_groups={{0,1,2,3}}, to_apply=%sum
      %cp = u8[100]{0} collective-permute(u8[100]{0} %w), source_target_pairs={{0,1}}
      %dot.5 = f32[8,8]{1,0} dot(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
    """
    stats = parse_collectives(hlo)
    assert stats.count == 4
    assert stats.bytes_by_kind["all-reduce"] == 4096
    assert stats.bytes_by_kind["all-gather"] == 4 * 2048 * 2
    assert stats.bytes_by_kind["reduce-scatter"] == 2048 * 4
    assert stats.bytes_by_kind["collective-permute"] == 100
    # wire: ar 2x result x 3/4; ag result x 3/4; rs operand x 3/4; cp operand
    expect = 2 * 4096 * 0.75 + 16384 * 0.75 + 8192 * 0.75 + 100
    assert stats.wire_bytes == pytest.approx(expect)


@pytest.mark.slow
def test_dryrun_cell_small_mesh():
    """The dry-run machinery end-to-end on a 4x2 mesh (8 fake devices)."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.launch.dryrun import lower_cell, _mem_dict, _cell_costs
        from repro.configs import get_smoke
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_smoke("chatglm3-6b")
        # reduced shapes: monkeypatch the shape table for the subprocess
        import repro.configs.base as base
        base.SHAPES["train_4k"] = base.ShapeConfig("train_4k", 64, 8, "train")
        lowered, compiled, info = lower_cell("chatglm3-6b", "train_4k", mesh,
                                             cfg=cfg)
        mem, peak = _mem_dict(compiled)
        costs = _cell_costs(compiled)
        assert costs["flops"] > 0
        assert peak is None or peak > 0
        print("OK", int(costs["flops"]))
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_mesh_factories():
    from repro.launch.mesh import make_debug_mesh
    m = make_debug_mesh(1, 1)
    assert m.axis_names == ("data", "model")
